"""Paper §5.3: safety/regression sweep over 160 configurations.

Batch x L_K x H_KV grid exactly as the paper's matrix; asserts the
patched policy NEVER regresses the modeled latency (>= 0.99x standard)
and that wins at L_K = 512 appear only for H_KV in {1, 2}.
"""
from __future__ import annotations

from repro.core.occupancy import H100_SXM, modeled_latency_us
from repro.core.split_policy import DecodeWorkload, fa3_baseline, paper_policy

from benchmarks.common import print_table, write_csv

BATCHES = (1, 2, 4, 8)
LKS = (128, 256, 384, 512, 1024, 2048, 4096, 8192)
HKVS = (1, 2, 4, 8, 32)


def main() -> None:
    rows = []
    worst = 1.0
    wins = []
    for b in BATCHES:
        for lk in LKS:
            for hkv in HKVS:
                w = DecodeWorkload(b, 1, lk, 64, hkv, 128)
                s0 = fa3_baseline(w, num_cores=132)
                s1 = paper_policy(w, num_cores=132)
                t0 = modeled_latency_us(w, s0, hw=H100_SXM, num_cores=132)
                t1 = modeled_latency_us(w, s1, hw=H100_SXM, num_cores=132)
                sp = t0 / t1
                worst = min(worst, sp)
                if sp > 1.01:
                    wins.append((b, lk, hkv, round(sp, 3)))
                rows.append([b, lk, hkv, s0, s1, round(sp, 3)])
    write_csv("regression_sweep", ["batch", "lk", "hkv", "s_std",
                                   "s_patched", "speedup"], rows)
    print(f"{len(rows)} configurations swept "
          f"({len(BATCHES)}x{len(LKS)}x{len(HKVS)})")
    print(f"worst-case speedup: {worst:.4f} (paper: >= 0.99x everywhere)")
    print_table(["batch", "lk", "hkv", "speedup"],
                [[b, lk, hkv, sp] for b, lk, hkv, sp in wins],
                "cells with wins")
    assert worst >= 0.99, f"regression! {worst}"
    assert all(lk == 512 and hkv in (1, 2) for _, lk, hkv, _ in wins), wins


if __name__ == "__main__":
    main()
