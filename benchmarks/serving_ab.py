"""Serving-path A/B: fused bucketed prefill vs loop prefill admission.

Drives the request-lifecycle :class:`~repro.serving.ServingEngine`
under a Poisson-ish synthetic arrival stream (exponential inter-arrival
gaps in scheduling steps, seeded) and reports the two latencies serving
people actually watch:

- **TTFT** — time to first token, submit -> first TOKEN event (includes
  queueing for a free slot + admission prefill);
- **TPOT** — time per output token after the first (decode lockstep),
  reported as mean / p50 / p90 — tail latency is what SLOs bind on,
  and a mean hides the slow-bucket steps a p90 exposes.

Both come straight off the engine's :mod:`repro.obs` metrics registry
(``ttft_ms`` / ``tpot_ms`` histograms, stamped by the lifecycle hooks)
rather than hand-timing around ``step()`` — the columns here and a
``ServeConfig.metrics_path`` dump are the same numbers.

Cells: {loop, fused} admission x {fa3_baseline, paper} split policy,
all on the metadata-enabled plan path.  On this CPU container the
wall-clock deltas are noisy; the *structural* columns are the
reproducible claim, asserted below:

- fused admission performs O(1) planned launches per admitted request
  (``PlanCacheStats.launches[("prefill", bucket)]`` sums to the number
  of admissions; loop admission performs O(prompt_len) decode steps);
- prefill-kind plans flow through the same Planner/PlanCache as decode
  plans (misses == distinct prompt buckets, the rest are hits);
- the split policy never runs inside traced code
  (``ops.policy_eval_count() == 0``);
- greedy tokens agree across all four cells (the policy and the
  admission path change the schedule, never the math).

``--smoke`` runs a seconds-scale variant wired into ``make verify`` and
CI.  CSV lands in ``experiments/bench/`` (smoke runs: the gitignored
``experiments/bench/smoke/`` — CI must not dirty the tree).
"""
from __future__ import annotations

import argparse
from collections import deque

import jax
import numpy as np

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.kernels import ops
from repro.models import build_model
from repro.obs import ObsConfig
from repro.plan import bucket_seqlen
from repro.serving import Request, ServingEngine

from benchmarks.common import print_table, write_csv


def _workload(smoke: bool, seed: int = 0):
    """(prompt lengths, arrival steps, knobs) for one run."""
    rng = np.random.default_rng(seed)
    if smoke:
        num, max_new, max_len, slots = 5, 4, 256, 2
        lens = [5, 40, 150, 7, 130]          # two prefill buckets
    else:
        num, max_new, max_len, slots = 12, 12, 512, 4
        lens = rng.integers(8, 400, size=num).tolist()
    gaps = rng.exponential(scale=1.5, size=num)
    arrivals = np.floor(np.cumsum(gaps)).astype(int).tolist()
    return lens, arrivals, dict(max_new=max_new, max_len=max_len,
                                slots=slots)


def run_cell(model, params, policy: str, prefill_mode: str,
             lens, arrivals, knobs, seed: int = 0):
    rng = np.random.default_rng(seed + 1)
    reqs = deque(sorted(
        ((a, Request(i, rng.integers(1, model.cfg.vocab_size,
                                     size=n).tolist(),
                     max_new_tokens=knobs["max_new"]))
         for i, (n, a) in enumerate(zip(lens, arrivals))),
        key=lambda p: p[0]))
    # TTFT/TPOT come from the repro.obs metrics registry (the same
    # surface ServeConfig.metrics_path dumps at drain) — the engine's
    # lifecycle hooks stamp submit/first-token/finish, so the benchmark
    # no longer hand-times events around step()
    obs = ObsConfig(metrics=True).resolve()
    eng = ServingEngine(
        model, ServeConfig(model=model.cfg, split_policy=policy,
                           prefill_mode=prefill_mode),
        max_len=knobs["max_len"], batch_slots=knobs["slots"], obs=obs)
    eng.load(params)

    ops.reset_policy_eval_count()
    step_i = 0
    while reqs or eng.has_work():
        while reqs and reqs[0][0] <= step_i:
            eng.submit(reqs.popleft()[1])
        if eng.has_work():
            eng.step()
        step_i += 1
    outs = eng.drain()

    mx = obs.metrics_snapshot()["metrics"]
    ttft = mx["ttft_ms"]["aggregate"]
    tpot = mx["tpot_ms"]["aggregate"]
    assert ttft["count"] == len(outs) == tpot["count"]
    # counters from the engine's JSON snapshot (the same surface
    # ServeConfig.stats_path dumps at drain) — not re-derived by hand
    st = eng.stats.to_json()
    n_dec = sum(v for k, v in st["launches"].items()
                if not k.startswith("prefill/"))
    n_pre = sum(v for k, v in st["launches"].items()
                if k.startswith("prefill/"))
    pre_miss = sum(1 for k in st["seen_buckets"]
                   if k.startswith("prefill/"))
    row = [policy, prefill_mode, len(outs),
           sum(len(c.tokens) for c in outs), n_dec, n_pre, pre_miss,
           round(ttft["mean"], 1), round(ttft["p50"], 1),
           round(tpot["mean"], 1), round(tpot["p50"], 1),
           round(tpot["p90"], 1),
           ops.policy_eval_count()]
    return row, [c.tokens for c in outs]


def main(smoke: bool = False) -> None:
    cfg = reduced_config("qwen2.5-3b", num_layers=2,
                         d_model=32 if smoke else 64)
    assert cfg.num_kv_heads == 1, "A/B needs the MQA low-head-count shape"
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    lens, arrivals, knobs = _workload(smoke)

    header = ["policy", "prefill", "requests", "tokens", "decode_launches",
              "prefill_launches", "prefill_plan_misses", "ttft_ms_mean",
              "ttft_ms_p50", "tpot_ms_mean", "tpot_ms_p50", "tpot_ms_p90",
              "policy_evals_in_dispatch"]
    rows, token_sets = [], []
    for policy in ("fa3_baseline", "paper"):
        for mode in ("loop", "fused"):
            row, toks = run_cell(model, params, policy, mode, lens,
                                 arrivals, knobs)
            rows.append(row)
            token_sets.append(toks)
    title = ("serving A/B: fused vs loop prefill admission "
             f"({'smoke' if smoke else 'full'}, Poisson-ish arrivals)")
    print_table(header, rows, title)
    write_csv("serving_ab", header, rows, smoke=smoke)

    # structural claims (the reproducible part of the A/B)
    n_req = len(lens)
    scfg = ServeConfig(model=cfg)
    width = scfg.prefill_bucket or scfg.seqlen_bucket
    buckets = {min(bucket_seqlen(n, width), knobs["max_len"])
               for n in lens}
    for row in rows:
        assert row[12] == 0, "policy ran inside a traced step"
        if row[1] == "fused":
            assert row[5] == n_req, \
                "fused admission must be O(1) planned launches/request"
            assert row[6] == len(buckets), \
                "prefill plans must cache per prompt-length bucket"
            assert row[4] < rows[0][4], \
                "fused admission must cut decode-lockstep launches"
        else:
            assert row[5] == 0 and row[6] == 0
    assert all(t == token_sets[0] for t in token_sets), \
        "admission path / policy changed greedy tokens"
    print("\nserving A/B: fused admission = 1 planned prefill launch per "
          f"request ({n_req} requests, {len(buckets)} bucket plans), "
          "policy evals in dispatch = 0, greedy tokens identical across "
          "all cells")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale variant (make verify / CI)")
    main(**vars(ap.parse_args()))
