"""Paper Table 1: standard vs sequence-aware patched kernel (A/B).

CPU container -> the paper's CUDA-graph wall-clock is replaced by the
calibrated analytic H100 occupancy model (core/occupancy.py); the
*decisions* (split counts) are the faithful policy ports.  Each row
reports the policy's split choice, the modeled latencies, the modeled
speedup, and the paper's measured speedup — the structural claims
(which cells change, by roughly how much, no regressions) are what this
table reproduces and what the tests assert.
"""
from __future__ import annotations

from repro.core.occupancy import H100_SXM, modeled_latency_us
from repro.core.split_policy import DecodeWorkload, fa3_baseline, paper_policy

from benchmarks.common import print_table, write_csv

# (L_K, H_KV) -> paper-measured (standard us, patched us)
PAPER_TABLE1 = {
    (128, 1): (9.56, 9.56), (128, 2): (9.45, 9.45), (128, 8): (9.46, 9.46),
    (256, 1): (11.57, 11.57), (256, 2): (11.58, 11.58),
    (256, 8): (11.60, 11.60),
    (384, 1): (13.60, 13.60), (384, 2): (13.57, 13.57),
    (384, 8): (13.55, 13.55),
    (512, 1): (13.72, 11.37), (512, 2): (13.52, 10.93),
    (512, 8): (13.56, 13.56),
    (2048, 1): (11.99, 11.99), (2048, 2): (12.66, 12.66),
    (2048, 8): (12.73, 12.73),
    (4096, 1): (13.88, 13.88), (4096, 2): (13.53, 13.53),
    (4096, 8): (15.05, 15.05),
}


def rows():
    out = []
    for (lk, hkv), (p_std, p_pat) in PAPER_TABLE1.items():
        w = DecodeWorkload(1, 1, lk, 64, hkv, 128)
        s_std = fa3_baseline(w, num_cores=H100_SXM.num_cores)
        s_pat = paper_policy(w, num_cores=H100_SXM.num_cores)
        t_std = modeled_latency_us(w, s_std, hw=H100_SXM,
                                   num_cores=H100_SXM.num_cores)
        t_pat = modeled_latency_us(w, s_pat, hw=H100_SXM,
                                   num_cores=H100_SXM.num_cores)
        out.append([lk, hkv, s_std, s_pat,
                    round(t_std, 2), round(t_pat, 2),
                    round(t_std / t_pat, 3),
                    round(p_std / p_pat, 3),
                    round(t_std / p_std - 1, 3)])
    return out


def main() -> None:
    header = ["L_K", "H_KV", "s_std", "s_patched", "model_std_us",
              "model_patched_us", "model_speedup", "paper_speedup",
              "model_cal_err"]
    r = rows()
    print_table(header, r, "Table 1 A/B (policy decisions + modeled "
                           "latency vs paper measurements)")
    write_csv("table1_ab", header, r)
    changed = [(lk, hkv) for (lk, hkv), (a, b) in PAPER_TABLE1.items()
               if a != b]
    ours = [(row[0], row[1]) for row in r if row[2] != row[3]]
    assert set(changed) == set(ours), (changed, ours)
    print(f"\ncells changed by the patch: {sorted(ours)} "
          f"(matches paper: {sorted(changed)})")


if __name__ == "__main__":
    main()
