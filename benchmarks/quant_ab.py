"""Quantized-KV A/B: the repro.quant fused path vs dequant-then-attend.

Three halves, all on the paper's low-head-count decode regime:

1. **Decision + cost sweep** — over the paper grid (H_KV ∈ {1, 2, 4}
   at head_dim 128, plus the reduced-engine MQA shape), compare the
   fused int8 launch (1-byte KV stream at the split the measured table
   picked for the *int8 family*) against dequant-then-attend (an extra
   full-cache read+f32 write pass, then attending the materialized f32
   cache at *its* family's split).  Both sides are priced by the same
   occupancy cost model the committed reference table is the argmin of,
   so the reproducible claims are structural: the fused path is never
   slower on any covered cell, and the int8 family carries its own
   split decisions (``s_int8 != s_bf16`` on a nonzero number of cells —
   the policy reads ``dtype_bytes``, not just shape).
2. **Tolerance oracle** — real arrays through the real kernels: the
   fused Pallas launch (storage-dtype blocks dequantized in-register
   against per-row scales) vs the unfused xla reference (materialize
   ``Quantizer.dequantize``, then attend), from the SAME
   :class:`~repro.quant.QuantizedKV` artifact, for int8 AND fp8, with
   ragged ``kv_len`` and poisoned pad tails (data *and* scales), dense
   and ``PagedKV`` views.  Agreement within ``repro.quant.AB_ATOL`` —
   the quantization error itself cancels (both sides read the same
   artifact); the bound covers kernel accumulation-order drift only.
3. **Engine end-to-end** — the real :class:`ServingEngine` under
   ``ServeConfig.kv_quant="int8"`` across the serving feature matrix
   (dense, paged, paged+prefix-sharing, paged+speculation): greedy
   token streams identical across all four cells, the split policy
   evaluated zero times inside traced code, page conservation after
   the paged cells, and every decode plan keyed on the int8 family
   (``workload.dtype_bytes == 1``, provenance in ``describe()``).

``--smoke`` is the seconds-scale variant wired into ``make verify``
(``quant-smoke``) and CI.  CSV lands in ``experiments/bench/`` (smoke:
the gitignored ``experiments/bench/smoke/``).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.core.occupancy import TPU_V5E, modeled_latency_us
from repro.core.split_policy import DecodeWorkload
from repro.kernels import ops
from repro.models import build_model
from repro.plan import AttentionSpec, Planner
from repro.quant import AB_ATOL, Quantizer
from repro.serving import Request, ServingEngine
from repro.tune import REFERENCE_TABLE_PATH, SplitTable

from benchmarks.common import print_table, write_csv

PAPER_HEADS = ((64, 1), (16, 2), (32, 4), (4, 1))   # Table 1 rows + engine MQA


# ---------------------------------------------------------------------------
# 1. decision + modeled-cost sweep
# ---------------------------------------------------------------------------

def _attend_us(w: DecodeWorkload, s: int, cores: int) -> float:
    """Kernel latency + the per-row scale stream (both paths read it)."""
    scale_bytes = 2 * w.seqlen_k * w.num_heads_kv * 4      # K and V, f32
    return modeled_latency_us(w, s, num_cores=cores) \
        + scale_bytes / TPU_V5E.hbm_bw * 1e6


def _dequant_pass_us(w: DecodeWorkload) -> float:
    """The dequant-then-attend extra pass: read the 1-byte cache +
    scales, write the materialized f32 cache (which the attend then
    re-reads — that read is priced by the f32 attend workload)."""
    elems = 2 * w.seqlen_k * w.num_heads_kv * w.head_dim   # K and V
    scale_bytes = 2 * w.seqlen_k * w.num_heads_kv * 4
    return (elems * (1 + 4) + scale_bytes) / TPU_V5E.hbm_bw * 1e6


def sweep(table: SplitTable, smoke: bool) -> List[List]:
    lks = (384, 512, 1024) if smoke else (128, 256, 384, 512, 640,
                                          1024, 4096)
    batches = (1,) if smoke else (1, 2, 4, 8)
    cores = table.fingerprint["num_cores"]
    planner = Planner(policy="measured", table=table, num_cores=cores)
    rows = []
    for hq, hkv in PAPER_HEADS:
        for b in batches:
            for lk in lks:
                w8 = DecodeWorkload(b, 1, lk, hq, hkv, 128,
                                    dtype_bytes=1, kv_dtype="int8")
                wbf = DecodeWorkload(b, 1, lk, hq, hkv, 128)
                p8 = planner.plan(AttentionSpec.from_workload(w8))
                pbf = planner.plan(AttentionSpec.from_workload(wbf))
                covered = table.covers(w8)
                assert p8.tuned == covered
                # dequant-then-attend materializes f32 and attends it
                # at the split ITS OWN family would plan (best case for
                # the baseline: same policy, f32 bytes)
                w32 = DecodeWorkload(b, 1, lk, hq, hkv, 128,
                                     dtype_bytes=4, kv_dtype="float32")
                s32 = planner.plan(AttentionSpec.from_workload(w32)) \
                             .num_splits
                fused = _attend_us(w8, p8.num_splits, cores)
                deq = _dequant_pass_us(w8) + _attend_us(w32, s32, cores)
                rows.append([b, lk, hq, hkv, covered, pbf.num_splits,
                             p8.num_splits, round(fused, 2),
                             round(deq, 2), round(deq / fused, 3)])
    return rows


# ---------------------------------------------------------------------------
# 2. tolerance oracle (real kernels, same artifact both sides)
# ---------------------------------------------------------------------------

def _poisoned_artifact(rng, B, Lk, hq, hkv, D, kv_dtype):
    q = jnp.asarray(rng.standard_normal((B, hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Lk, hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Lk, hkv, D)), jnp.float32)
    kv_len = jnp.asarray(rng.integers(1, Lk + 1, size=B), jnp.int32)
    art = Quantizer.from_kv_dtype(kv_dtype).quantized_kv(k, v)
    # poison BOTH the data and the scale tails past each row's kv_len:
    # masking, not luck, must keep them out of the fused accumulator
    rows = jnp.arange(Lk)[None, :, None] >= kv_len[:, None, None]
    art = art._replace(
        k=jnp.where(rows[..., None], jnp.asarray(127, art.k.dtype), art.k),
        v=jnp.where(rows[..., None], jnp.asarray(-127, art.v.dtype), art.v),
        k_scale=jnp.where(rows, 1e4, art.k_scale),
        v_scale=jnp.where(rows, 1e4, art.v_scale))
    return q, art, kv_len


def oracle(smoke: bool) -> List[List]:
    shapes = [(2, 256, 8, 1, 64)] if smoke else \
        [(2, 256, 8, 1, 64), (1, 384, 16, 2, 128), (4, 160, 4, 4, 64)]
    rng = np.random.default_rng(0)
    rows = []
    for kv_dtype in ("int8", "fp8"):
        for B, Lk, hq, hkv, D in shapes:
            q, art, kv_len = _poisoned_artifact(rng, B, Lk, hq, hkv, D,
                                                kv_dtype)
            fused = ops.decode_attention_quant(q, art, kv_len,
                                               impl="pallas")
            unfused = ops.decode_attention_quant(q, art, kv_len,
                                                 impl="xla")
            # the unfused path IS dequant-then-attend, bit-for-bit
            qz = Quantizer.from_kv_dtype(kv_dtype)
            explicit = ops.decode_attention(
                q, qz.dequantize(art.k, art.k_scale),
                qz.dequantize(art.v, art.v_scale), kv_len, impl="xla")
            assert np.array_equal(np.asarray(unfused),
                                  np.asarray(explicit)), \
                "unfused quant path must BE dequant-then-attend"
            err = float(jnp.max(jnp.abs(fused - unfused)))
            tol = AB_ATOL[kv_dtype]
            assert err <= tol, \
                f"fused {kv_dtype} drifted {err} > {tol} at " \
                f"B{B} L{Lk} Hq{hq} Hkv{hkv} D{D}"
            rows.append([kv_dtype, B, Lk, hq, hkv, D, "dense",
                         f"{err:.2e}", tol])
    # PagedKV views: the scale pools page with the data pools (one page
    # table serves all four leaves); fused paged == fused dense-gathered
    B, ps, n, hkv, hq, D = 2, 16, 3, 1, 4, 8
    pool = 2 * n + 1                                  # page 0 = trash
    kp = jnp.asarray(rng.standard_normal((pool, ps, hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((pool, ps, hkv, D)), jnp.float32)
    table = jnp.asarray([[1, 2, 3, 0], [4, 5, 6, 0]], jnp.int32)
    kv_len = jnp.asarray([40, 17], jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, hq, D)), jnp.float32)
    for kv_dtype in ("int8", "fp8"):
        qz = Quantizer.from_kv_dtype(kv_dtype)
        kq, ks = qz.quantize(kp)
        vq, vs = qz.quantize(vp)
        paged = ops.decode_attention_quant(
            q, (ops.PagedKV(kq, table, n), ops.PagedKV(vq, table, n),
                ops.PagedKV(ks, table, n), ops.PagedKV(vs, table, n)),
            kv_len, impl="pallas")
        dense = ops.decode_attention_quant(
            q, (ops.gather_pages(kq, table, num_pages=n),
                ops.gather_pages(vq, table, num_pages=n),
                ops.gather_pages(ks, table, num_pages=n),
                ops.gather_pages(vs, table, num_pages=n)),
            kv_len, impl="pallas")
        assert np.array_equal(np.asarray(paged), np.asarray(dense)), \
            f"paged fused {kv_dtype} != dense-gathered fused"
        rows.append([kv_dtype, B, n * ps, hq, hkv, D, "paged",
                     "0 (bit-eq)", AB_ATOL[kv_dtype]])
    return rows


# ---------------------------------------------------------------------------
# 3. engine end-to-end across the serving feature matrix
# ---------------------------------------------------------------------------

def _traffic(cfg, n: int) -> List[Request]:
    rng = np.random.default_rng(7)
    system = rng.integers(0, cfg.vocab_size, size=96).tolist()
    reqs = []
    for i in range(n):
        # repetitive tails draft well under the ngram cell
        tail = ([3, 5, 7, 9] * 3)[: 4 + 2 * i]
        reqs.append(Request(i, system + tail, max_new_tokens=8))
    return reqs


def run_engine_cell(model, params, name: str, **cfg_kw):
    eng = ServingEngine(
        model, ServeConfig(model=model.cfg, kv_quant="int8", **cfg_kw),
        max_len=256, batch_slots=2)
    eng.load(params)
    ops.reset_policy_eval_count()
    t0 = time.monotonic()
    for r in _traffic(model.cfg, 4):
        eng.submit(r)
    outs = eng.drain()
    dt = time.monotonic() - t0
    evals = ops.policy_eval_count()
    assert evals == 0, f"{name}: policy ran inside a traced step"
    spec = eng.sched.decode_spec(128)
    assert spec.workload().dtype_bytes == 1, \
        f"{name}: engine plans must key the int8 family"
    plan = eng.sched.decode_plan(127)
    d = plan.describe()
    assert d.get("kv_dtype") == "int8" and d.get("dtype_bytes") == 1, \
        f"{name}: plan provenance must carry the quant family: {d}"
    if cfg_kw.get("cache_layout") == "paged":
        eng.cache.check_conservation()
    toks = [c.tokens for c in sorted(outs, key=lambda c: c.request_id)]
    return toks, dt, plan.num_splits


def engine_matrix(model, params, smoke: bool) -> List[List]:
    cells = [("dense", {}), ("paged", {"cache_layout": "paged"})]
    if not smoke:
        cells += [
            ("paged+prefix", {"cache_layout": "paged",
                              "share_prefix": True}),
            ("paged+spec", {"cache_layout": "paged",
                            "speculation": "ngram", "speculation_k": 4}),
        ]
    rows, streams = [], {}
    for name, kw in cells:
        toks, dt, s = run_engine_cell(model, params, name, **kw)
        streams[name] = toks
        ntok = sum(len(t) for t in toks)
        rows.append([name, ntok, s, round(1e3 * dt / max(1, ntok), 1)])
    base = streams["dense"]
    for name, toks in streams.items():
        assert toks == base, \
            f"int8 greedy stream diverged on the {name} cell"
    return rows


def main(smoke: bool = False) -> None:
    table = SplitTable.load(REFERENCE_TABLE_PATH)
    header = ["batch", "seqlen_k", "hq", "hkv", "covered", "s_bf16",
              "s_int8", "fused_us", "dequant_attend_us", "speedup"]
    rows = sweep(table, smoke)
    print_table(header, rows,
                f"quant A/B: fused int8 vs dequant-then-attend "
                f"({'smoke' if smoke else 'full'}, modeled, table "
                f"{table.version})")
    write_csv("quant_ab", header, rows, smoke=smoke)

    # structural claims (the reproducible part of the A/B)
    covered = [r for r in rows if r[4]]
    assert covered, "sweep must hit reference-covered int8 families"
    for r in rows:
        assert r[7] <= r[8] + 1e-9, \
            f"fused int8 modeled slower than dequant-then-attend: {r}"
    distinct = [r for r in covered if r[5] != r[6]]
    if not smoke:
        assert distinct, \
            "int8 family must carry its own split decisions somewhere " \
            "on the covered grid"

    orows = oracle(smoke)
    print_table(["kv_dtype", "batch", "seqlen_k", "hq", "hkv", "head_dim",
                 "layout", "max_abs_err", "atol"], orows,
                "quant A/B: fused-vs-unfused tolerance oracle "
                "(poisoned tails, ragged kv_len)")

    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    erows = engine_matrix(model, params, smoke)
    print_table(["cell", "tokens", "num_splits", "ms_per_token"], erows,
                "quant A/B: int8 engine across the serving matrix "
                "(greedy streams identical)")

    best = max(rows, key=lambda r: r[9])
    print(f"\nquant A/B: fused int8 never slower on all {len(rows)} "
          f"cells ({len(covered)} table-covered; best {best[9]}x vs "
          f"dequant-then-attend at B{best[0]} L{best[1]} Hkv{best[3]}); "
          f"{len(distinct)} covered cells plan DIFFERENT splits for the "
          "int8 family than bf16; fused==unfused within per-dtype "
          "tolerance (int8 + fp8, dense + paged, poisoned tails); "
          f"engine matrix: {len(erows)} cells, identical greedy "
          "streams, policy evals 0, conservation + int8-family plan "
          "provenance asserted")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale variant (make verify / CI)")
    main(**vars(ap.parse_args()))
