"""Run every benchmark (one per paper table/figure + the roofline report).

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        cache_ab,
        mesh_split_ab,
        metadata_ab,
        obs_ab,
        prefix_ab,
        quant_ab,
        regression_sweep,
        roofline_report,
        serving_ab,
        shard_ab,
        spec_ab,
        table1_ab,
        tune_ab,
        u_curve_sweep,
    )

    jobs = [
        ("table1_ab (paper Table 1)", table1_ab.main),
        ("u_curve_sweep (paper Fig. 3)", u_curve_sweep.main),
        ("regression_sweep (paper §5.3, 160 configs)",
         regression_sweep.main),
        ("roofline_report (§Roofline)", roofline_report.main),
        ("metadata_ab (paper §5 serving path)", metadata_ab.main),
        ("serving_ab (fused vs loop prefill admission, TTFT/TPOT)",
         serving_ab.main),
        ("cache_ab (DenseLayout vs PagedKVCache, mixed prompt lengths)",
         cache_ab.main),
        ("prefix_ab (share_prefix on vs off, shared system prompt)",
         prefix_ab.main),
        ("tune_ab (measured vs paper vs fa3_baseline split policies)",
         tune_ab.main),
        ("spec_ab (speculative verify steps vs plain decode)",
         spec_ab.main),
        ("quant_ab (fused quantized KV vs dequant-then-attend)",
         quant_ab.main),
        ("shard_ab (single vs dp slot shards vs sp seq-sharded decode; "
         "re-execs under 8 forced devices)", shard_ab.main),
        ("obs_ab (tracing on vs off: bit-identical serving + "
         "Perfetto-loadable timeline)", obs_ab.main),
        ("mesh_split_ab smoke (pod policy A/B; re-execs under 16 "
         "forced devices — full 512-device run stays manual)",
         mesh_split_ab.smoke_main),
    ]
    failures = 0
    for name, fn in jobs:
        print(f"\n{'=' * 72}\nBENCH {name}\n{'=' * 72}")
        t0 = time.time()
        try:
            fn()
            print(f"[ok] {name} ({time.time() - t0:.1f}s)")
        except Exception as e:                           # pragma: no cover
            failures += 1
            print(f"[FAIL] {name}: {type(e).__name__}: {e}")
    print(f"\n{len(jobs) - failures}/{len(jobs)} benchmarks ok")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
