"""Shared benchmark plumbing: CSV emission + output locations."""
from __future__ import annotations

import csv
import io
from pathlib import Path
from typing import Dict, Iterable, List, Sequence

OUT_DIR = Path(__file__).resolve().parents[1] / "experiments" / "bench"
# --smoke variants run from `make verify` / CI on every push; their CSVs
# land in a gitignored subdir so a verify run never dirties the tree
# (full-run CSVs stay committed next to the tables they reproduce)
SMOKE_DIR = OUT_DIR / "smoke"


def write_csv(name: str, header: Sequence[str],
              rows: Iterable[Sequence], smoke: bool = False) -> Path:
    out = SMOKE_DIR if smoke else OUT_DIR
    out.mkdir(parents=True, exist_ok=True)
    p = out / f"{name}.csv"
    with open(p, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow(r)
    return p


def print_table(header: Sequence[str], rows: List[Sequence],
                title: str = "") -> None:
    if title:
        print(f"\n== {title} ==")
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(header)]
    print("  ".join(str(h).ljust(w) for h, w in zip(header, widths)))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
