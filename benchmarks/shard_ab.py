"""Mesh-native serving A/B: single engine vs dp slot shards vs sp
sequence-sharded decode (repro.shard).

Three cells serve the SAME greedy request stream on the reduced
qwen2.5-3b (H_KV < 4, the paper's low-head-count regime — sp=4 is
storage-forced, so ``mesh_splits`` provenance is guaranteed):

- ``single``  — one ServingEngine, 2 slots (the baseline).
- ``dp4``     — 4 data-parallel slot shards x 2 slots = 8 slots (4x the
  capacity claim), each shard admitting against its OWN page budget.
- ``sp4``     — one shard whose decode sequence-shards the KV cache
  over 4 chips (the fused shard_map split-KV combine — chips for SMs).

Structural claims (the reproducible part):
- greedy tokens bit-identical across all three cells, per request_id;
- zero policy evaluations inside traced code in every cell;
- every sp4 decode plan carries ``mesh_splits == 4`` and the realized
  shard mesh;
- dp4 launches are counted PER SHARD and every shard worked.

The benchmark needs 8 virtual devices, so it always re-execs itself in
a fresh process with ``XLA_FLAGS`` set (jax device flags are frozen at
first import):

    PYTHONPATH=src python -m benchmarks.shard_ab [--smoke]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(smoke: bool = False) -> None:
    """Re-exec the benchmark under 8 forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"), _ROOT,
                    env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, "-m", "benchmarks.shard_ab", "--inner"]
    if smoke:
        cmd.append("--smoke")
    subprocess.run(cmd, check=True, env=env, cwd=_ROOT)


def bench(smoke: bool = False) -> None:
    import time

    import jax
    import numpy as np

    from benchmarks.common import print_table, write_csv
    from repro.configs.base import ServeConfig
    from repro.configs.reduced import reduced_config
    from repro.kernels import ops
    from repro.models.registry import build_model
    from repro.obs import ObsConfig
    from repro.serving import Request, ServingEngine
    from repro.shard import (
        ShardSpec,
        ShardedServingEngine,
        clear_shard_plan_caches,
    )

    assert len(jax.devices()) >= 8, \
        "shard_ab needs 8 devices (run via the --inner re-exec)"

    cfg = reduced_config("qwen2.5-3b", num_layers=2,
                         d_model=32 if smoke else 64)
    assert cfg.num_kv_heads < 4, \
        "A/B needs the low-head-count shape (sp=4 storage-forced)"
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    scfg = ServeConfig(model=cfg)
    max_len = 256
    n_req = 8 if smoke else 24
    max_new = 8 if smoke else 24
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(4, 16))).tolist(),
                    max_new_tokens=max_new)
            for i in range(n_req)]

    cells = [
        ("single", 1, 1),
        ("dp4", 4, 1),
        ("sp4", 1, 4),
    ]
    header = ["mode", "dp", "sp", "slots", "requests", "new_tokens",
              "wall_s", "toks_per_s", "launches", "per_shard_launches",
              "ttft_ms_mean", "tpot_ms_mean", "policy_evals"]
    rows, token_sets, shard_launches = [], [], {}
    for mode, dp, sp in cells:
        clear_shard_plan_caches()
        ops.reset_policy_eval_count()
        # TTFT/TPOT read off the engine's repro.obs metrics registry
        # (shard cells label every series per shard, merged in the
        # family aggregate) — no hand-timing around drain()
        obs = ObsConfig(metrics=True).resolve()
        if mode == "single":
            eng = ServingEngine(model, scfg, max_len=max_len,
                                batch_slots=2, obs=obs)
        else:
            eng = ShardedServingEngine(
                model, scfg,
                spec=ShardSpec(dp=dp, sp=sp, slots_per_shard=2),
                max_len=max_len, obs=obs)
        eng.load(params)
        t0 = time.monotonic()
        for r in reqs:
            eng.submit(r)
        outs = eng.drain()
        wall = time.monotonic() - t0
        toks = {c.request_id: c.tokens for c in outs}
        total = sum(len(t) for t in toks.values())
        if mode == "single":
            launches = eng.stats.total_launches
            per_shard = [launches]
            slots = eng.B
        else:
            per_shard = [c.stats.total_launches for c in eng.cores]
            launches = sum(per_shard)
            slots = eng.B
            shard_launches[mode] = per_shard
            if sp > 1:
                plans = {k: e.plan for k, e in
                         eng.cores[0].sched.plans.items()
                         if isinstance(k, int)}
                assert plans and all(
                    p.mesh_splits == sp and p.seq_shard_mesh is not None
                    for p in plans.values()), \
                    "sp decode plans must carry the realized mesh split"
        evals = ops.policy_eval_count()
        mx = obs.metrics_snapshot()["metrics"]
        ttft = mx["ttft_ms"]["aggregate"]
        tpot = mx["tpot_ms"]["aggregate"]
        assert ttft["count"] == len(outs), \
            "every request must have stamped a first token"
        if mode != "single":
            shard_labels = {k for k in mx["ttft_ms"]["series"] if k}
            assert shard_labels == {f"shard={d}" for d in range(dp)}, \
                "sharded cells must label TTFT series per shard"
        token_sets.append(toks)
        rows.append([mode, dp, sp, slots, len(outs), total,
                     round(wall, 2), round(total / max(wall, 1e-9), 1),
                     launches, "/".join(str(x) for x in per_shard),
                     round(ttft["mean"], 1), round(tpot["mean"], 1),
                     evals])

    title = ("mesh-native serving A/B: single vs dp=4 slots vs sp=4 "
             f"seq-sharded decode ({'smoke' if smoke else 'full'}, "
             "8 virtual devices)")
    print_table(header, rows, title)
    write_csv("shard_ab", header, rows, smoke=smoke)

    # structural claims
    assert rows[1][3] == 4 * rows[0][3], \
        "dp=4 must serve 4x the single engine's slots"
    assert all(t == token_sets[0] for t in token_sets), \
        "shard topology changed greedy tokens"
    assert all(r[12] == 0 for r in rows), \
        "policy ran inside a traced step"
    assert all(n > 0 for n in shard_launches["dp4"]), \
        "every dp shard must have admitted + launched work"
    print(f"\nshard A/B: {n_req} requests bit-identical across all "
          f"topologies, dp4 slots = 4x single, per-shard launches "
          f"{shard_launches['dp4']}, sp4 plans carry mesh_splits=4, "
          "policy evals 0")


def main(smoke: bool = False) -> None:
    """run.py entry: always a fresh 8-device process."""
    run_subprocess(smoke=smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale cell sizes (make shard-smoke)")
    ap.add_argument("--inner", action="store_true",
                    help="internal: already running under forced devices")
    args = ap.parse_args()
    if args.inner:
        bench(smoke=args.smoke)
    else:
        run_subprocess(smoke=args.smoke)
