"""Prefix-sharing A/B: ``share_prefix`` on vs off on the paged cache.

Drives two :class:`~repro.serving.ServingEngine`\\ s that differ ONLY in
``ServeConfig.share_prefix`` over the same traffic shape the knob exists
for — N requests opening with one common "system prompt" (spanning
several full pages), each followed by a short unique tail — and reports:

- **TTFT** — submit -> first TOKEN, mean over the FOLLOWER requests
  (the ones whose prompt prefix is already resident when they admit);
  a warmup phase with a *different* system prompt pre-compiles every
  launch shape first, so the timed phase measures launches, not jit;
- **pages allocated** — free-list pops during the timed phase: sharing
  must pay for the common prefix once, not once per request;
- **prefill launches** — ``("prefill", bucket)`` vs
  ``("sprefill", view_bucket, suffix_bucket)`` keys in the plan-cache
  launch counters: the follower admissions must be SUFFIX launches, so
  the full-prefill count for the shared pages is structurally zero.

The *structural* columns are the reproducible claim, asserted below:

- greedy tokens are bit-identical with sharing on vs off (adoption and
  copy-on-write move bytes, never math);
- the shared arm issues exactly ONE full prefill (the leader) and one
  suffix prefill per follower; the unshared arm full-prefills all N;
- the shared arm allocates strictly fewer pages than the unshared arm;
- the split policy never runs inside traced code
  (``ops.policy_eval_count() == 0``);
- :meth:`CacheManager.check_conservation` holds after the run (refcount
  drift, double-free, and trash-page misuse all trip it).

``--smoke`` runs a seconds-scale variant wired into ``make verify``
(``prefix-smoke``) and CI; the follower-TTFT speedup is asserted only in
the full run (CPU-container wall clocks are too noisy at smoke scale).
CSV lands in ``experiments/bench/`` (smoke: the gitignored
``experiments/bench/smoke/``).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.kernels import ops
from repro.models import build_model
from repro.serving import TOKEN, Request, ServingEngine

from benchmarks.common import print_table, write_csv


def _workload(smoke: bool, seed: int = 0):
    rng = np.random.default_rng(seed)
    n_req = 3 if smoke else 6
    system = rng.integers(1, 150, size=100).tolist()
    warm_system = rng.integers(1, 150, size=100).tolist()
    tails = [rng.integers(1, 150, size=4 + (3 * i) % 8).tolist()
             for i in range(n_req)]
    prompts = [system + t for t in tails]
    warm = [warm_system + t for t in tails[:2]]
    return prompts, warm, dict(max_len=256, slots=4, page=32,
                               max_new=4 if smoke else 8)


def _drive(eng, prompts, max_new):
    """Serve ``prompts``, returning (tokens per request, TTFT seconds
    per request, wall seconds)."""
    submit_t, first_t = {}, {}
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=max_new))
        submit_t[i] = time.monotonic()
    t0 = time.monotonic()
    while eng.has_work():
        events = eng.step()
        now = time.monotonic()
        for ev in events:
            if ev.kind == TOKEN and ev.index == 0:
                first_t[ev.request_id] = now
    wall = time.monotonic() - t0
    outs = eng.drain()
    toks = {c.request_id: c.tokens for c in outs}
    ttft = {r: first_t[r] - submit_t[r] for r in first_t}
    return toks, ttft, wall


def _launches(stats, kind):
    return sum(v for k, v in stats.launches.items()
               if isinstance(k, tuple) and k[0] == kind)


def run_cell(model, params, share: bool, prompts, warm, knobs):
    eng = ServingEngine(
        model, ServeConfig(model=model.cfg, cache_layout="paged",
                           cache_page_size=knobs["page"],
                           prefill_bucket=knobs["page"],
                           share_prefix=share),
        max_len=knobs["max_len"], batch_slots=knobs["slots"])
    eng.load(params)
    # warmup: same launch shapes, different system prompt — compiles the
    # (s)prefill and decode steps so the timed phase measures launches
    _drive(eng, warm, knobs["max_new"])
    ops.reset_policy_eval_count()
    base_launches = dict(eng.stats.launches)
    c = eng.cache
    base = (c.pages_allocated_total, c.prefix_hits,
            c.prefix_shared_rows, c.prefix_copies)

    toks, ttft, wall = _drive(eng, prompts, knobs["max_new"])

    delta = {k: v - base_launches.get(k, 0)
             for k, v in eng.stats.launches.items()
             if v > base_launches.get(k, 0)}

    class _D:                                   # launch deltas, stats-like
        launches = delta
    n_tok = sum(len(t) for t in toks.values())
    followers = [r for r in ttft if r != 0]
    pages, hits, rows_shared, copies = (
        v - b for v, b in zip((c.pages_allocated_total, c.prefix_hits,
                               c.prefix_shared_rows, c.prefix_copies),
                              base))
    row = ["shared" if share else "unshared", len(toks), n_tok,
           round(n_tok / max(wall, 1e-9), 1),
           pages, hits, rows_shared, copies,
           _launches(_D, "prefill"), _launches(_D, "sprefill"),
           round(1e3 * float(np.mean([ttft[r] for r in followers])), 1),
           ops.policy_eval_count()]
    eng.cache.check_conservation()
    return row, toks


def main(smoke: bool = False) -> None:
    cfg = reduced_config("qwen2.5-3b", num_layers=2,
                         d_model=32 if smoke else 64)
    assert cfg.num_kv_heads == 1, "A/B needs the MQA low-head-count shape"
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompts, warm, knobs = _workload(smoke)

    header = ["mode", "requests", "tokens", "tok_per_s",
              "pages_allocated", "prefix_hits", "shared_rows",
              "page_copies", "full_prefills", "suffix_prefills",
              "follower_ttft_ms", "policy_evals_in_dispatch"]
    rows, token_sets = [], []
    for share in (True, False):
        row, toks = run_cell(model, params, share, prompts, warm, knobs)
        rows.append(row)
        token_sets.append(toks)
    title = ("prefix A/B: share_prefix on vs off "
             f"({'smoke' if smoke else 'full'}, "
             f"{len(prompts)} requests, one shared system prompt)")
    print_table(header, rows, title)
    write_csv("prefix_ab", header, rows, smoke=smoke)

    shared_row, unshared_row = rows
    n = len(prompts)
    # structural claims (the reproducible part of the A/B)
    assert token_sets[0] == token_sets[1], \
        "prefix sharing changed greedy tokens"
    for row in rows:
        assert row[11] == 0, "policy ran inside a traced step"
    assert shared_row[8] == 1 and shared_row[9] == n - 1, \
        f"shared arm must full-prefill ONLY the leader: {shared_row}"
    assert unshared_row[8] == n and unshared_row[9] == 0, \
        f"unshared arm must full-prefill every request: {unshared_row}"
    assert shared_row[4] < unshared_row[4], \
        "sharing must allocate strictly fewer pages"
    assert shared_row[5] == n - 1, "every follower must hit the trie"
    if not smoke:
        assert shared_row[10] < unshared_row[10], \
            "follower TTFT must improve when the prefix is resident " \
            f"(shared {shared_row[10]} ms vs unshared {unshared_row[10]})"
    print(f"\nprefix A/B: greedy tokens identical, "
          f"{shared_row[4]} vs {unshared_row[4]} pages allocated, "
          f"full prefills {shared_row[8]} vs {unshared_row[8]}, "
          f"{shared_row[6]} prompt rows served from the trie, "
          "conservation + policy-eval counters clean")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale variant (make verify / CI)")
    main(**vars(ap.parse_args()))
