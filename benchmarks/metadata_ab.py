"""Metadata-enabled vs internal-heuristic serving path (paper §5 A/B).

Drives the REAL ``DecodeEngine`` end-to-end on the paper's low-head-count
regime (MQA reduced model, B=1 slot, prompts crossing the L_K = 512
boundary bucket) under each split policy, twice:

- ``metadata`` — plan cache on: one frozen ``SchedulerMetadata`` per
  cache-length bucket, jitted step specialized per plan, policy runs
  zero times inside the traced program.
- ``heuristic`` — plan cache off: one generic step, policy re-evaluated
  at trace time on the padded cache length (the upstream default the
  paper improves on).

Reports steps/s plus the plan-cache counters and the in-dispatch
policy-evaluation count, so the A/B doubles as a living proof that the
metadata path is exercised (benchmarks/tests assert the same counters).
On this CPU container the wall-clock delta is noise; the *structural*
columns (plans, splits frozen per bucket, policy evals = 0) are the
reproducible claim.
"""
from __future__ import annotations

import time

import jax

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.core.scheduler_metadata import metadata_cache_info
from repro.kernels import ops
from repro.models import build_model
from repro.plan import AttentionSpec, Planner
from repro.serving.engine import DecodeEngine, Request

from benchmarks.common import print_table, write_csv

MAX_LEN = 512
PROMPT_LEN = 400            # crosses the 128/256/384/512 buckets
NEW_TOKENS = 16


def _requests():
    prompt = [1 + (i * 7) % 250 for i in range(PROMPT_LEN)]
    return [Request(0, list(prompt), max_new_tokens=NEW_TOKENS)]


def run_cell(model, params, policy: str, use_metadata: bool) -> list:
    scfg = ServeConfig(model=model.cfg, split_policy=policy,
                       use_scheduler_metadata=use_metadata)
    eng = DecodeEngine(model, scfg, max_len=MAX_LEN, batch_slots=1)
    eng.load(params)
    ops.reset_policy_eval_count()
    t0 = time.time()
    out = eng.generate(_requests())
    dt = time.time() - t0
    steps = sum(c.steps for c in out)
    st = eng.stats
    plans = eng.planned_splits()
    return [policy, "metadata" if use_metadata else "heuristic",
            steps, round(steps / dt, 1), st.misses, st.hits,
            ops.policy_eval_count(),
            ";".join(f"{lk}:{s}" for lk, s in sorted(plans.items()))]


def main() -> None:
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    assert cfg.num_kv_heads == 1, "A/B needs the MQA low-head-count shape"
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    header = ["policy", "path", "steps", "steps_per_s", "plan_misses",
              "plan_hits", "policy_evals_in_dispatch",
              "frozen_splits_per_bucket"]
    rows = []
    for policy in ("fa3_baseline", "paper", "tpu_adaptive"):
        for use_md in (True, False):
            rows.append(run_cell(model, params, policy, use_md))
    print_table(header, rows, "metadata-enabled vs internal-heuristic "
                              "decode path (engine end-to-end)")
    write_csv("metadata_ab", header, rows)

    md_rows = [r for r in rows if r[1] == "metadata"]
    assert all(r[6] == 0 for r in md_rows), "policy ran inside a plan step"
    assert any("512:3" in r[7] for r in md_rows), \
        "paper policy should freeze 3 splits for the 512 bucket"

    # plan equivalence: the engine's frozen buckets must match what a
    # standalone Planner produces for the same specs (the engine is just
    # a PlanCache over the public Planner — no second decision path)
    for policy, row in zip(("fa3_baseline", "paper", "tpu_adaptive"),
                           md_rows):
        planner = Planner(policy=policy)
        for cell in filter(None, row[7].split(";")):
            lk, s = map(int, cell.split(":"))
            spec = AttentionSpec.decode(1, lk, cfg.num_heads,
                                        cfg.num_kv_heads,
                                        cfg.resolved_head_dim)
            assert planner.plan(spec).num_splits == s, (policy, lk)
    # explicit-override API (FA3's num_splits argument): the Planner
    # bypasses the policy, clamped per-shape to num_n_blocks
    forced = Planner(num_splits_override=2).plan(
        AttentionSpec.decode(1, 512, cfg.num_heads, cfg.num_kv_heads,
                             cfg.resolved_head_dim))
    assert forced.num_splits == 2

    print("\nmetadata path: policy evals in dispatch = 0 across all "
          "policies; paper freezes 512->3 splits (boundary override); "
          "engine plans == Planner plans")
    print(f"process-wide metadata cache: {metadata_cache_info()}")


if __name__ == "__main__":
    main()
