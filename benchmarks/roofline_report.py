"""Roofline table: aggregate the dry-run JSON records into §Roofline.

Reads experiments/dryrun/<mesh>/<arch>/<shape>.json and emits the
per-cell three-term table (+ dominant term, useful ratio, step-time
lower bound = max of the three terms).
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import print_table, write_csv

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def collect(mesh: str = "16x16"):
    rows = []
    for f in sorted(DRYRUN.glob(f"{mesh}/*/*.json")):
        d = json.loads(f.read_text())
        if d.get("status") != "ok":
            rows.append([d["arch"], d["shape"], d.get("status"),
                         "-", "-", "-", "-", "-", "-",
                         d.get("reason", d.get("error", ""))[:40]])
            continue
        r = d["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append([
            d["arch"], d["shape"], "ok",
            round(r["compute_s"] * 1e3, 2),
            round(r["memory_s"] * 1e3, 2),
            round(r["collective_s"] * 1e3, 2),
            r["dominant"],
            round(r["useful_ratio"], 3),
            round(bound * 1e3, 2),
            "",
        ])
    return rows


def main() -> None:
    for mesh in ("16x16", "2x16x16"):
        rows = collect(mesh)
        if not rows:
            print(f"(no dry-run records for mesh {mesh} — run "
                  f"`python -m repro.launch.dryrun --all`)")
            continue
        header = ["arch", "shape", "status", "compute_ms", "memory_ms",
                  "collective_ms", "dominant", "useful", "bound_ms",
                  "note"]
        print_table(header, rows, f"Roofline ({mesh}, per device, "
                                  f"probe-corrected)")
        write_csv(f"roofline_{mesh}", header, rows)


if __name__ == "__main__":
    main()
