"""Measured-policy A/B: ``measured`` vs ``paper`` vs ``fa3_baseline``.

Two halves, both on the paper's low-head-count decode regime:

1. **Decision sweep** — over the paper grid (H_KV ∈ {1, 2, 4} at
   head_dim 128, B ∈ {1, 8}, L_K crossing the boundary bucket into the
   efficiency-loop regime), compare each policy's split choice and its
   modeled latency.  The committed reference table is the argmin of
   exactly this cost model over ALL feasible splits, so the reproducible
   claim is structural: on covered shapes the measured choice is never
   slower than either analytic policy, and uncovered shapes fall back
   to ``paper`` bit-exactly — and are **counted**
   (``SplitTable.fallbacks`` / ``PlanCacheStats.measured_fallbacks``).
2. **Engine end-to-end** — the real :class:`ServingEngine` on
   ``split_policy="measured"`` vs ``"paper"``: greedy tokens identical,
   split policy evaluated zero times inside traced code, zero fallbacks
   (the reference grid covers the reduced engine's shapes), and the
   ``ServeConfig.stats_path`` JSON snapshot written at drain (the
   counters this benchmark reads instead of re-deriving them).

``--smoke`` is the seconds-scale variant wired into ``make verify``
(``tune-smoke``) and CI.  CSV lands in ``experiments/bench/`` (smoke:
the gitignored ``experiments/bench/smoke/``).
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.core.occupancy import modeled_latency_us
from repro.core.split_policy import DecodeWorkload, choose_num_splits
from repro.kernels import ops
from repro.models import build_model
from repro.plan import AttentionSpec, Planner
from repro.serving import Request, ServingEngine
from repro.tune import REFERENCE_TABLE_PATH, SplitTable

from benchmarks.common import SMOKE_DIR, print_table, write_csv

PAPER_HEADS = ((64, 1), (16, 2), (32, 4))      # paper Table 1 rows
UNCOVERED_HEADS = ((8, 8),)                    # off the reference grid


def sweep(table: SplitTable, smoke: bool):
    lks = (384, 512, 1024) if smoke else (128, 256, 384, 512, 640,
                                          1024, 4096)
    batches = (1,) if smoke else (1, 8)
    cores = table.fingerprint["num_cores"]
    planner = Planner(policy="measured", table=table, num_cores=cores)
    rows = []
    for hq, hkv in PAPER_HEADS + UNCOVERED_HEADS:
        for b in batches:
            for lk in lks:
                w = DecodeWorkload(b, 1, lk, hq, hkv, 128)
                covered = table.covers(w)
                plan = planner.plan(AttentionSpec.from_workload(w))
                splits = {
                    "fa3_baseline": choose_num_splits(
                        w, "fa3_baseline", num_cores=cores),
                    "paper": choose_num_splits(w, "paper",
                                               num_cores=cores),
                    "measured": plan.num_splits,
                }
                lat = {k: modeled_latency_us(w, s, num_cores=cores)
                       for k, s in splits.items()}
                assert plan.tuned == covered
                rows.append([b, lk, hq, hkv, covered,
                             splits["fa3_baseline"], splits["paper"],
                             splits["measured"],
                             round(lat["fa3_baseline"], 2),
                             round(lat["paper"], 2),
                             round(lat["measured"], 2),
                             round(lat["fa3_baseline"] / lat["measured"],
                                   3),
                             round(lat["paper"] / lat["measured"], 3)])
    return rows


def run_engine_cell(model, params, policy: str, table, stats_path):
    eng = ServingEngine(
        model, ServeConfig(model=model.cfg, split_policy=policy,
                           stats_path=stats_path),
        max_len=256, batch_slots=2, tune_table=table)
    eng.load(params)
    ops.reset_policy_eval_count()
    rng_prompts = [[1 + (7 * i + j) % 200 for j in range(4 + 3 * i)]
                   for i in range(4)]
    for i, p in enumerate(rng_prompts):
        eng.submit(Request(i, p, max_new_tokens=8))
    outs = eng.drain()
    return outs, ops.policy_eval_count()


def main(smoke: bool = False) -> None:
    table = SplitTable.load(REFERENCE_TABLE_PATH)
    header = ["batch", "seqlen_k", "hq", "hkv", "covered", "s_fa3",
              "s_paper", "s_measured", "lat_fa3_us", "lat_paper_us",
              "lat_measured_us", "speedup_vs_fa3", "speedup_vs_paper"]
    fallbacks_before = table.fallbacks
    rows = sweep(table, smoke)
    title = (f"tune A/B: measured (table {table.version}) vs analytic "
             f"policies ({'smoke' if smoke else 'full'}, modeled "
             "latency)")
    print_table(header, rows, title)
    write_csv("tune_ab", header, rows, smoke=smoke)

    # structural claims (the reproducible part of the A/B)
    n_uncovered = sum(1 for r in rows if not r[4])
    assert n_uncovered > 0, "sweep must exercise the fallback path"
    assert table.fallbacks - fallbacks_before == n_uncovered, \
        "every uncovered lookup must be counted as a fallback"
    for r in rows:
        if r[4]:                               # covered: never slower
            assert r[10] <= r[8] + 1e-9 and r[10] <= r[9] + 1e-9, \
                f"measured regressed the modeled latency: {r}"
        else:                                  # uncovered: paper, exactly
            assert r[7] == r[6], f"fallback must match paper: {r}"
    best = max(rows, key=lambda r: r[11])

    # engine end-to-end on split_policy="measured"
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    assert cfg.num_kv_heads == 1, "A/B needs the MQA low-head-count shape"
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    SMOKE_DIR.mkdir(parents=True, exist_ok=True)
    toks, snaps = {}, {}
    for policy in ("paper", "measured"):
        stats_path = str(SMOKE_DIR / f"tune_ab_stats_{policy}.json")
        outs, evals = run_engine_cell(
            model, params, policy,
            table if policy == "measured" else None, stats_path)
        assert evals == 0, "policy ran inside a traced step"
        toks[policy] = [c.tokens for c in outs]
        snaps[policy] = json.loads(open(stats_path).read())
    assert toks["measured"] == toks["paper"], \
        "the split policy changed greedy tokens"
    m = snaps["measured"]
    assert m["table_version"] == table.version
    assert m["measured_lookups"] >= 1 and m["measured_fallbacks"] == 0, \
        "reference grid must cover the reduced engine's decode shapes"

    print(f"\ntune A/B: measured never slower on {len(rows) - n_uncovered}"
          f" covered cells (best {best[11]}x vs fa3_baseline at "
          f"B{best[0]} L{best[1]} Hkv{best[3]}); {n_uncovered} uncovered "
          "cells fell back to paper bit-exactly and were counted; engine "
          f"end-to-end: tokens identical, policy evals 0, "
          f"{m['measured_lookups']} table lookups / 0 fallbacks "
          f"(stats snapshots in {SMOKE_DIR})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale variant (make verify / CI)")
    main(**vars(ap.parse_args()))
