"""Speculative-decoding A/B: repro.spec verify steps vs plain decode.

Drives the request-lifecycle :class:`~repro.serving.ServingEngine` on
the paged cache layout over greedy traffic, three cells:

- **baseline** — plain decode (no speculation);
- **ngram**    — the built-in self-speculative n-gram drafter, on
  mixed traffic (repetitive prompts that draft well + incompressible
  prompts that reject everything — the reject-heavy rollback path);
- **oracle**   — a benchmark-registered *replay* drafter that proposes
  the baseline run's own recorded continuation, exercising the
  draft-model extension seam (``register_drafter``) with a drafter
  whose proposals always verify — the acceptance upper bound.

Wall-clock deltas on this CPU container are noisy; the *structural*
columns are the reproducible claim, asserted below:

- greedy tokens are bit-identical with speculation on and off (the
  acceptance rule only ever commits what sequential argmax would have
  emitted);
- the oracle cell's acceptance rate is ~1 and its effective
  tokens-per-verify-step is > 1 (``PlanCacheStats`` spec counters) —
  speculation collapses decode launches by the same factor;
- verify launches are *planned*: every one lands under a
  ``("verify", k, bucket)`` plan-cache key and the split policy never
  runs inside traced code (``ops.policy_eval_count() == 0``);
- page conservation holds after the reject-heavy ngram cell —
  accept-masked commits plus ``kv_len`` rollback never leak or alias a
  page (``CacheManager.check_conservation``).

``--smoke`` runs a seconds-scale variant wired into ``make verify`` and
CI.  CSV lands in ``experiments/bench/`` (smoke runs: the gitignored
``experiments/bench/smoke/``).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.kernels import ops
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServingEngine
from repro.spec import Drafter, SpecConfig, register_drafter

from benchmarks.common import print_table, write_csv


class ReplayDrafter(Drafter):
    """Oracle replay: proposes a previously recorded continuation.

    Stands in for a draft model that happens to be perfect — same
    ``propose(history, k)`` contract, registered under a new name, zero
    engine changes.  ``script`` maps each request's prompt (as a tuple)
    to the token stream a reference run emitted for it.
    """

    script: Dict[Tuple[int, ...], List[int]] = {}

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        h = tuple(history)
        for prompt, toks in self.script.items():
            if h[:len(prompt)] == prompt:
                done = len(h) - len(prompt)
                return list(toks[done:done + k])
        return []


register_drafter("replay", ReplayDrafter)


def _workload(smoke: bool, vocab: int, seed: int = 0):
    """Mixed prompts: half repetitive (n-gram drafts verify), half
    incompressible random (every draft rejects)."""
    rng = np.random.default_rng(seed)
    if smoke:
        num, max_new, max_len, slots = 4, 8, 128, 2
    else:
        num, max_new, max_len, slots = 8, 32, 256, 4
    prompts = []
    for i in range(num):
        if i % 2 == 0:
            period = rng.integers(2, 5)
            motif = rng.integers(1, vocab, size=period).tolist()
            n = int(rng.integers(8, 16))
            prompts.append((motif * n)[:n])
        else:
            prompts.append(rng.integers(1, vocab,
                                        size=rng.integers(6, 14)).tolist())
    return prompts, dict(max_new=max_new, max_len=max_len, slots=slots)


def run_cell(model, params, name: str, spec: Optional[SpecConfig],
             prompts, knobs):
    eng = ServingEngine(
        model, ServeConfig(model=model.cfg, cache_layout="paged"),
        max_len=knobs["max_len"], batch_slots=knobs["slots"])
    eng.load(params)

    def one_pass(base_id: int):
        for i, p in enumerate(prompts):
            eng.submit(Request(base_id + i, p,
                               max_new_tokens=knobs["max_new"],
                               sampling=SamplingParams(speculation=spec)))
        return eng.drain()

    # warmup pass: populate the plan cache and compile every (plan,
    # step) specialization the workload needs, so the timed pass
    # measures steady-state launches — on this CPU container one XLA
    # compile costs more than the whole decode, and the baseline cell
    # compiles 2 programs where speculation compiles one per
    # ("verify", k, bucket) key
    one_pass(0)
    eng.stats.reset()
    ops.reset_policy_eval_count()
    t0 = time.monotonic()
    outs = one_pass(len(prompts))
    dt = time.monotonic() - t0
    eng.cache.check_conservation()

    st = eng.stats.to_json()
    n_dec = sum(v for k, v in st["launches"].items() if k.isdigit())
    n_ver = sum(v for k, v in st["launches"].items()
                if k.startswith("verify/"))
    n_tok = sum(len(c.tokens) for c in outs)
    row = [name, len(outs), n_tok, n_dec, n_ver, st["spec_steps"],
           st["spec_proposed"], st["spec_accepted"],
           st["spec_acceptance_rate"], st["spec_tokens_per_step"],
           round(1e3 * dt / max(1, n_tok), 2),
           ops.policy_eval_count()]
    return row, [c.tokens for c in outs], eng


def main(smoke: bool = False) -> None:
    cfg = reduced_config("qwen2.5-3b", num_layers=2,
                         d_model=32 if smoke else 64)
    assert cfg.num_kv_heads == 1, "A/B needs the MQA low-head-count shape"
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompts, knobs = _workload(smoke, cfg.vocab_size)
    k = 3 if smoke else 4

    rows, token_sets = [], []
    base_row, base_toks, _ = run_cell(model, params, "baseline", None,
                                      prompts, knobs)
    rows.append(base_row)
    token_sets.append(base_toks)

    ng_row, ng_toks, ng_eng = run_cell(
        model, params, "ngram", SpecConfig(method="ngram", k=k),
        prompts, knobs)
    rows.append(ng_row)
    token_sets.append(ng_toks)

    # oracle: replay the baseline's own output as the draft stream
    ReplayDrafter.script = {tuple(p): t
                            for p, t in zip(prompts, base_toks)}
    or_row, or_toks, or_eng = run_cell(
        model, params, "oracle", SpecConfig(method="replay", k=k),
        prompts, knobs)
    rows.append(or_row)
    token_sets.append(or_toks)

    header = ["cell", "requests", "tokens", "decode_launches",
              "verify_launches", "verify_slot_steps", "drafts_proposed",
              "drafts_accepted", "acceptance_rate", "tokens_per_step",
              "tpot_ms_mean", "policy_evals_in_dispatch"]
    title = ("speculative decoding A/B: verify steps vs plain decode "
             f"({'smoke' if smoke else 'full'}, paged layout, k={k})")
    print_table(header, rows, title)
    write_csv("spec_ab", header, rows, smoke=smoke)

    # structural claims (the reproducible part of the A/B)
    for row in rows:
        assert row[11] == 0, "policy ran inside a traced step"
    assert all(t == token_sets[0] for t in token_sets), \
        "speculation changed greedy tokens"
    assert or_row[8] > 0.9, \
        f"oracle drafts must (almost) all verify, got {or_row[8]}"
    assert or_row[9] > 1.0, \
        "oracle speculation must emit > 1 token per verify step"
    assert or_row[3] + or_row[4] < base_row[3], \
        "speculation must collapse decode-lockstep launches"
    assert or_eng.sched.planned_verify_keys(), \
        "verify launches must be planned under ('verify', k, bucket) keys"
    assert ng_row[7] < ng_row[6], \
        "mixed traffic must exercise the reject/rollback path"
    if not smoke:
        assert or_row[10] < base_row[10], \
            "oracle speculation must improve mean TPOT"
    print("\nspec A/B: greedy tokens bit-identical across all cells; "
          f"oracle acceptance {or_row[8]:.2f}, {or_row[9]:.2f} "
          f"tokens/verify-step over {or_row[4]} planned verify launches "
          f"(keys {or_eng.sched.planned_verify_keys()}), page "
          "conservation holds after the reject-heavy ngram cell, "
          "policy evals in dispatch = 0")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale variant (make verify / CI)")
    main(**vars(ap.parse_args()))
