"""Cache-layout A/B: DenseLayout vs PagedKVCache under mixed prompts.

Drives two :class:`~repro.serving.ServingEngine`\\ s that differ ONLY in
``ServeConfig.cache_layout`` over the same mixed-prompt-length workload
(short context next to near-capacity context — the shape the paper's
sequence-aware split policy exists for) and reports:

- **tokens/s** — end-to-end decode throughput (wall clock; noisy on
  this CPU container, recorded for trend only);
- **cache HBM bytes** — what the layout actually allocates
  (``CacheLayout.storage_bytes``) vs the dense-equivalent baseline;
- **attended KB/step** — K/V bytes one decode launch streams at the
  workload's resident view (``CacheLayout.attended_bytes``): dense
  always streams the padded ``max_len``, paged streams the
  resident-length bucket;
- **admit_ms** — admission latency (submit -> first TOKEN, includes the
  planned prefill launch and, for paged, page allocation).

The *structural* columns are the reproducible claim, asserted below:

- greedy tokens are bit-identical across layouts (the layout moves
  bytes, never math);
- the split policy never runs inside traced code
  (``ops.policy_eval_count() == 0``);
- decode plans are keyed on RESIDENT-length buckets (short-context
  steps plan on small buckets; the padded ``max_len`` bucket appears
  only once the longest request actually grows into it);
- under a constrained ``cache_page_budget`` the paged pool allocates
  strictly fewer cache bytes than dense while serving the same traffic.

``--smoke`` runs a seconds-scale variant wired into ``make verify``
(``cache-smoke``) and CI.  CSV lands in ``experiments/bench/`` (smoke
runs: the gitignored ``experiments/bench/smoke/``).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.kernels import ops
from repro.models import build_model
from repro.serving import TOKEN, Request, ServingEngine

from benchmarks.common import print_table, write_csv


def _workload(smoke: bool, seed: int = 0):
    rng = np.random.default_rng(seed)
    # a LONG-capacity engine serving SHORTER mixed traffic — the shape
    # the paper's split policy (and the paged layout) exist for: dense
    # pays max_len per slot per step, paged pays the resident bucket
    if smoke:
        max_len, slots, max_new = 512, 2, 4
        lens = [5, 40, 150, 7, 200]
    else:
        max_len, slots, max_new = 1024, 4, 16
        lens = rng.integers(8, 460, size=12).tolist()
    prompts = [rng.integers(1, 200, size=n).tolist() for n in lens]
    return prompts, dict(max_len=max_len, slots=slots, max_new=max_new,
                         page=64)


def run_cell(model, params, layout: str, prompts, knobs,
             page_budget=None):
    eng = ServingEngine(
        model, ServeConfig(model=model.cfg, cache_layout=layout,
                           cache_page_size=knobs["page"],
                           cache_page_budget=page_budget),
        max_len=knobs["max_len"], batch_slots=knobs["slots"])
    eng.load(params)
    ops.reset_policy_eval_count()

    submit_t, first_t = {}, {}
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=knobs["max_new"]))
        submit_t[i] = time.monotonic()
    t0 = time.monotonic()
    while eng.has_work():
        now_events = eng.step()
        now = time.monotonic()
        for ev in now_events:
            if ev.kind == TOKEN and ev.index == 0:
                first_t[ev.request_id] = now
    wall = time.monotonic() - t0
    outs = eng.drain()

    n_tok = sum(len(c.tokens) for c in outs)
    admit = [first_t[r] - submit_t[r] for r in first_t]
    lay = eng.cache.layout
    resident = max(len(p) for p in prompts) + knobs["max_new"]
    bucket = eng.sched.decode_bucket(resident - 1)
    row = [layout, len(outs), n_tok,
           round(n_tok / max(wall, 1e-9), 1),
           lay.storage_bytes(), lay.dense_bytes(),
           round(lay.attended_bytes(bucket) / 1024, 1),
           round(1e3 * float(np.mean(admit)), 1),
           sorted(eng.planned_splits()),
           ops.policy_eval_count()]
    return row, [c.tokens for c in outs], eng


def main(smoke: bool = False) -> None:
    cfg = reduced_config("qwen2.5-3b", num_layers=2,
                         d_model=32 if smoke else 64)
    assert cfg.num_kv_heads == 1, "A/B needs the MQA low-head-count shape"
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompts, knobs = _workload(smoke)

    header = ["layout", "requests", "tokens", "tok_per_s",
              "cache_bytes", "dense_equiv_bytes", "attended_kb_step",
              "admit_ms_mean", "decode_plan_buckets",
              "policy_evals_in_dispatch"]
    rows, token_sets, engines = [], [], []
    # paged page budget sized to the worst-case CONCURRENT residency
    # (the `slots` largest requests all resident at once, page-rounded)
    # — strictly under the dense engine's slots * max_len capacity
    spec = model.cache_spec(1, knobs["max_len"], layout="paged",
                            page_size=knobs["page"])
    needs = sorted((spec.pages_for(len(p) + knobs["max_new"])
                    for p in prompts), reverse=True)
    budget = sum(needs[:knobs["slots"]])
    for layout, kw in (("dense", {}), ("paged", dict(page_budget=budget))):
        row, toks, eng = run_cell(model, params, layout, prompts, knobs,
                                  **kw)
        rows.append(row)
        token_sets.append(toks)
        engines.append(eng)
    title = ("cache A/B: DenseLayout vs PagedKVCache "
             f"({'smoke' if smoke else 'full'}, mixed prompt lengths)")
    print_table(header, rows, title)
    write_csv("cache_ab", header, rows, smoke=smoke)

    # structural claims (the reproducible part of the A/B)
    assert token_sets[0] == token_sets[1], \
        "cache layout changed greedy tokens"
    for row in rows:
        assert row[9] == 0, "policy ran inside a traced step"
    dense_row, paged_row = rows
    assert paged_row[4] < dense_row[4], \
        "budgeted paged pool must allocate less than dense capacity"
    assert paged_row[6] < dense_row[6], \
        "paged decode must stream less K/V than the padded dense launch"
    # resident-length keying: mixed-length traffic plans on SMALL
    # buckets first; the near-capacity bucket shows up only as the
    # longest request grows into it
    buckets = paged_row[8]
    assert buckets and buckets[0] < knobs["max_len"], \
        f"expected a sub-capacity resident bucket, got {buckets}"
    pstats = engines[1].cache_stats()
    print(f"\ncache A/B: greedy tokens identical, paged pool "
          f"{paged_row[4]} B vs dense {dense_row[4]} B "
          f"({pstats['total_pages']} pages of {pstats['page_size']}), "
          f"decode plans keyed on resident buckets {buckets}, "
          "policy evals in dispatch = 0")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale variant (make verify / CI)")
    main(**vars(ap.parse_args()))
