"""Paper Fig. 3: extended split sweep s = 1..64 (B=1, L_K=512, H_KV=1).

Modeled on the calibrated H100 cost model AND on the TPU v5e model at
mesh scale (chips as cores) — the structure the paper reports (steep
drop after s=1, broad plateau, shallow minima) must appear in both.

Each forced split count goes through ``Planner(num_splits_override=s)``
— the same explicit-override path (FA3's ``num_splits`` argument)
production callers use — so the sweep exercises the public planning API,
not a side channel.  The planner clamps overrides to ``num_n_blocks``
(L_K=512 -> 4 blocks), so s > 4 collapses onto the s=4 plan: the modeled
plateau beyond the knee is exactly the clamp's flat region.
"""
from __future__ import annotations

from repro.core.occupancy import H100_SXM, TPU_V5E, modeled_latency_us
from repro.plan import AttentionSpec, Planner

from benchmarks.common import print_table, write_csv

SPEC = AttentionSpec.decode(1, 512, 64, 1, 128)


def sweep(hw, num_cores):
    out = {}
    for s in range(1, 65):
        plan = Planner(num_cores=num_cores,
                       num_splits_override=s).plan(SPEC)
        # model the REQUESTED split so the full U-curve is visible; the
        # frozen plan's (clamped) count is what a launch would use
        out[s] = modeled_latency_us(plan.spec.workload(), s, hw=hw,
                                    num_cores=num_cores)
    return out


def main() -> None:
    h100 = sweep(H100_SXM, 132)
    tpu = sweep(TPU_V5E, 16)           # v5e-16 serving slice
    header = ["s", "h100_us", "tpu16_us"]
    rows = [[s, round(h100[s], 2), round(tpu[s], 2)]
            for s in sorted(h100)]
    write_csv("u_curve_sweep", header, rows)
    print_table(header, rows[:12] + [["...", "...", "..."]] + rows[-4:],
                "Fig. 3 split sweep (modeled)")

    # structural assertions (the figure's described shape)
    t1, t3 = h100[1], h100[3]
    plateau = [h100[s] for s in range(3, 65)]
    assert t3 < t1, "splitting must win at the boundary"
    assert max(plateau) < t1, "plateau stays below the unsplit latency"
    spread = (max(plateau) - min(plateau)) / min(plateau)
    print(f"\nh100: s=1 {t1:.2f}us -> s=3 {t3:.2f}us "
          f"(x{t1/t3:.2f}); plateau spread {spread*100:.1f}% "
          f"(paper: gain s=3->best < ~2%)")
    best = min(plateau)
    print(f"gain s=3 -> best: {(t3-best)/t3*100:.1f}%")


if __name__ == "__main__":
    main()
