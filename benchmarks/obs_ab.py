"""Observability A/B: tracing on vs off must not change the serving.

Two cells serve the SAME greedy request stream on the reduced
qwen2.5-3b engine (fused admission, paper policy):

- ``off`` — the zero-cost default (``NULL_OBSERVER``: engines branch on
  ``enabled`` and allocate nothing per step);
- ``on``  — full repro.obs: Chrome trace-event timeline + metrics
  registry, dumped at drain through ``ServeConfig.trace_path`` /
  ``metrics_path`` (the artifacts land in the gitignored smoke dir —
  they are run outputs, not tables).

Structural claims (the reproducible part, asserted below):

- greedy tokens and PlanCacheStats are BIT-IDENTICAL across the two
  cells — observation never changes the schedule or the math;
- zero policy evaluations inside traced code in both cells (the
  observer is strictly host-side);
- the dumped trace is schema-valid Chrome JSON
  (:func:`repro.obs.validate_trace`): per-request lifecycle spans
  (queue_wait -> admit -> steps, nested under one ``request`` span) and
  per-launch spans each stamped with full LaunchPlan provenance
  (``num_splits`` / ``mesh_splits`` / ``kv_dtype`` / ``table_version``);
- the metrics snapshot's TTFT/TPOT histograms cover every request —
  the same numbers ``serving_ab``'s columns now read.

Load the trace at https://ui.perfetto.dev.

    PYTHONPATH=src python -m benchmarks.obs_ab [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.kernels import ops
from repro.models import build_model
from repro.obs import validate_trace
from repro.serving import Request, ServingEngine

from benchmarks.common import SMOKE_DIR, print_table, write_csv

# provenance keys every launch span must carry (the plan-cache key plus
# the frozen split decision and its inputs)
PROVENANCE_KEYS = ("key", "num_splits", "mesh_splits", "kv_dtype",
                   "table_version", "tuned", "policy")


def _requests(cfg, n_req: int, max_new: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(4, 20))).tolist(),
                    max_new_tokens=max_new)
            for i in range(n_req)]


def run_cell(model, params, reqs, *, max_len: int, slots: int,
             trace_path=None, metrics_path=None):
    scfg = ServeConfig(model=model.cfg, split_policy="paper",
                       prefill_mode="fused",
                       trace_path=trace_path, metrics_path=metrics_path)
    eng = ServingEngine(model, scfg, max_len=max_len, batch_slots=slots)
    eng.load(params)
    ops.reset_policy_eval_count()
    t0 = time.monotonic()
    for r in reqs:
        eng.submit(r)
    outs = eng.drain()
    wall = time.monotonic() - t0
    return eng, outs, wall, ops.policy_eval_count()


def main(smoke: bool = False) -> None:
    cfg = reduced_config("qwen2.5-3b", num_layers=2,
                         d_model=32 if smoke else 64)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    n_req, max_new = (5, 6) if smoke else (12, 16)
    max_len, slots = 256, 2
    reqs = _requests(cfg, n_req, max_new)

    # artifacts are run outputs (never committed): always the smoke dir
    art = SMOKE_DIR / "obs"
    trace_path = str(art / "trace.json")
    metrics_path = str(art / "metrics.json")

    cells = [("off", None, None), ("on", trace_path, metrics_path)]
    header = ["obs", "requests", "tokens", "wall_s", "trace_events",
              "request_spans", "launch_spans", "ttft_ms_mean",
              "tpot_ms_mean", "policy_evals"]
    rows, token_sets, stat_sets = [], [], []
    for mode, tp, mp in cells:
        eng, outs, wall, evals = run_cell(
            model, params, reqs, max_len=max_len, slots=slots,
            trace_path=tp, metrics_path=mp)
        token_sets.append([c.tokens for c in outs])
        stat_sets.append(eng.stats.to_json())
        total = sum(len(c.tokens) for c in outs)
        if mode == "off":
            rows.append([mode, len(outs), total, round(wall, 2),
                         0, 0, 0, "-", "-", evals])
            continue

        with open(trace_path) as f:
            trace = json.load(f)
        validate_trace(trace)           # schema + span-nesting gate
        evs = trace["traceEvents"]
        req_spans = [e for e in evs
                     if e["ph"] == "X" and e["name"] == "request"]
        launch_spans = [e for e in evs
                        if e["ph"] == "X" and e.get("cat") == "launch"]
        assert len(req_spans) == n_req, \
            "one request span per served request"
        assert launch_spans, "no launch spans recorded"
        for sp in launch_spans:
            missing = [k for k in PROVENANCE_KEYS
                       if k not in sp.get("args", {})]
            assert not missing, \
                f"launch span missing provenance {missing}"
        kinds = {sp["name"] for sp in launch_spans}
        assert {"prefill", "decode"} <= kinds, \
            f"expected prefill+decode launch spans, got {kinds}"
        # every request track carries the full lifecycle taxonomy
        names = {e["name"] for e in evs if e["ph"] == "X"}
        assert {"queue_wait", "admit"} <= names

        with open(metrics_path) as f:
            snap = json.load(f)
        mx = snap["metrics"]
        ttft = mx["ttft_ms"]["aggregate"]
        tpot = mx["tpot_ms"]["aggregate"]
        assert ttft["count"] == n_req, "TTFT must cover every request"
        assert mx["tokens_total"]["aggregate"] == total
        assert snap["plan_cache"]["launches"] == \
            stat_sets[-1]["launches"], \
            "metrics snapshot must absorb PlanCacheStats verbatim"
        rows.append([mode, len(outs), total, round(wall, 2),
                     len(evs), len(req_spans), len(launch_spans),
                     round(ttft["mean"], 1), round(tpot["mean"], 1),
                     evals])

    title = ("observability A/B: tracing on vs off "
             f"({'smoke' if smoke else 'full'})")
    print_table(header, rows, title)
    write_csv("obs_ab", header, rows, smoke=smoke)

    # structural claims
    assert token_sets[0] == token_sets[1], \
        "tracing changed the greedy token stream"
    assert stat_sets[0] == stat_sets[1], \
        "tracing changed the PlanCacheStats counters"
    assert all(r[9] == 0 for r in rows), \
        "policy ran inside a traced step"
    print(f"\nobs A/B: {n_req} requests bit-identical with tracing "
          f"on/off, schema-valid trace ({rows[1][4]} events, "
          f"{rows[1][5]} request spans over {rows[1][6]} launch spans, "
          "all provenance-stamped), policy evals 0\n"
          f"trace artifact: {trace_path} (https://ui.perfetto.dev)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale variant (make verify / CI)")
    main(**vars(ap.parse_args()))
