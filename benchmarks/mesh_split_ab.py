"""Mesh-level policy A/B: the paper's Table 1, lifted to the pod.

For a SHORT-cache batched decode (the paper's chat regime: L_K = 512)
on the 16x16 production mesh, build the serve step under each policy and
compare the compiled programs: the mesh split decision, the collective
schedule, and the modeled per-step bound.  This is the deployment-level
consequence of the heuristic — fa3_baseline leaves the model axis
starved exactly like it left H100 SMs idle.

Run separately (needs 512 virtual devices, ~1 min):

    PYTHONPATH=src python -m benchmarks.mesh_split_ab
"""
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

from benchmarks.common import print_table, write_csv


def main() -> None:
    import jax  # after the flag

    from repro.configs import get_arch
    from repro.configs.base import ServeConfig, ShapeConfig
    from repro.launch.mesh import make_production_mesh
    from repro.models.registry import build_model
    from repro.plan import AttentionSpec, Planner
    from repro.roofline.analysis import HBM_BW, ICI_LINK_BW
    from repro.roofline.hlo import collective_bytes, wire_bytes
    from repro.roofline.probe import analytic_memory_bytes
    from repro.serving.decode_step import build_serve_step

    mesh = make_production_mesh()
    # the paper's boundary bucket, batched for serving: each data-shard
    # replica decodes with a 512-token cache; H_KV=2 (qwen2.5-3b) is the
    # Table-1 H_KV=2 row
    shape = ShapeConfig("decode_512", 512, 128, "decode")
    cfg = get_arch("qwen2.5-3b")
    model = build_model(cfg)

    rows = []
    for policy in ("fa3_baseline", "paper", "tpu_adaptive"):
        scfg = ServeConfig(model=cfg, shape=shape, split_policy=policy)
        bundle = build_serve_step(model, scfg, mesh)
        compiled = bundle.step.lower(*bundle.abstract_args()).compile()
        coll = collective_bytes(compiled.as_text())
        # layer-scan body counted once -> scale by layer count
        wire = wire_bytes(coll) * cfg.num_layers
        mem = analytic_memory_bytes(cfg, shape, mesh, microbatches=1,
                                    kind="decode",
                                    seq_split=bundle.mesh_splits > 1)
        # the KERNEL-level plan for the same shape (per-chip split count)
        md = Planner(policy=policy).plan(
            AttentionSpec.decode(1, 512, cfg.num_heads, cfg.num_kv_heads,
                                 cfg.resolved_head_dim))
        rows.append([policy, bundle.mesh_splits, md.num_splits,
                     round(wire / 2**20, 1),
                     round(wire / ICI_LINK_BW * 1e3, 3),
                     round(mem / HBM_BW * 1e3, 3)])

    header = ["policy", "mesh_splits", "kernel_splits", "wire_MiB/step",
              "collective_ms", "memory_ms"]
    print_table(header, rows, "mesh + kernel policy A/B "
                "(decode, L_K=512, H_KV=2, B=128, 16x16 mesh)")
    write_csv("mesh_split_ab", header, rows)
    by = {r[0]: r for r in rows}
    # FINDING (documented in EXPERIMENTS.md): at pod scale the STORAGE
    # constraint already forces sequence-sharding for every kv < axis
    # arch — head-sharding cannot even represent the cache — so the mesh
    # decision converges across policies.  The policies still diverge at
    # the KERNEL level (the Pallas split count below), which is exactly
    # the paper's original scope.
    assert by["fa3_baseline"][1] == by["paper"][1] == 16
    assert by["fa3_baseline"][2] == 1, "kernel baseline: static guard"
    assert by["paper"][2] == 3, "kernel paper policy: boundary override"


if __name__ == "__main__":
    main()
