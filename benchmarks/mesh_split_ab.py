"""Mesh-level policy A/B: the paper's Table 1, lifted to the pod.

For a SHORT-cache batched decode (the paper's chat regime: L_K = 512)
on the 16x16 production mesh, build the serve step under each policy and
compare the compiled programs: the mesh split decision, the collective
schedule, and the modeled per-step bound.  This is the deployment-level
consequence of the heuristic — fa3_baseline leaves the model axis
starved exactly like it left H100 SMs idle.

``--smoke`` runs the same three-policy compile-and-compare on a 4x4
mesh (16 virtual devices) with the reduced arch — seconds, CI-sized —
asserting only the mesh-independent structure (storage-forced sequence
sharding, the kernel baseline's static guard).

The benchmark always re-execs itself with ``XLA_FLAGS`` forcing the
device count (jax freezes device flags at first import, so the caller's
process — e.g. ``benchmarks.run`` — can never host it):

    PYTHONPATH=src python -m benchmarks.mesh_split_ab [--smoke]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(smoke: bool = False) -> None:
    """Re-exec under the forced device count (512 full, 16 smoke)."""
    env = dict(os.environ)
    n = 16 if smoke else 512
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"), _ROOT,
                    env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, "-m", "benchmarks.mesh_split_ab", "--inner"]
    if smoke:
        cmd.append("--smoke")
    subprocess.run(cmd, check=True, env=env, cwd=_ROOT)


def bench(smoke: bool = False) -> None:
    import jax  # noqa: F401  (inside the forced-device process)

    from benchmarks.common import print_table, write_csv
    from repro.configs import get_arch
    from repro.configs.base import ServeConfig, ShapeConfig
    from repro.configs.reduced import reduced_config
    from repro.launch.mesh import make_production_mesh
    from repro.compat import make_mesh
    from repro.models.registry import build_model
    from repro.plan import AttentionSpec, Planner
    from repro.roofline.analysis import HBM_BW, ICI_LINK_BW
    from repro.roofline.hlo import collective_bytes, wire_bytes
    from repro.roofline.probe import analytic_memory_bytes
    from repro.serving.decode_step import build_mesh_decode_step

    if smoke:
        mesh = make_mesh((4, 4), ("data", "model"))
        cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=64)
        batch = 8
    else:
        mesh = make_production_mesh()
        cfg = get_arch("qwen2.5-3b")
        batch = 128
    # the paper's boundary bucket, batched for serving: each data-shard
    # replica decodes with a 512-token cache; H_KV=2 (qwen2.5-3b) is the
    # Table-1 H_KV=2 row (the reduced arch keeps the GQA ratio: H_KV=1)
    shape = ShapeConfig("decode_512", 512, batch, "decode")
    model = build_model(cfg)
    axis = mesh.shape["model"]

    rows = []
    for policy in ("fa3_baseline", "paper", "tpu_adaptive"):
        scfg = ServeConfig(model=cfg, shape=shape, split_policy=policy)
        bundle = build_mesh_decode_step(model, scfg, mesh)
        compiled = bundle.step.lower(*bundle.abstract_args()).compile()
        coll = collective_bytes(compiled.as_text())
        # layer-scan body counted once -> scale by layer count
        wire = wire_bytes(coll) * cfg.num_layers
        mem = analytic_memory_bytes(cfg, shape, mesh, microbatches=1,
                                    kind="decode",
                                    seq_split=bundle.mesh_splits > 1)
        # the KERNEL-level plan for the same shape (per-chip split count)
        md = Planner(policy=policy).plan(
            AttentionSpec.decode(1, 512, cfg.num_heads, cfg.num_kv_heads,
                                 cfg.resolved_head_dim))
        rows.append([policy, bundle.mesh_splits, md.num_splits,
                     round(wire / 2**20, 1),
                     round(wire / ICI_LINK_BW * 1e3, 3),
                     round(mem / HBM_BW * 1e3, 3)])

    header = ["policy", "mesh_splits", "kernel_splits", "wire_MiB/step",
              "collective_ms", "memory_ms"]
    print_table(header, rows, "mesh + kernel policy A/B (decode, "
                f"L_K=512, H_KV={cfg.num_kv_heads}, B={batch}, "
                f"{mesh.shape['data']}x{axis} mesh"
                f"{', smoke' if smoke else ''})")
    write_csv("mesh_split_ab", header, rows, smoke=smoke)
    by = {r[0]: r for r in rows}
    # FINDING (documented in EXPERIMENTS.md): at pod scale the STORAGE
    # constraint already forces sequence-sharding for every kv < axis
    # arch — head-sharding cannot even represent the cache — so the mesh
    # decision converges across policies.  The policies still diverge at
    # the KERNEL level (the Pallas split count below), which is exactly
    # the paper's original scope.
    assert by["fa3_baseline"][1] == by["paper"][1] == axis
    assert by["fa3_baseline"][2] == 1, "kernel baseline: static guard"
    if not smoke:
        assert by["paper"][2] == 3, "kernel paper policy: boundary override"


def main(smoke: bool = False) -> None:
    """run.py entry: always a fresh forced-device process."""
    run_subprocess(smoke=smoke)


def smoke_main() -> None:
    """run.py entry for the CI-sized cell (16 devices, seconds)."""
    run_subprocess(smoke=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="4x4 mesh, reduced arch, seconds-scale")
    ap.add_argument("--inner", action="store_true",
                    help="internal: already running under forced devices")
    args = ap.parse_args()
    if args.inner:
        bench(smoke=args.smoke)
    else:
        run_subprocess(smoke=args.smoke)
