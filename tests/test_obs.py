"""repro.obs: metrics registry / tracer / validate_trace units, the
Observer lifecycle on an injectable fake clock, atomic artifact writes,
deterministic engine traces, the tracing-on/off bit-identity property
(dense/paged x single/dp-sharded), warning-once regressions, and
(multidevice tier) dp=2 artifact parity in an 8-device subprocess."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

from _hyp_compat import given, settings, strategies as st
from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.kernels import ops
from repro.models import build_model
from repro.obs import (
    NULL_OBSERVER,
    MetricsRegistry,
    ObsConfig,
    TraceArtifact,
    Tracer,
    atomic_write_json,
    atomic_write_text,
    plan_provenance,
    validate_trace,
)
from repro.obs.metrics import _percentile
from repro.plan import AttentionSpec, Planner
from repro.serving import Request, ServingEngine
from repro.shard import ShardSpec, ShardedServingEngine, \
    clear_shard_plan_caches
from repro.tune.table import REFERENCE_TABLE_PATH

REPO = Path(__file__).resolve().parents[1]


class FakeClock:
    """Deterministic monotonic clock: +1 ms per reading."""

    def __init__(self, step: float = 1e-3):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(autouse=True)
def _fresh_registry():
    clear_shard_plan_caches()
    yield
    clear_shard_plan_caches()


def _reqs(cfg, n, seed=0, max_new=4, lo=3, hi=9):
    import numpy as np
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(1, cfg.vocab_size,
                                    size=int(rng.integers(lo, hi))).tolist(),
                    max_new_tokens=max_new) for i in range(n)]


# ---------------------------------------------------------------------------
# metrics: instruments, families, registry, prometheus exposition
# ---------------------------------------------------------------------------


def test_percentile_matches_numpy_interpolation():
    import numpy as np
    samples = [5.0, 1.0, 9.0, 3.0, 7.0, 2.0]
    for q in (0.0, 0.25, 0.5, 0.9, 1.0):
        assert _percentile(samples, q) == pytest.approx(
            float(np.percentile(samples, 100 * q)))
    assert _percentile([], 0.5) == 0.0


def test_histogram_snapshot_counts_sum_and_cumulative_buckets():
    m = MetricsRegistry()
    h = m.histogram("lat_ms", "latency", buckets=(1.0, 10.0, 100.0))
    for x in (0.5, 5.0, 5.0, 50.0, 5000.0):
        h.observe(x)
    s = m.snapshot()["lat_ms"]
    assert s["kind"] == "histogram"
    agg = s["aggregate"]
    assert agg["count"] == 5
    assert agg["sum"] == pytest.approx(5060.5)
    assert agg["min"] == 0.5 and agg["max"] == 5000.0
    # cumulative per upper bound, +Inf catches the tail
    assert agg["buckets"] == {"1": 1, "10": 3, "100": 4, "+Inf": 5}
    assert agg["p50"] == pytest.approx(5.0)


def test_registry_memoizes_families_and_rejects_kind_mismatch():
    m = MetricsRegistry()
    assert m.counter("a", "one") is m.counter("a")
    with pytest.raises(ValueError, match="already registered"):
        m.gauge("a")


def test_family_label_series_and_aggregate_merge():
    m = MetricsRegistry()
    c = m.counter("launches_total", "launches")
    c.inc(3, shard="0")
    c.inc(4, shard="1")
    snap = m.snapshot()["launches_total"]
    assert snap["series"] == {"shard=0": 3, "shard=1": 4}
    assert snap["aggregate"] == 7
    h = m.histogram("t_ms", "t", buckets=(10.0,))
    h.observe(1.0, shard="0")
    h.observe(100.0, shard="1")
    agg = m.snapshot()["t_ms"]["aggregate"]
    assert agg["count"] == 2 and agg["buckets"] == {"10": 1, "+Inf": 2}


def test_prometheus_text_exposition_format():
    m = MetricsRegistry()
    m.counter("tokens_total", "tokens").inc(5)
    m.histogram("ttft_ms", "ttft", buckets=(10.0, 100.0)) \
        .observe(50.0, shard="0")
    text = m.prometheus()
    assert "# HELP repro_tokens_total tokens" in text
    assert "# TYPE repro_tokens_total counter" in text
    assert "repro_tokens_total 5" in text
    assert 'repro_ttft_ms_bucket{shard="0",le="10"} 0' in text
    assert 'repro_ttft_ms_bucket{shard="0",le="100"} 1' in text
    assert 'repro_ttft_ms_bucket{shard="0",le="+Inf"} 1' in text
    assert 'repro_ttft_ms_sum{shard="0"} 50' in text
    assert 'repro_ttft_ms_count{shard="0"} 1' in text


# ---------------------------------------------------------------------------
# tracer + TraceArtifact + validate_trace (the schema gate)
# ---------------------------------------------------------------------------


def test_tracer_roundtrip_and_helpers(tmp_path):
    tr = Tracer()
    tr.ensure_process(0, "serve")
    tr.ensure_process(0, "serve")               # idempotent
    tr.ensure_thread(0, 1, "req0")
    tr.complete(0, 1, "request", "request", 10, 100, {"tokens": 3})
    tr.complete(0, 1, "admit", "request", 20, 30)
    tr.instant(0, 1, "first_token", "request", 60)
    art = tr.artifact()
    assert sum(e["ph"] == "M" for e in art.events) == 2  # proc + thread
    art.validate()
    p = tmp_path / "trace.json"
    art.save(p)
    back = TraceArtifact.load(p)
    assert back.events == art.events
    assert len(back.spans("admit")) == 1
    assert len(back.spans(cat="request")) == 2
    assert back.instants("first_token")[0]["ts"] == 60


@pytest.mark.parametrize("mutate, msg", [
    (lambda o: o.pop("traceEvents"), "traceEvents"),
    (lambda o: o["traceEvents"].append({"ph": "X"}), "missing/invalid"),
    (lambda o: o["traceEvents"].append(
        {"name": "x", "ph": "Q", "pid": 0, "tid": 0, "ts": 0}),
     "unknown ph"),
    (lambda o: o["traceEvents"].append(
        {"name": "x", "ph": "X", "pid": 0, "tid": 0, "ts": -1, "dur": 1}),
     "negative ts"),
    (lambda o: o["traceEvents"].append(
        {"name": "x", "ph": "X", "pid": 0, "tid": 0, "ts": 0}),
     "dur >= 0"),
    (lambda o: o["traceEvents"].append(
        {"name": "bogus", "ph": "M", "pid": 0, "tid": 0, "ts": 0,
         "args": {"name": "n"}}), "metadata"),
])
def test_validate_trace_rejects_schema_violations(mutate, msg):
    obj = {"traceEvents": []}
    mutate(obj)
    with pytest.raises(ValueError, match=msg):
        validate_trace(obj)


def test_validate_trace_rejects_partial_overlap_but_allows_nesting():
    def span(name, ts, dur):
        return {"name": name, "ph": "X", "pid": 0, "tid": 1,
                "ts": ts, "dur": dur}
    # proper forest: parent [0, 100), children [10, 30) and [40, 90)
    validate_trace({"traceEvents": [span("parent", 0, 100),
                                    span("a", 10, 20),
                                    span("b", 40, 50)]})
    # partial overlap: [10, 120) spills past the open parent
    with pytest.raises(ValueError, match="partially overlaps"):
        validate_trace({"traceEvents": [span("parent", 0, 100),
                                        span("bad", 10, 110)]})


# ---------------------------------------------------------------------------
# atomic artifact writes
# ---------------------------------------------------------------------------


def test_atomic_write_replaces_and_leaves_no_temp(tmp_path):
    p = tmp_path / "deep" / "stats.json"
    atomic_write_json(p, {"a": 1})
    atomic_write_json(p, {"a": 2})
    assert json.loads(p.read_text()) == {"a": 2}
    atomic_write_text(tmp_path / "m.prom", "x 1\n")
    assert (tmp_path / "m.prom").read_text() == "x 1\n"
    leftovers = [f for f in tmp_path.rglob("*.tmp")]
    assert not leftovers, f"temp files left behind: {leftovers}"


def test_atomic_write_failure_preserves_existing_file(tmp_path):
    p = tmp_path / "stats.json"
    atomic_write_json(p, {"ok": True})
    with pytest.raises(TypeError):
        atomic_write_json(p, {"bad": object()})
    assert json.loads(p.read_text()) == {"ok": True}
    assert not list(tmp_path.glob("*.tmp"))


# ---------------------------------------------------------------------------
# plan provenance + ObsConfig resolution
# ---------------------------------------------------------------------------


def test_plan_provenance_always_carries_acceptance_keys():
    plan = Planner(policy="paper").plan(
        AttentionSpec.decode(2, 256, 16, 1, 64), bucket=256)
    d = plan_provenance(("verify", 2, 256), plan)
    assert d["key"] == "verify/2/256"
    assert d["num_splits"] == plan.num_splits
    assert d["kv_dtype"] == "bfloat16"
    assert d["policy"] == "paper" and d["bucket"] == 256
    assert "mesh_splits" in d and "table_version" in d
    # fallback launches (no plan) still stamp the four keys, as nulls
    d0 = plan_provenance(None, None)
    assert d0["key"] == "fallback"
    for k in ("num_splits", "mesh_splits", "kv_dtype", "table_version"):
        assert d0[k] is None


def test_obsconfig_disabled_resolves_to_null_singleton():
    obs = ObsConfig().resolve()
    assert obs is NULL_OBSERVER and not obs.enabled
    # hooks are no-ops and never allocate observable state
    obs.on_submit(0, 0, 1)
    obs.on_launch("decode", None, None, 0)
    assert obs.metrics_snapshot() == {} and obs.prometheus() == ""
    assert obs.shard_view(3) is obs
    on = ObsConfig(trace=True).resolve()
    assert on.enabled and on.tracer is not None and on.metrics is None
    assert ObsConfig(metrics_path="x.json").resolve().metrics is not None


# ---------------------------------------------------------------------------
# Observer lifecycle on a fake clock (deterministic spans + metrics)
# ---------------------------------------------------------------------------


def test_observer_lifecycle_spans_and_metrics():
    obs = ObsConfig(trace=True, metrics=True, clock=FakeClock()).resolve()
    obs.on_submit(0, 7, 5)
    obs.on_admit_start(0)
    t0 = obs.now_us()
    obs.on_launch("prefill", ("prefill", 128), None, t0, handles=(0,))
    obs.on_admit_end(0, "full")
    obs.on_token(0, 0)
    obs.on_token(0, 1)
    obs.on_finish(0, "length")
    art = obs.tracer.artifact()
    art.validate()
    req = art.spans("request")[0]
    qw, admit = art.spans("queue_wait")[0], art.spans("admit")[0]
    # request encloses queue_wait, admit and the mirrored step span
    for child in (qw, admit, art.spans("prefill", cat="step")[0]):
        assert req["ts"] <= child["ts"]
        assert child["ts"] + child["dur"] <= req["ts"] + req["dur"]
    assert req["args"] == {"request_id": 7, "prompt_len": 5,
                           "prefill": "full", "finish_reason": "length",
                           "tokens": 2}
    assert art.instants("first_token")
    launch = art.spans("prefill", cat="launch")[0]
    assert launch["tid"] == 0 and launch["args"]["key"] == "prefill/128"
    mx = obs.metrics_snapshot()["metrics"]
    assert mx["requests_submitted_total"]["aggregate"] == 1
    assert mx["requests_finished_total"]["series"] == {"reason=length": 1}
    assert mx["tokens_total"]["aggregate"] == 2
    assert mx["ttft_ms"]["aggregate"]["count"] == 1
    assert mx["tpot_ms"]["aggregate"]["count"] == 1
    assert mx["queue_wait_ms"]["aggregate"]["count"] == 1
    # ttft (submit -> first token) strictly exceeds queue wait
    assert mx["ttft_ms"]["aggregate"]["sum"] > \
        mx["queue_wait_ms"]["aggregate"]["sum"]


def test_shard_views_share_one_clock_and_label_series():
    obs = ObsConfig(trace=True, metrics=True, clock=FakeClock()).resolve()
    v0, v1 = obs.shard_view(0), obs.shard_view(1)
    seq = [v0.now_us(), v1.now_us(), obs.now_us(), v1.now_us()]
    assert seq == sorted(seq), "shard views must merge on one timeline"
    v0.on_submit(0, 0, 3)
    v1.on_submit(0, 1, 3)
    v0.on_token(0, 0)
    v1.on_token(0, 0)
    mx = obs.metrics_snapshot()["metrics"]
    sub = mx["requests_submitted_total"]
    assert sub["series"]["shard=0"] == 1 and sub["series"]["shard=1"] == 1
    assert sub["aggregate"] == 2
    pids = {e["pid"] for e in obs.tracer.artifact().events}
    assert {0, 1} <= pids


def test_prometheus_absorbs_plan_cache_scalars():
    obs = ObsConfig(metrics=True, clock=FakeClock()).resolve()
    text = obs.prometheus({"hits": 3, "misses": 1, "policy": "paper"})
    assert "repro_plan_cache_hits 3" in text
    assert "repro_plan_cache_misses 1" in text
    assert "policy" not in text.split("repro_plan_cache_")[-1]
    sharded = obs.prometheus({
        "shards": [{"shard": 0, "hits": 2}, {"shard": 1, "hits": 5}],
        "aggregate": {"hits": 7}})
    assert 'repro_plan_cache_hits{shard="0"} 2' in sharded
    assert 'repro_plan_cache_hits{shard="1"} 5' in sharded
    assert "repro_plan_cache_hits 7" in sharded


# ---------------------------------------------------------------------------
# engine integration: deterministic traces, bit-identity, dumps
# ---------------------------------------------------------------------------


def _serve(model, scfg, reqs, *, obs=None, max_len=64, slots=2,
           sharded=False):
    if sharded:
        eng = ShardedServingEngine(
            model, scfg, spec=ShardSpec(dp=1, sp=1, slots_per_shard=slots),
            max_len=max_len, obs=obs)
    else:
        eng = ServingEngine(model, scfg, max_len=max_len,
                            batch_slots=slots, obs=obs)
    eng.load(model.init_params(jax.random.PRNGKey(0)))
    for r in reqs:
        eng.submit(r)
    return eng, eng.drain()


def test_engine_trace_is_deterministic_under_fake_clock(tiny_model):
    cfg, model, params = tiny_model
    scfg = ServeConfig(model=cfg, prefill_mode="fused")

    def one_run():
        obs = ObsConfig(trace=True, metrics=True,
                        clock=FakeClock()).resolve()
        eng = ServingEngine(model, scfg, max_len=64, batch_slots=2,
                            obs=obs)
        eng.load(params)
        for r in _reqs(cfg, 3, max_new=3):
            eng.submit(r)
        eng.drain()
        return obs

    a, b = one_run(), one_run()
    ea = a.tracer.artifact()
    assert ea.events == b.tracer.artifact().events, \
        "same requests + same fake clock must replay the same trace"
    ea.validate()
    assert len(ea.spans("request")) == 3
    for sp in ea.spans(cat="launch"):
        for k in ("key", "num_splits", "mesh_splits", "kv_dtype",
                  "table_version"):
            assert k in sp["args"], f"launch span missing {k}"
    assert {"prefill", "decode"} <= {sp["name"]
                                     for sp in ea.spans(cat="launch")}
    assert a.metrics_snapshot() == b.metrics_snapshot()


@settings(max_examples=4, deadline=None)
@given(layout=st.sampled_from(["dense", "paged"]),
       sharded=st.sampled_from([False, True]),
       seed=st.integers(0, 3))
def test_property_tracing_on_off_bit_identical(tiny_model, layout,
                                               sharded, seed):
    cfg, model, params = tiny_model
    scfg = ServeConfig(model=cfg, cache_layout=layout)
    reqs = _reqs(cfg, 4, seed=seed, max_new=4)

    clear_shard_plan_caches()
    ops.reset_policy_eval_count()
    eng_off, outs_off = _serve(model, scfg, reqs, sharded=sharded)
    evals_off = ops.policy_eval_count()

    clear_shard_plan_caches()
    ops.reset_policy_eval_count()
    obs = ObsConfig(trace=True, metrics=True, clock=FakeClock()).resolve()
    eng_on, outs_on = _serve(model, scfg, reqs, obs=obs, sharded=sharded)
    evals_on = ops.policy_eval_count()

    assert [c.tokens for c in outs_off] == [c.tokens for c in outs_on], \
        "tracing changed the greedy token stream"
    assert [c.finish_reason for c in outs_off] == \
        [c.finish_reason for c in outs_on]
    if sharded:
        stats_off = [c.stats.to_json() for c in eng_off.cores]
        stats_on = [c.stats.to_json() for c in eng_on.cores]
    else:
        stats_off, stats_on = eng_off.stats.to_json(), eng_on.stats.to_json()
    assert stats_off == stats_on, "tracing changed PlanCacheStats"
    assert evals_off == evals_on == 0, "policy ran inside a traced step"
    obs.tracer.artifact().validate()


def test_engine_owned_dump_writes_both_artifacts(tiny_model, tmp_path):
    cfg, model, params = tiny_model
    scfg = ServeConfig(model=cfg,
                       stats_path=str(tmp_path / "stats.json"),
                       trace_path=str(tmp_path / "trace.json"),
                       metrics_path=str(tmp_path / "metrics.prom"))
    _serve(model, scfg, _reqs(cfg, 2, max_new=3))
    stats = json.loads((tmp_path / "stats.json").read_text())
    assert stats["policy"] == "paper"
    trace = json.loads((tmp_path / "trace.json").read_text())
    validate_trace(trace)
    prom = (tmp_path / "metrics.prom").read_text()
    # .prom suffix selects text exposition, with plan-cache scalars
    assert "# TYPE repro_ttft_ms histogram" in prom
    assert "repro_plan_cache_total_launches" in prom
    assert not list(tmp_path.glob("*.tmp"))


def test_sharded_dump_merges_shards_onto_one_artifact(tiny_model,
                                                      tmp_path):
    cfg, model, params = tiny_model
    scfg = ServeConfig(model=cfg,
                       stats_path=str(tmp_path / "stats.json"),
                       trace_path=str(tmp_path / "trace.json"),
                       metrics_path=str(tmp_path / "metrics.json"))
    _serve(model, scfg, _reqs(cfg, 3, max_new=3), sharded=True)
    trace = json.loads((tmp_path / "trace.json").read_text())
    validate_trace(trace)
    names = {e["args"]["name"] for e in trace["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert "shard0" in names
    snap = json.loads((tmp_path / "metrics.json").read_text())
    assert "shard=0" in snap["metrics"]["ttft_ms"]["series"]
    # plan_cache section rides the merge_stats_snapshots path
    pc = snap["plan_cache"]
    assert [s["shard"] for s in pc["shards"]] == [0]
    assert pc["aggregate"]["total_launches"] == \
        pc["shards"][0]["total_launches"]
    stats = json.loads((tmp_path / "stats.json").read_text())
    assert set(stats) >= {"topology", "shards", "aggregate"}


# ---------------------------------------------------------------------------
# structured warning events (exactly once, python warning kept for compat)
# ---------------------------------------------------------------------------


def test_len_capacity_warning_fires_exactly_once(tiny_model):
    cfg, model, params = tiny_model
    obs = ObsConfig(trace=True, metrics=True, clock=FakeClock()).resolve()
    # both requests decode into the max_len wall; the python warning and
    # the structured event must each fire exactly once per engine
    reqs = [Request(i, [7, 8, 9], max_new_tokens=64) for i in range(2)]
    with pytest.warns(RuntimeWarning, match="KV cache capacity"):
        _, outs = _serve(model, ServeConfig(model=cfg), reqs, obs=obs,
                         max_len=16)
    assert all(c.finish_reason == "cache_capacity" for c in outs)
    warn = obs.metrics_snapshot()["metrics"]["engine_warnings_total"]
    assert warn["series"] == {"code=len_capacity": 1}
    assert len(obs.tracer.artifact().instants("warning:len_capacity")) == 1


def test_registry_fallback_warning_fires_exactly_once(tiny_model,
                                                      tmp_path):
    cfg, model, params = tiny_model
    for name, backend, device in (("a_tpu.json", "tpu", "TPU v5e"),
                                  ("b_gpu.json", "gpu", "H100")):
        d = json.loads(REFERENCE_TABLE_PATH.read_text())
        d["fingerprint"]["backend"] = backend
        d["fingerprint"]["device"] = device
        (tmp_path / name).write_text(json.dumps(d))
    obs = ObsConfig(trace=True, metrics=True, clock=FakeClock()).resolve()
    with pytest.warns(RuntimeWarning, match="no table in registry"):
        eng = ServingEngine(
            model, ServeConfig(model=cfg, split_policy="measured",
                               tune_table_path=str(tmp_path)),
            max_len=64, batch_slots=1, obs=obs)
    assert eng.stats.table_registry_fallbacks == 1
    warn = obs.metrics_snapshot()["metrics"]["engine_warnings_total"]
    assert warn["series"] == {"code=table_registry_fallback": 1}
    assert len(obs.tracer.artifact()
               .instants("warning:table_registry_fallback")) == 1


# ---------------------------------------------------------------------------
# multidevice tier: dp=2 artifact parity in an 8-device subprocess
# ---------------------------------------------------------------------------


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.multidevice
def test_dp2_trace_merges_both_shards_bit_identical_tokens(tmp_path):
    out = run_py(f"""
    import json
    import jax, numpy as np
    from repro.configs.base import ServeConfig
    from repro.configs.reduced import reduced_config
    from repro.models import build_model
    from repro.obs import validate_trace
    from repro.serving import Request
    from repro.shard import ShardSpec, ShardedServingEngine

    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    def reqs():
        rng = np.random.default_rng(0)
        return [Request(i, rng.integers(1, 250,
                        size=int(rng.integers(2, 8))).tolist(),
                        max_new_tokens=5) for i in range(6)]

    tdir = {str(tmp_path)!r}
    spec = ShardSpec(dp=2, sp=1, slots_per_shard=2)
    on = ShardedServingEngine(
        model, ServeConfig(model=cfg,
                           trace_path=tdir + "/trace.json",
                           metrics_path=tdir + "/metrics.json"),
        spec=spec, max_len=64)
    on.load(params)
    for r in reqs():
        on.submit(r)
    outs_on = on.drain()

    from repro.shard import clear_shard_plan_caches
    clear_shard_plan_caches()
    off = ShardedServingEngine(model, ServeConfig(model=cfg),
                               spec=spec, max_len=64)
    off.load(params)
    for r in reqs():
        off.submit(r)
    outs_off = off.drain()
    assert [c.tokens for c in outs_on] == [c.tokens for c in outs_off], \\
        "tracing changed sharded greedy tokens"

    trace = json.load(open(tdir + "/trace.json"))
    validate_trace(trace)
    pids = {{e["pid"] for e in trace["traceEvents"]}}
    assert pids == {{0, 1}}, pids
    snap = json.load(open(tdir + "/metrics.json"))
    series = snap["metrics"]["requests_submitted_total"]["series"]
    assert series.get("shard=0", 0) + series.get("shard=1", 0) == 6
    assert [s["shard"] for s in snap["plan_cache"]["shards"]] == [0, 1]
    print("OK dp2 obs parity")
    """)
    assert "OK dp2 obs parity" in out
