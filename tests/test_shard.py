"""repro.shard: spec -> resolver -> ShardPlan, the per-topology
plan-cache registry, the mesh-native ShardedServingEngine's routed
admission, and (multidevice tier, subprocesses) dp/sp topology parity
against the single-device oracle."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import warnings
from pathlib import Path

import jax
import pytest

from _hyp_compat import given, settings, strategies as st
from repro.compat import make_mesh
from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.models import build_model
from repro.plan import merge_stats_snapshots
from repro.serving import Request, ServingEngine
from repro.serving.scheduler import Completion  # noqa: F401  (API surface)
from repro.shard import (
    ShardResolver,
    ShardSpec,
    ShardedServingEngine,
    clear_shard_plan_caches,
    pick_shard,
    shard_plan_cache,
)
from repro.tune import select_table
from repro.tune.table import REFERENCE_TABLE_PATH

REPO = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(autouse=True)
def _fresh_registry():
    clear_shard_plan_caches()
    yield
    clear_shard_plan_caches()


# ---------------------------------------------------------------------------
# ShardSpec (pure data)
# ---------------------------------------------------------------------------


def test_spec_validation_and_derived():
    s = ShardSpec(dp=4, sp=2, slots_per_shard=3)
    assert s.num_devices == 8
    assert s.total_slots == 12
    with pytest.raises(ValueError, match="axes must be >= 1"):
        ShardSpec(dp=0)
    with pytest.raises(ValueError, match="axes must be >= 1"):
        ShardSpec(sp=0)
    with pytest.raises(ValueError, match="slots_per_shard"):
        ShardSpec(slots_per_shard=0)
    with pytest.raises(ValueError, match="page_budget_per_shard"):
        ShardSpec(page_budget_per_shard=0)
    with pytest.raises(ValueError, match="params policy"):
        ShardSpec(params="sharded")


def test_spec_fingerprint_is_stable_identity():
    a = ShardSpec(dp=2, sp=2)
    assert a.fingerprint == ShardSpec(dp=2, sp=2).fingerprint
    assert a.fingerprint.startswith("shard.")
    # every field is identity: same grid, different budget -> new key
    assert a.fingerprint != ShardSpec(dp=2, sp=2, slots_per_shard=8).fingerprint
    assert a.fingerprint != ShardSpec(dp=2, sp=2,
                                      page_budget_per_shard=4).fingerprint
    assert a.fingerprint != a.with_(params="tp").fingerprint


def test_spec_parse_forms():
    assert ShardSpec.parse("4,2") == ShardSpec(dp=4, sp=2)
    assert ShardSpec.parse("4") == ShardSpec(dp=4, sp=1)
    assert ShardSpec.parse(" dp=2, sp=4 ") == ShardSpec(dp=2, sp=4)
    assert ShardSpec.parse("sp=2,slots_per_shard=8") == \
        ShardSpec(sp=2, slots_per_shard=8)
    # overrides win over the parsed text (serve --slots)
    assert ShardSpec.parse("2,2", slots_per_shard=6).slots_per_shard == 6
    with pytest.raises(ValueError, match="empty"):
        ShardSpec.parse(" , ")
    with pytest.raises(ValueError, match="mixed"):
        ShardSpec.parse("4,sp=2")
    with pytest.raises(ValueError, match="unknown shard topology field"):
        ShardSpec.parse("dp=2,chips=4")
    with pytest.raises(ValueError, match="positional"):
        ShardSpec.parse("2,2,2")


def test_pick_shard_least_loaded_lowest_index():
    assert pick_shard([3, 1, 2]) == 1
    assert pick_shard([2, 1, 1]) == 1          # tie -> lowest index
    assert pick_shard([0, 0, 0, 0]) == 0
    assert pick_shard([5]) == 0


# ---------------------------------------------------------------------------
# ShardResolver (validation happens at resolution, not first launch)
# ---------------------------------------------------------------------------


def test_resolver_divisibility_checked_before_devices():
    # these raise on ONE device even though the topologies need more:
    # layout divisibility fails first, with the layout in the message
    with pytest.raises(ValueError, match="max_len"):
        ShardResolver(ShardSpec(sp=2)).resolve(max_len=63)
    with pytest.raises(ValueError, match="page_size"):
        ShardResolver(ShardSpec(sp=2)).resolve(
            max_len=64, cache_layout="paged", page_size=15)


def test_resolver_rejects_short_device_set():
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        ShardResolver(ShardSpec(dp=2)).resolve(
            max_len=64, devices=jax.devices()[:1])


def test_resolved_plan_shapes_and_registry():
    plan = ShardResolver(ShardSpec(dp=1, sp=1)).resolve(max_len=64)
    assert plan.mesh.shape == {"data": 1, "model": 1}
    assert len(plan.submeshes) == 1
    assert plan.shard_devices(0) == plan.devices
    assert plan.fingerprint.startswith(plan.spec.fingerprint + ".")
    # same (topology, shard, ident) -> the SAME PlanCache object; any
    # key component changing -> a different one
    c0 = plan.plan_cache(0, ident=("a",))
    assert plan.plan_cache(0, ident=("a",)) is c0
    assert plan.plan_cache(0, ident=("b",)) is not c0
    clear_shard_plan_caches()
    assert plan.plan_cache(0, ident=("a",)) is not c0
    assert shard_plan_cache(("x",), 4).capacity == 4


# ---------------------------------------------------------------------------
# merge_stats_snapshots (the stats_path dump's aggregate section)
# ---------------------------------------------------------------------------


def test_merge_stats_snapshots_sums_and_unions():
    a = {"hits": 3, "misses": 1, "total_launches": 4,
         "launches": {"128": 4}, "seen_buckets": [128],
         "spec_proposed": 4, "spec_accepted": 2, "spec_steps": 2,
         "spec_emitted": 4, "shard": 0, "policy": "paper"}
    b = {"hits": 5, "misses": 2, "total_launches": 7,
         "launches": {"128": 3, "256": 4}, "seen_buckets": [128, 256],
         "table_registry_fallbacks": 1}
    m = merge_stats_snapshots([a, b])
    assert m["hits"] == 8 and m["misses"] == 3
    assert m["total_launches"] == 11
    assert m["launches"] == {"128": 7, "256": 4}
    assert m["seen_buckets"] == [128, 256]
    assert m["distinct_buckets"] == 2          # union, not a sum
    assert m["table_registry_fallbacks"] == 1
    assert m["spec_acceptance_rate"] == 0.5
    assert m["spec_tokens_per_step"] == 2.0
    assert m["shards"] == 2
    # annotation keys pass through to neither sums nor output
    assert "policy" not in m and "shard" not in m


# ---------------------------------------------------------------------------
# select_table: tune_table_path as a registry DIRECTORY
# ---------------------------------------------------------------------------


def _write_table_variant(dst: Path, backend: str, device: str) -> None:
    d = json.loads(REFERENCE_TABLE_PATH.read_text())
    d["fingerprint"]["backend"] = backend
    d["fingerprint"]["device"] = device
    dst.write_text(json.dumps(d))


def test_select_table_file_and_registry_match(tmp_path):
    table, matched = select_table(REFERENCE_TABLE_PATH)
    assert matched                              # plain file: trusted
    live = jax.default_backend()
    _write_table_variant(tmp_path / "a_tpu.json", "tpu", "TPU v5e")
    _write_table_variant(tmp_path / "b_live.json", live,
                         jax.devices()[0].device_kind)
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # a match must not warn
        table, matched = select_table(tmp_path)
    assert matched
    assert table.fingerprint["backend"] == live


def test_select_table_registry_fallback_warns_and_counts(tmp_path,
                                                         tiny_model):
    _write_table_variant(tmp_path / "a_tpu.json", "tpu", "TPU v5e")
    _write_table_variant(tmp_path / "b_gpu.json", "gpu", "H100")
    with pytest.warns(RuntimeWarning, match="no table in registry"):
        table, matched = select_table(tmp_path)
    assert not matched
    assert table.fingerprint["backend"] == "tpu"   # sorted-name fallback
    (tmp_path / "empty").mkdir()
    with pytest.raises(ValueError, match="no \\*\\.json"):
        select_table(tmp_path / "empty")

    # the engine counts the fallback (observability, not a hard error)
    cfg, model, params = tiny_model
    with pytest.warns(RuntimeWarning, match="no table in registry"):
        eng = ServingEngine(
            model, ServeConfig(model=cfg, split_policy="measured",
                               tune_table_path=str(tmp_path)),
            max_len=64, batch_slots=1)
    assert eng.stats.table_registry_fallbacks == 1
    assert eng.tune_table is not None


# ---------------------------------------------------------------------------
# build_serve_step deprecation shim
# ---------------------------------------------------------------------------


def test_build_serve_step_shim_warns_once_and_delegates(tiny_model,
                                                        monkeypatch):
    from repro.serving import decode_step
    cfg, model, _ = tiny_model
    monkeypatch.setattr(decode_step, "_BUILD_SERVE_STEP_WARNED", False)
    mesh = make_mesh((1, 1), ("data", "model"))
    scfg = ServeConfig(model=cfg)
    with pytest.warns(DeprecationWarning, match="build_mesh_decode_step"):
        bundle = decode_step.build_serve_step(model, scfg, mesh)
    assert bundle.step is not None              # delegated, same bundle
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        bundle2 = decode_step.build_serve_step(model, scfg, mesh)
    assert not any(issubclass(x.category, DeprecationWarning)
                   for x in rec)                # warn-once
    assert type(bundle2) is type(bundle)


# ---------------------------------------------------------------------------
# dp=1 x sp=1 on the host device: full parity with the plain engine
# ---------------------------------------------------------------------------


def _reqs(n, seed=0, max_new=6):
    import numpy as np
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(1, 250,
                                    size=int(rng.integers(2, 8))).tolist(),
                    max_new_tokens=max_new) for i in range(n)]


def test_dp1_sp1_matches_plain_engine(tiny_model):
    cfg, model, params = tiny_model
    scfg = ServeConfig(model=cfg)
    plain = ServingEngine(model, scfg, max_len=64, batch_slots=2)
    plain.load(params)
    for r in _reqs(5):
        plain.submit(r)
    want = {c.request_id: (c.tokens, c.finish_reason)
            for c in plain.drain()}

    eng = ShardedServingEngine(
        model, scfg, spec=ShardSpec(dp=1, sp=1, slots_per_shard=2),
        max_len=64)
    eng.load(params)
    handles = [eng.submit(r) for r in _reqs(5)]
    assert len(set(handles)) == 5               # global handles
    got = {c.request_id: (c.tokens, c.finish_reason)
           for c in eng.drain()}
    assert got == want
    agg = eng.aggregate_stats()
    assert agg["shards"] == 1
    assert agg["total_launches"] == plain.stats.total_launches
    assert eng.routed(0) == [0, 1, 2, 3, 4]
    assert eng.B == 2


def test_per_shard_page_budget_and_label(tiny_model):
    """spec.page_budget_per_shard replaces the engine-wide budget: the
    sharded engine hits cache_capacity exactly like a plain engine with
    cache_page_budget set to the same number, and its conservation
    assertions carry the shard label."""
    cfg, model, params = tiny_model
    scfg = ServeConfig(model=cfg, cache_layout="paged",
                       cache_page_size=16)
    reqs = lambda: [Request(0, [1] * 20, max_new_tokens=60),  # noqa: E731
                    Request(1, [2] * 5, max_new_tokens=3)]
    plain = ServingEngine(
        model, dataclasses.replace(scfg, cache_page_budget=3),
        max_len=128, batch_slots=1)
    plain.load(params)
    for r in reqs():
        plain.submit(r)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        want = {c.request_id: (c.tokens, c.finish_reason)
                for c in plain.drain()}
    assert want[0][1] == "cache_capacity"       # 3 pages = 48 rows < 80
    assert want[1][1] == "length"

    eng = ShardedServingEngine(
        model, scfg,
        spec=ShardSpec(dp=1, sp=1, slots_per_shard=1,
                       page_budget_per_shard=3),
        max_len=128)
    eng.load(params)
    assert eng.cores[0].cache.label == "shard0"
    assert eng.cores[0].cache_stats()["total_pages"] == 3
    for r in reqs():
        eng.submit(r)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        got = {c.request_id: (c.tokens, c.finish_reason)
               for c in eng.drain()}
    assert got == want
    eng.check_conservation()
    assert eng.describe()[0]["free_pages"] == 3


def test_same_topology_engines_share_compiled_steps(tiny_model):
    """Two engines resolved to the same (topology, identity) share ONE
    PlanCache: the second serves entirely on the first's compiled
    steps (zero new misses)."""
    cfg, model, params = tiny_model
    scfg = ServeConfig(model=cfg)
    spec = ShardSpec(dp=1, sp=1, slots_per_shard=2)
    e1 = ShardedServingEngine(model, scfg, spec=spec, max_len=64)
    e1.load(params)
    for r in _reqs(3):
        e1.submit(r)
    out1 = e1.drain()
    misses = e1.stats.misses
    assert misses > 0

    e2 = ShardedServingEngine(model, scfg, spec=spec, max_len=64)
    e2.load(params)
    assert e2.cores[0].sched.plans is e1.cores[0].sched.plans
    for r in _reqs(3):
        e2.submit(r)
    out2 = e2.drain()
    assert e2.stats.misses == misses            # warm: hits only
    assert [c.tokens for c in out1] == [c.tokens for c in out2]

    # a different identity (policy) must NOT share
    e3 = ShardedServingEngine(model, scfg, spec=spec, max_len=64,
                              policy="fa3_baseline")
    assert e3.cores[0].sched.plans is not e1.cores[0].sched.plans


def test_stats_path_merges_shards_into_one_dump(tiny_model, tmp_path):
    cfg, model, params = tiny_model
    out = tmp_path / "stats.json"
    eng = ShardedServingEngine(
        model, ServeConfig(model=cfg, stats_path=str(out)),
        spec=ShardSpec(dp=1, sp=1, slots_per_shard=2), max_len=64)
    eng.load(params)
    for r in _reqs(3):
        eng.submit(r)
    eng.drain()
    d = json.loads(out.read_text())
    assert d["topology"]["dp"] == 1
    assert d["fingerprint"] == eng.plan.fingerprint
    assert [s["shard"] for s in d["shards"]] == [0]
    assert d["shards"][0]["devices"]
    assert d["aggregate"]["shards"] == 1
    assert d["aggregate"]["total_launches"] == \
        d["shards"][0]["total_launches"] > 0


def test_engine_requires_a_topology(tiny_model):
    cfg, model, _ = tiny_model
    with pytest.raises(ValueError, match="no topology"):
        ShardedServingEngine(model, ServeConfig(model=cfg))
    # ServeConfig.shard is the serve-launcher path to the same spec
    eng = ShardedServingEngine(
        model, ServeConfig(model=cfg, shard="1,1"), max_len=64)
    assert eng.spec == ShardSpec(dp=1, sp=1)


# ---------------------------------------------------------------------------
# multidevice tier: real dp/sp topologies in 8-device subprocesses
# ---------------------------------------------------------------------------


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


_SETUP = """
    import dataclasses, json, warnings
    import jax, numpy as np
    from repro.configs.base import ServeConfig
    from repro.configs.reduced import reduced_config
    from repro.models import build_model
    from repro.serving import Request, ServingEngine
    from repro.shard import ShardSpec, ShardedServingEngine

    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    def reqs(n, seed=0, max_new=6):
        rng = np.random.default_rng(seed)
        return [Request(i, rng.integers(1, 250,
                        size=int(rng.integers(2, 8))).tolist(),
                        max_new_tokens=max_new) for i in range(n)]

    def done_map(outs):
        return {c.request_id: (tuple(c.tokens), c.finish_reason)
                for c in outs}
"""


@pytest.mark.multidevice
def test_dp4_serves_4x_slots_bit_identical():
    run_py(_SETUP + """
    scfg = ServeConfig(model=cfg)
    single = ServingEngine(model, scfg, max_len=64, batch_slots=2)
    single.load(params)
    for r in reqs(8):
        single.submit(r)
    want = done_map(single.drain())

    eng = ShardedServingEngine(
        model, scfg, spec=ShardSpec(dp=4, sp=1, slots_per_shard=2),
        max_len=64)
    eng.load(params)
    assert eng.B == 4 * single.B == 8
    for r in reqs(8):
        eng.submit(r)
    assert done_map(eng.drain()) == want

    per_shard = [c.stats.total_launches for c in eng.cores]
    assert all(n > 0 for n in per_shard), per_shard
    # round-robin routing under equal load: 2 requests per shard
    assert [len(eng.routed(d)) for d in range(4)] == [2, 2, 2, 2]
    agg = eng.aggregate_stats()
    assert agg["shards"] == 4
    assert agg["total_launches"] == sum(per_shard)
    print("dp4 OK", per_shard)
    """)


@pytest.mark.multidevice
def test_sp4_long_context_decode_with_mesh_provenance():
    """sp=4 sequence-shards an L_K=4096 dense decode over 4 chips:
    tokens bit-identical to the single-device engine, and every decode
    plan carries mesh_splits=4 + the realized shard mesh."""
    run_py(_SETUP + """
    scfg = ServeConfig(model=cfg)
    prompt = np.random.default_rng(1).integers(
        1, 250, size=4000).tolist()
    def one_req():
        return [Request(0, list(prompt), max_new_tokens=5)]

    single = ServingEngine(model, scfg, max_len=4096, batch_slots=1)
    single.load(params)
    for r in one_req():
        single.submit(r)
    want = done_map(single.drain())

    eng = ShardedServingEngine(
        model, scfg, spec=ShardSpec(dp=1, sp=4, slots_per_shard=1),
        max_len=4096)
    eng.load(params)
    assert eng.cores[0].seq_shards == 4
    for r in one_req():
        eng.submit(r)
    assert done_map(eng.drain()) == want

    plans = {k: e.plan for k, e in eng.cores[0].sched.plans.items()
             if isinstance(k, int)}
    assert 4096 in plans, sorted(plans)
    assert all(p.mesh_splits == 4 and p.seq_shard_mesh is not None
               for p in plans.values()), plans
    print("sp4 OK", {k: p.mesh_splits for k, p in plans.items()})
    """)


@pytest.mark.multidevice
def test_dp2_paged_budget_exhaustion_is_per_shard():
    """One shard exhausting ITS page budget finishes only ITS request
    with cache_capacity — the other shard's identical budget is
    untouched and its request runs to length."""
    run_py(_SETUP + """
    scfg = ServeConfig(model=cfg, cache_layout="paged",
                       cache_page_size=16)
    eng = ShardedServingEngine(
        model, scfg,
        spec=ShardSpec(dp=2, sp=1, slots_per_shard=1,
                       page_budget_per_shard=3),
        max_len=128)
    eng.load(params)
    eng.submit(Request(0, [1] * 20, max_new_tokens=60))  # -> shard 0
    eng.submit(Request(1, [2] * 5, max_new_tokens=3))    # -> shard 1
    assert eng.routed(0) == [0] and eng.routed(1) == [1]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        got = done_map(eng.drain())
    assert got[0][1] == "cache_capacity", got
    assert got[1][1] == "length", got
    eng.check_conservation()
    for row in eng.describe():
        assert row["free_pages"] == row["total_pages"] == 3
    print("dp2 paged budget OK")
    """)


@pytest.mark.multidevice
def test_sp2_paged_decode_matches_oracle():
    run_py(_SETUP + """
    scfg = ServeConfig(model=cfg, cache_layout="paged",
                       cache_page_size=16)
    single = ServingEngine(model, scfg, max_len=64, batch_slots=2)
    single.load(params)
    for r in reqs(5):
        single.submit(r)
    want = done_map(single.drain())

    eng = ShardedServingEngine(
        model, scfg, spec=ShardSpec(dp=1, sp=2, slots_per_shard=2),
        max_len=64)
    eng.load(params)
    for r in reqs(5):
        eng.submit(r)
    assert done_map(eng.drain()) == want
    plans = {k: e.plan for k, e in eng.cores[0].sched.plans.items()
             if isinstance(k, int)}
    assert plans and all(p.mesh_splits == 2 for p in plans.values())
    eng.check_conservation()
    print("sp2 paged OK")
    """)


# ---------------------------------------------------------------------------
# property: ANY topology + ANY interleaving == the per-shard oracle
# ---------------------------------------------------------------------------

_PROPERTY_BODY = """
    DP, SP, LAYOUT, SEED = {dp}, {sp}, {layout!r}, {seed}
    rng = np.random.default_rng(SEED)
    scfg = ServeConfig(model=cfg, cache_layout=LAYOUT,
                       cache_page_size=16)
    budget = 4 if LAYOUT == "paged" else None
    spec = ShardSpec(dp=DP, sp=SP, slots_per_shard=2,
                     page_budget_per_shard=budget)
    eng = ShardedServingEngine(model, scfg, spec=spec, max_len=64)
    eng.load(params)
    # the oracle fleet: one single-DEVICE engine per shard, same
    # slots/budget (dp=1 sp=1 resolves to the first device only)
    oracle_scfg = dataclasses.replace(
        scfg, cache_page_budget=budget) if budget else scfg
    oracles = [ServingEngine(model, oracle_scfg, max_len=64,
                             batch_slots=2) for _ in range(DP)]
    for o in oracles:
        o.load(params)

    # mixed finish reasons: eos (random tokens), length (short), and —
    # paged: 4 pages = 64 rows shared by 2 slots — cache_capacity
    n = 9
    stream = [Request(i, rng.integers(1, 250,
                      size=int(rng.integers(2, 12))).tolist(),
                      max_new_tokens=int(rng.choice([3, 6, 40])))
              for i in range(n)]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for r in stream:
            before = [len(eng.routed(d)) for d in range(DP)]
            eng.submit(Request(r.request_id, list(r.prompt),
                               r.max_new_tokens))
            after = [len(eng.routed(d)) for d in range(DP)]
            (d,) = [i for i in range(DP) if after[i] != before[i]]
            oracles[d].submit(r)
            # random interleaving, mirrored step-for-step per shard
            for _ in range(int(rng.integers(0, 3))):
                if not eng.has_work():
                    break
                pumped = [i for i, c in enumerate(eng.cores)
                          if c.has_work()]
                eng.step()
                for i in pumped:
                    assert oracles[i].has_work()   # lockstep invariant
                    oracles[i].step()
        got = done_map(eng.drain())
        want = {{}}
        for o in oracles:
            want.update(done_map(o.drain()))
    assert got == want, (got, want)
    assert sorted(r for d in range(DP) for r in eng.routed(d)) == \
        list(range(n))
    if LAYOUT == "paged":
        eng.check_conservation()
        for row in eng.describe():
            assert row["free_pages"] == row["total_pages"] == 4
    reasons = {{fr for _, fr in got.values()}}
    print("topology", (DP, SP, LAYOUT, SEED), "reasons", reasons)
"""


@pytest.mark.multidevice
@settings(max_examples=4, deadline=None)
@given(topo=st.sampled_from([(1, 2), (2, 1), (2, 2), (4, 1), (1, 4),
                             (4, 2), (2, 4), (3, 2)]),
       layout=st.sampled_from(["dense", "paged"]),
       seed=st.integers(0, 3))
def test_property_topology_parity_with_oracle(topo, layout, seed):
    dp, sp = topo
    run_py(_SETUP + _PROPERTY_BODY.format(dp=dp, sp=sp, layout=layout,
                                          seed=seed))
