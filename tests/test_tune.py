"""repro.tune subsystem: Spec -> Calibrator -> Table, measured policy.

The load-bearing guarantees:

- the committed reference table is schema-valid and replays bit-exact
  through ``Planner(policy="measured")`` (the ``make tune-golden`` gate,
  mirroring ``plan-golden``),
- SplitTable round-trips, merges, and rejects schema/version mismatches,
- the Calibrator is deterministic under a fixed seed (same grid, same
  decisions, same content-derived version),
- nearest-bucket lookup always yields a feasible split and falls back
  (counted) exactly when the grid does not cover the shape family,
- measured plans are bit-stable across PlanCache eviction and
  re-specialization,
- the serving engine on ``split_policy="measured"`` keeps the policy out
  of traced code (``policy_eval_count`` flat) and its greedy tokens
  bit-identical to the analytic policies'.
"""
import dataclasses
import json

import jax
import pytest

from _hyp_compat import given, settings, strategies as st

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.core.split_policy import (
    DecodeWorkload,
    analytic_policies,
    available_policies,
    choose_num_splits,
    get_policy,
)
from repro.kernels import ops
from repro.models import build_model
from repro.plan import AttentionSpec, PlanCache, Planner
from repro.serving.engine import DecodeEngine, Request, ServingEngine
from repro.tune import (
    REFERENCE_SPEC,
    REFERENCE_TABLE_PATH,
    SCHEMA_VERSION,
    Calibrator,
    SplitTable,
    TuneSpec,
)

SMALL_SPEC = TuneSpec(lk_buckets=(128, 256, 512), batches=(1, 2),
                      head_shapes=((4, 1, 8), (64, 1, 128)))


@pytest.fixture(scope="module")
def small_table() -> SplitTable:
    return Calibrator(SMALL_SPEC, mode="modeled", seed=0).calibrate()


@pytest.fixture(scope="module")
def reference_table() -> SplitTable:
    return SplitTable.load(REFERENCE_TABLE_PATH)


# ---------------------------------------------------------------------------
# tune-golden gate: the committed reference table
# ---------------------------------------------------------------------------


def test_reference_table_schema_valid(reference_table):
    reference_table.validate()
    fp = reference_table.fingerprint
    assert fp["mode"] == "modeled" and fp["fallback"] == "paper", \
        "reference table must be the deterministic modeled calibration"
    assert len(reference_table) == REFERENCE_SPEC.grid_size(), \
        "reference table drifted from REFERENCE_SPEC's grid"


def test_reference_table_replays_bit_exact(reference_table):
    """Every committed cell, through the public Planner — regenerate
    intentionally with `python -m repro.launch.tune --reference`."""
    planner = Planner(policy="measured", table=reference_table)
    ops.reset_policy_eval_count()
    for e in reference_table.entries:
        spec = AttentionSpec.decode(
            e["batch"], e["lk_bucket"], e["num_heads_q"],
            e["num_heads_kv"], e["head_dim"], kv_dtype=e["kv_dtype"])
        plan = planner.plan(spec)
        assert plan.num_splits == e["best_split"], e
        assert plan.tuned and plan.table_version == reference_table.version
    assert ops.policy_eval_count() == 0     # planning is not dispatch
    assert reference_table.fallbacks == 0   # the grid covers itself


def test_reference_table_is_regenerated_deterministically(reference_table):
    """`--reference` recalibrates to the exact committed artifact."""
    fresh = Calibrator(REFERENCE_SPEC, mode="modeled", seed=0).calibrate()
    assert fresh.version == reference_table.version


# ---------------------------------------------------------------------------
# SplitTable: round-trip / merge / mismatch rejection
# ---------------------------------------------------------------------------


def test_table_round_trip(tmp_path, small_table):
    p = small_table.save(tmp_path / "t.json")
    loaded = SplitTable.load(p)
    assert loaded.version == small_table.version
    assert loaded.entries == small_table.entries
    assert loaded.fingerprint == small_table.fingerprint


def test_table_rejects_schema_mismatch(tmp_path, small_table):
    d = small_table.to_json()
    d["schema"] = 99
    del d["version"]
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(d))
    with pytest.raises(ValueError, match="schema mismatch"):
        SplitTable.load(p)


def test_table_rejects_tampered_entries(tmp_path, small_table):
    d = small_table.to_json()
    d["entries"][0]["best_split"] = 1 + d["entries"][0]["best_split"] % 2
    p = tmp_path / "tampered.json"
    p.write_text(json.dumps(d))
    with pytest.raises(ValueError, match="version mismatch"):
        SplitTable.load(p)


def test_table_merge_overrides_and_extends(small_table):
    sub = TuneSpec(lk_buckets=(512, 640), batches=(1,),
                   head_shapes=((64, 1, 128),), candidates=(1,),
                   dtypes=("bfloat16",))
    recal = Calibrator(sub, mode="modeled", seed=1).calibrate()
    merged = small_table.merge(recal)
    merged.validate()
    # 512 cell overridden (candidates pinned to 1), 640 cell added
    w512 = DecodeWorkload(1, 1, 512, 64, 1, 128)
    assert merged.choose(w512) == (1, True)
    assert len(merged) == len(small_table) + 1
    assert small_table.choose(w512)[0] != 1    # original decision intact
    other = SplitTable(recal.entries, recal.fingerprint)
    other.schema = SCHEMA_VERSION + 1           # simulate newer artifact
    with pytest.raises(ValueError, match="merge"):
        small_table.merge(other)


def test_table_validate_catches_infeasible_and_non_argmin(small_table):
    bad = [dict(e) for e in small_table.entries]
    bad[0] = dict(bad[0], best_split=99)
    with pytest.raises(ValueError, match="infeasible"):
        SplitTable(bad, small_table.fingerprint).validate()
    worst = [dict(e) for e in small_table.entries]
    e = dict(worst[-1])
    lats = dict(e["latencies_us"])
    assert len(lats) > 1
    e["best_split"] = int(max(lats, key=lambda k: lats[k]))
    e["latencies_us"] = lats
    worst[-1] = e
    with pytest.raises(ValueError, match="argmin"):
        SplitTable(worst, small_table.fingerprint).validate()


# ---------------------------------------------------------------------------
# Calibrator: determinism, wallclock path, budget degradation
# ---------------------------------------------------------------------------


def test_calibrator_deterministic_under_seed(small_table):
    again = Calibrator(SMALL_SPEC, mode="modeled", seed=0).calibrate()
    assert again.version == small_table.version
    assert again.entries == small_table.entries


def test_calibrator_wallclock_times_real_launches():
    """The wallclock mode actually jits and times decode_attention
    (tiny 1-cell grid); latencies are positive and the argmin is one of
    the candidates."""
    spec = TuneSpec(lk_buckets=(256,), batches=(1,),
                    head_shapes=((4, 1, 8),), repeats=2, warmup=1)
    table = Calibrator(spec, mode="wallclock", seed=0).calibrate()
    bf16, int8 = table.entries          # default grid: bf16 AND int8
    assert bf16["kv_dtype"] == "bfloat16" and bf16["source"] == "measured"
    # quantized cells ride the fused harness and are labeled apart
    assert int8["kv_dtype"] == "int8" and int8["source"] == "wallclock"
    for e in (bf16, int8):
        assert set(e["latencies_us"]) == {"1", "2"}
        assert all(t > 0 for t in e["latencies_us"].values())
    assert table.fingerprint["sources"] == "measured"   # both timed
    table.validate()


def test_calibrator_budget_degrades_to_model():
    spec = TuneSpec(lk_buckets=(128, 256), batches=(1,),
                    head_shapes=((4, 1, 8),), budget_s=0.0)
    table = Calibrator(spec, mode="wallclock", seed=0).calibrate()
    assert all(e["source"] == "modeled" for e in table.entries)
    assert table.fingerprint["sources"] == "mixed"


# ---------------------------------------------------------------------------
# Lookup property: feasible when covered, counted fallback when not
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(batch=st.integers(1, 16),
       lk=st.integers(1, 65536),
       heads=st.sampled_from([(4, 1, 8), (4, 1, 16), (64, 1, 128),
                              (16, 2, 128), (32, 4, 128), (5, 1, 8),
                              (4, 1, 64), (8, 8, 128)]))
def test_lookup_feasible_or_counted_fallback(reference_table, batch, lk,
                                             heads):
    hq, hkv, hd = heads
    w = DecodeWorkload(batch, 1, lk, hq, hkv, hd)
    before = reference_table.fallbacks
    s, tuned = reference_table.choose(w)
    assert 1 <= s <= w.num_n_blocks          # ALWAYS feasible (clamped)
    assert tuned == reference_table.covers(w), \
        "tuned iff the grid covers the shape family"
    if not tuned:                            # fallback: analytic paper
        assert reference_table.fallbacks == before + 1
        assert s == choose_num_splits(
            w, policy="paper",
            num_cores=reference_table.fingerprint["num_cores"])
    else:
        assert reference_table.fallbacks == before


def test_nearest_bucket_picks_closest_lk(reference_table):
    fam = {e["lk_bucket"]: e for e in reference_table.entries
           if (e["batch"], e["num_heads_q"], e["num_heads_kv"],
               e["head_dim"]) == (1, 64, 1, 128)}
    assert {128, 256, 384, 512, 640, 1024, 4096} <= set(fam)
    # 600 sits between the 512 and 640 buckets; 640 is nearer
    w = DecodeWorkload(1, 1, 600, 64, 1, 128)
    s, tuned = reference_table.choose(w)
    assert tuned
    assert s == min(fam[640]["best_split"], w.num_n_blocks)
    # far past the grid: the largest measured bucket decides (clamped)
    w_far = DecodeWorkload(1, 1, 60000, 64, 1, 128)
    s_far, tuned = reference_table.choose(w_far)
    assert tuned and s_far == min(fam[4096]["best_split"],
                                  w_far.num_n_blocks)


# ---------------------------------------------------------------------------
# Planner integration: provenance, ergonomics, eviction bit-stability
# ---------------------------------------------------------------------------


def test_measured_policy_is_registered_but_not_analytic():
    assert "measured" in available_policies()
    assert "measured" not in analytic_policies()
    assert getattr(get_policy("measured"), "needs_table", False)


def test_planner_requires_table_for_measured_and_lists_backends():
    with pytest.raises(ValueError) as ei:
        Planner(policy="measured")
    assert "SplitTable" in str(ei.value) and "paper" in str(ei.value)
    with pytest.raises(KeyError) as ei:
        Planner(policy="nope")
    for name in available_policies():
        assert name in str(ei.value)


def test_measured_plan_provenance(reference_table):
    planner = Planner(policy="measured", table=reference_table)
    covered = planner.plan(AttentionSpec.decode(1, 512, 64, 1, 128),
                           bucket=512)
    assert covered.tuned and covered.policy == "measured"
    assert covered.table_version == reference_table.version
    assert covered.describe()["tuned"] is True
    uncovered = planner.plan(AttentionSpec.decode(3, 512, 8, 8, 128))
    assert not uncovered.tuned and uncovered.policy == "measured"
    assert uncovered.table_version == reference_table.version
    # override bypasses the table entirely
    forced = dataclasses.replace(planner, num_splits_override=2).plan(
        AttentionSpec.decode(1, 512, 64, 1, 128))
    assert forced.num_splits == 2 and not forced.tuned


def test_measured_plans_bit_stable_across_eviction(reference_table):
    """A re-specialized (evicted, re-built) measured plan must be the
    same plan — the table is the single decision surface, so eviction
    can never change a decision."""
    planner = Planner(policy="measured", table=reference_table)
    cache = PlanCache(capacity=1)

    def build(bucket):
        spec = AttentionSpec.decode(1, bucket, 64, 1, 128)
        return lambda: planner.plan(spec, bucket=bucket)

    first = cache.get_or_build(512, build(512))
    cache.get_or_build(1024, build(1024))        # evicts 512
    assert 512 not in cache
    rebuilt = cache.get_or_build(512, build(512))
    assert rebuilt == first                      # bit-stable re-spec
    assert cache.stats.misses == 3


# ---------------------------------------------------------------------------
# Serving engine end-to-end on split_policy="measured"
# ---------------------------------------------------------------------------


def _engine(model, policy, table=None, stats_path=None, **kw):
    scfg = ServeConfig(model=model.cfg, split_policy=policy,
                       stats_path=stats_path)
    eng = ServingEngine(model, scfg, max_len=256, batch_slots=2,
                        tune_table=table, **kw)
    return eng


def test_engine_measured_policy_end_to_end(reference_table, tmp_path):
    cfg = reduced_config("qwen2.5-3b", num_layers=1, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    reqs = [Request(i, [1 + i, 2, 3], max_new_tokens=6) for i in range(3)]

    toks = {}
    for policy in ("paper", "measured"):
        table = reference_table if policy == "measured" else None
        stats_path = tmp_path / f"{policy}.json"
        eng = _engine(model, policy, table, stats_path=str(stats_path))
        eng.load(params)
        ops.reset_policy_eval_count()
        for r in reqs:
            eng.submit(r)
        outs = eng.drain()
        # the policy changes the schedule, never the math — and never
        # runs inside traced code on the metadata path
        assert ops.policy_eval_count() == 0, policy
        toks[policy] = [c.tokens for c in outs]
        if policy == "measured":
            st = eng.stats
            assert st.measured_lookups >= 1
            assert st.measured_fallbacks == 0, \
                "reference grid must cover the reduced engine's shapes"
            # every decode plan came from the table, with provenance
            for bucket, entry in eng.sched.plans.items():
                if isinstance(bucket, int):
                    assert entry.plan.tuned
                    assert entry.plan.table_version == \
                        reference_table.version
                    w = DecodeWorkload(2, 1, bucket, 4, 1, 8)
                    assert entry.plan.num_splits == \
                        reference_table.choose(w)[0]
        snap = json.loads(stats_path.read_text())
        assert snap["misses"] == eng.stats.misses
        assert snap["policy"] == policy
    assert toks["measured"] == toks["paper"]


def test_engine_measured_rejects_heuristic_path(reference_table):
    cfg = reduced_config("qwen2.5-3b", num_layers=1, d_model=32)
    model = build_model(cfg)
    with pytest.raises(ValueError, match="metadata"):
        ServingEngine(model,
                      ServeConfig(model=cfg, split_policy="measured",
                                  use_scheduler_metadata=False),
                      tune_table=reference_table)


def test_engine_loads_table_from_config_path(tmp_path, small_table):
    p = small_table.save(tmp_path / "t.json")
    cfg = reduced_config("qwen2.5-3b", num_layers=1, d_model=32)
    model = build_model(cfg)
    eng = DecodeEngine(model, ServeConfig(model=cfg,
                                          split_policy="measured",
                                          tune_table_path=str(p)))
    assert eng.engine.tune_table.version == small_table.version
    # (4,1,8) families are NOT in SMALL_SPEC -> decode plans fall back,
    # and the fallback lands in the ENGINE's PlanCacheStats
    params = model.init_params(jax.random.PRNGKey(0))
    eng.load(params)
    eng.generate([Request(0, [1, 2], max_new_tokens=2)])
    assert eng.stats.measured_lookups >= 1
    assert eng.stats.measured_fallbacks == eng.stats.measured_lookups
    # family key: (batch=the engine's 4 slots, Hq, Hkv, head_dim, ...)
    assert eng.stats.measured_fallback_trace[0][:4] == (4, 4, 1, 8)


def test_quantized_specs_key_the_int8_family(reference_table):
    """An int8-KV launch must not look up (or mislabel) bf16 cells: the
    spec's ``kv_dtype`` reaches the workload's family key, the reference
    table now commits int8 cells, and an fp8 spec — same byte width —
    must never be served from them."""
    from repro.plan import AttentionSpec
    spec = AttentionSpec.decode(1, 512, 64, 1, 128, kv_dtype="int8")
    assert spec.workload().dtype_bytes == 1
    planner = Planner(policy="measured", table=reference_table)
    assert planner.plan(spec).tuned            # int8 family is committed
    # fp8 shares dtype_bytes=1 but keys a distinct (uncommitted) family:
    # the NAME, not the width, is the key — counted fallback, not tuned
    fp8 = AttentionSpec.decode(1, 512, 64, 1, 128, kv_dtype="fp8")
    assert fp8.workload().dtype_bytes == 1
    before = reference_table.fallbacks
    assert not planner.plan(fp8).tuned
    assert reference_table.fallbacks == before + 1
    # wallclock now times int8 cells through the fused-quant harness
    int8_spec = TuneSpec(lk_buckets=(512,), batches=(1,),
                         head_shapes=((64, 1, 128),), dtypes=("int8",))
    t8 = Calibrator(int8_spec, mode="wallclock", seed=0).calibrate()
    assert all(e["source"] == "wallclock" for e in t8.entries)
    assert t8.fingerprint["sources"] == "measured"
    assert Planner(policy="measured", table=t8).plan(spec).tuned
    # and the engine keys its lookups on the serve-config kv dtype
    cfg = reduced_config("qwen2.5-3b", num_layers=1, d_model=32)
    model = build_model(cfg)
    eng = ServingEngine(model, ServeConfig(model=cfg,
                                           split_policy="measured",
                                           kv_cache_dtype="int8"),
                        tune_table=reference_table)
    assert eng.sched.decode_spec(128).workload().dtype_bytes == 1


def test_measured_impl_reaches_table_from_every_path():
    """The impl family must be selectable through choose_num_splits /
    mesh planning, not only Planner.plan (regression: mesh plans of a
    pallas-calibrated table silently looked up the xla family)."""
    spec = TuneSpec(lk_buckets=(512,), batches=(1,),
                    head_shapes=((16, 4, 128),), impls=("pallas",))
    t = Calibrator(spec, mode="modeled", seed=0).calibrate()
    w = DecodeWorkload(1, 1, 512, 16, 4, 128)
    assert t.covers(w, impl="pallas") and not t.covers(w)
    s = choose_num_splits(w, policy="measured", table=t, impl="pallas")
    assert (s, True) == t.choose(w, impl="pallas")
    assert t.fallbacks == 0
    # H_KV=4 divides the 4-axis -> the occupancy (not storage-forced)
    # mesh path runs, and both its kernel plan and its mesh-splits
    # decision must hit the pallas family
    mesh_plan = Planner(policy="measured", table=t,
                        impl="pallas").mesh_plan(
        AttentionSpec.decode(1, 512, 16, 4, 128), axis_size=4)
    assert t.fallbacks == 0, "mesh planning must hit the pallas family"
    assert mesh_plan.tuned


def test_stats_to_json_round_trips_counters(small_table):
    from repro.plan import PlanCacheStats
    st_obj = PlanCacheStats()
    st_obj.hits = 2
    st_obj.record_launch(128)
    st_obj.record_launch(("prefill", 256))
    st_obj.record_fallback(100, 512)
    st_obj.record_measured((1, 4, 1, 8, "xla", 2, 128), fallback=True)
    d = json.loads(json.dumps(st_obj.to_json()))
    assert d["launches"] == {"128": 1, "prefill/256": 1}
    assert d["fallback_trace"] == [[100, 512]]
    assert d["measured_lookups"] == 1 and d["measured_fallbacks"] == 1
    assert d["measured_fallback_trace"] == [[1, 4, 1, 8, "xla", 2, 128]]
    st_obj.reset()
    assert st_obj.measured_lookups == 0
    assert st_obj.to_json()["measured_fallback_trace"] == []
