"""Multi-device correctness (8 forced host devices, run in subprocesses —
the main pytest process must keep seeing 1 device per the dry-run rules).

Covers: fused shard_map split decode == auto-SPMD == single-device
oracle; FSDP+TP train step == single-device step; compressed-DP grads
== exact grads (within int8 tolerance).
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.multidevice

REPO = Path(__file__).resolve().parents[1]


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    code = "from repro.compat import make_mesh\n" + textwrap.dedent(code)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, env=env,
                       timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_fused_seqsharded_decode_matches_oracle():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.kernels import ops, ref

        mesh = make_mesh((2, 4), ("data", "model"))
        B, L, Hkv, G, D = 4, 64, 1, 8, 32
        rng = jax.random.PRNGKey(0)
        ks = jax.random.split(rng, 5)
        q = jax.random.normal(ks[0], (B, Hkv*G, D), jnp.float32)
        ck = jax.random.normal(ks[1], (B, L, Hkv, D), jnp.float32)
        cv = jax.random.normal(ks[2], (B, L, Hkv, D), jnp.float32)
        kn = jax.random.normal(ks[3], (B, Hkv, D), jnp.float32)
        vn = jax.random.normal(ks[4], (B, Hkv, D), jnp.float32)
        t = jnp.array([10, 3, 63, 0], jnp.int32)
        kv_len = t + 1

        # single-device oracle: update then naive attention
        def upd(c, new, ti):
            return jax.lax.dynamic_update_slice(
                c, new[None], (ti, jnp.zeros((), jnp.int32),
                               jnp.zeros((), jnp.int32)))
        ck_ref = jax.vmap(upd)(ck, kn, t)
        cv_ref = jax.vmap(upd)(cv, vn, t)
        want = ref.naive_decode_attention(q, ck_ref, cv_ref, kv_len)

        from repro.plan import LaunchPlan, plan_scope
        plan = LaunchPlan(kind="decode", seq_shard_mesh=mesh,
                          seq_shard_axis="model")
        cache_sh = NamedSharding(mesh, P("data", "model", None, None))
        ckd = jax.device_put(ck, cache_sh)
        cvd = jax.device_put(cv, cache_sh)
        with plan_scope(plan):
            out, nk, nv = jax.jit(
                lambda *a: ops.decode_attention_update(*a)
            )(q, ckd, cvd, kn, vn, t, kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(nk), np.asarray(ck_ref),
                                   rtol=0, atol=0)
        print("fused decode OK")
    """)


def test_fused_decode_mla_latent_matches_oracle():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.kernels import ops, ref

        mesh = make_mesh((1, 8), ("data", "model"))
        B, L, H, W, R = 2, 64, 8, 40, 32
        rng = jax.random.PRNGKey(1)
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (B, H, W), jnp.float32)
        lat = jax.random.normal(ks[1], (B, L, 1, W), jnp.float32)
        new = jax.random.normal(ks[2], (B, 1, W), jnp.float32)
        t = jnp.array([5, 33], jnp.int32)
        kv_len = t + 1

        def upd(c, n, ti):
            return jax.lax.dynamic_update_slice(
                c, n[None], (ti, jnp.zeros((), jnp.int32),
                             jnp.zeros((), jnp.int32)))
        lat_ref = jax.vmap(upd)(lat, new, t)
        want = ref.naive_decode_attention(q, lat_ref, lat_ref[..., :R],
                                          kv_len, scale=1.0)

        from repro.plan import LaunchPlan, plan_scope
        plan = LaunchPlan(kind="decode", seq_shard_mesh=mesh)
        latd = jax.device_put(lat, NamedSharding(mesh, P(None, "model",
                                                         None, None)))
        with plan_scope(plan):
            out, nl, _ = jax.jit(
                lambda *a: ops.decode_attention_update(
                    *a, v_width=R, scale=1.0)
            )(q, latd, None, new, None, t, kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        print("fused MLA decode OK")
    """)


def test_sharded_train_step_matches_single_device():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import (OptimizerConfig, ShapeConfig,
                                        TrainConfig)
        from repro.configs.reduced import reduced_config
        from repro.data.synthetic import DataConfig, SyntheticLM
        from repro.models import build_model
        from repro.training.train_step import build_train_step

        cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=64)
        model = build_model(cfg)
        shape = ShapeConfig("t", 16, 8, "train")
        tcfg = TrainConfig(model=cfg, shape=shape,
                           optimizer=OptimizerConfig(warmup_steps=1,
                                                     total_steps=8))
        data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 8, seed=2))
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

        def run(mesh_shape):
            mesh = make_mesh(mesh_shape, ("data", "model"))
            b = build_train_step(model, tcfg, mesh)
            params, opt = b.init(jax.random.PRNGKey(0))
            for _ in range(2):
                params, opt, m = b.step(params, opt, batch)
            return float(m["loss"]), params

        l_single, p_single = run((1, 1))
        l_dp_tp, p_dp_tp = run((2, 4))
        assert abs(l_single - l_dp_tp) < 1e-2, (l_single, l_dp_tp)
        for a, b_ in zip(jax.tree.leaves(p_single),
                         jax.tree.leaves(p_dp_tp)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b_, np.float32),
                rtol=0.1, atol=0.05)
        print("sharded train step OK", l_single, l_dp_tp)
    """)


def test_compressed_dp_grads_close_to_exact():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.training.compression import (
            build_compressed_dp_grads, init_error_feedback)

        mesh = make_mesh((8,), ("data",))
        W = jax.random.normal(jax.random.PRNGKey(0), (16, 16))
        X = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
        Y = jax.random.normal(jax.random.PRNGKey(2), (32, 16))

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            return jnp.mean((pred - batch["y"]) ** 2), {}

        params = {"w": W}
        batch = {"x": X, "y": Y}
        exact = jax.grad(lambda p: loss_fn(p, batch)[0])(params)

        gf = build_compressed_dp_grads(loss_fn, mesh)
        ef = init_error_feedback(params)
        loss, grads, ef = jax.jit(gf)(params, batch, ef)
        # one-shot int8 bounds the ABSOLUTE error by ~scale/2 per replica
        # (relative error on near-zero entries is unbounded; the EF buffer
        # compensates across steps — see test_training.py)
        diff = np.abs(np.asarray(grads["w"]) - np.asarray(exact["w"]))
        scale = np.abs(np.asarray(exact["w"])).max()
        assert diff.max() / scale < 0.02, diff.max() / scale
        print("compressed DP grads OK, scaled max err", diff.max() / scale)
    """)


def test_moe_ep_shard_map_matches_gather():
    run_py("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs.reduced import reduced_config
        from repro.models import moe as moe_mod
        from repro.models.common import init_params
        from repro.sharding.ctx import activation_mesh

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = reduced_config("qwen3-moe-235b-a22b", d_model=32)
        # capacity high enough that neither path drops tokens: results
        # must then agree exactly (E=8 pads to 8 on a 4-axis: ok)
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
        params = init_params(moe_mod.moe_specs(cfg), jax.random.PRNGKey(0))
        params = jax.tree.map(lambda a: a.astype(jnp.float32), params)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32),
                              jnp.float32)

        ref_out, _ = moe_mod.apply_moe(params, cfg, x, dispatch="gather")
        with activation_mesh(mesh):
            ep_out, _ = jax.jit(lambda p, xx: moe_mod.apply_moe(
                p, cfg, xx, dispatch="ep_shard_map"))(params, x)
        np.testing.assert_allclose(np.asarray(ep_out),
                                   np.asarray(ref_out),
                                   rtol=2e-4, atol=2e-4)
        print("MoE EP shard_map OK")
    """)


def test_seqpar_attention_matches_reference():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels import ops, ref

        mesh = make_mesh((2, 4), ("data", "model"))
        B, L, H, D = 2, 64, 5, 16      # 5 heads: not divisible by 4
        rng = jax.random.PRNGKey(0)
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (B, L, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, L, 1, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, L, 1, D), jnp.float32)
        want = ref.naive_attention(q, k, v, causal=True)
        from repro.plan import LaunchPlan, plan_scope
        plan = LaunchPlan(kind="prefill", seq_shard_mesh=mesh)
        with plan_scope(plan):
            got = jax.jit(lambda *a: ops.attention(*a, causal=True))(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        # windowed variant (hybrid local attention)
        want_w = ref.naive_attention(q, k, v, causal=True, window=16)
        with plan_scope(plan):
            got_w = jax.jit(lambda *a: ops.attention(
                *a, causal=True, window=16))(q, k, v)
        np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                                   rtol=2e-5, atol=2e-5)
        print("seq-parallel attention OK")
    """)


def test_elastic_remesh_restore():
    """Checkpoint on a (2,4) mesh, resume on (4,2) — same final loss as
    an uninterrupted run: the elastic-restart story end to end."""
    run_py("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import (OptimizerConfig, ShapeConfig,
                                        TrainConfig)
        from repro.configs.reduced import reduced_config
        from repro.data.synthetic import DataConfig, SyntheticLM
        from repro.fault.elastic import resumable_train_loop
        from repro.models import build_model
        from repro.training.train_step import build_train_step

        cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=64)
        model = build_model(cfg)
        shape = ShapeConfig("t", 16, 8, "train")
        tcfg = TrainConfig(model=cfg, shape=shape,
                           optimizer=OptimizerConfig(warmup_steps=2,
                                                     total_steps=20))
        data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 8, seed=9))
        quiet = lambda s: None

        def mk(mesh_shape):
            mesh = make_mesh(mesh_shape, ("data", "model"))
            return build_train_step(model, tcfg, mesh)

        with tempfile.TemporaryDirectory() as d:
            ref = resumable_train_loop(mk((2, 4)), data, total_steps=10,
                                       ckpt_dir=d + "/ref", ckpt_every=100,
                                       async_ckpt=False, log_fn=quiet)
            # phase 1 on (2,4), checkpoint at step 5, crash at 6
            try:
                resumable_train_loop(mk((2, 4)), data, total_steps=10,
                                     ckpt_dir=d + "/el", ckpt_every=6,
                                     async_ckpt=False, fail_at_step=7,
                                     log_fn=quiet)
            except RuntimeError:
                pass
            # phase 2: the cluster "shrank/regrew" -> new mesh (4,2)
            out = resumable_train_loop(mk((4, 2)), data, total_steps=10,
                                       ckpt_dir=d + "/el", ckpt_every=6,
                                       async_ckpt=False, log_fn=quiet)
        assert abs(out["loss"] - ref["loss"]) < 1e-2, (out, ref)
        print("elastic re-mesh OK", out["loss"], ref["loss"])
    """)


def test_dryrun_single_cell_production_mesh():
    """One full production-mesh cell end-to-end (512 virtual devices)."""
    run_py("""
        from repro.launch.dryrun import run_cell
        rec = run_cell("mamba2-780m", "decode_32k")
        assert rec["status"] == "ok", rec
        assert rec["chips"] == 256
        print("dryrun cell OK:", rec["roofline"]["dominant"])
    """, devices=512)
