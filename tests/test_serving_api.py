"""Request-lifecycle serving API: scheduling invariants, sampling,
streaming, fused-prefill plan accounting, legacy bit-equality."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.kernels import ops
from repro.plan import AttentionSpec, PlanCache, Planner, bucket_seqlen
from repro.models import build_model
from repro.serving import (
    FINISHED,
    TOKEN,
    DecodeEngine,
    Request,
    SamplingParams,
    ServingEngine,
)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(cfg, model, params, slots, *, max_len=64, **kw):
    eng = ServingEngine(model, ServeConfig(model=cfg), max_len=max_len,
                        batch_slots=slots, **kw)
    eng.load(params)
    return eng


def _reqs(sampling=None, lens=(3, 9, 2, 5), max_new=(6, 4, 8, 5)):
    sampling = sampling or SamplingParams()
    return [Request(i, [(7 * i + j) % 200 + 1 for j in range(n)],
                    max_new_tokens=m, sampling=sampling)
            for i, (n, m) in enumerate(zip(lens, max_new))]


# ---------------------------------------------------------------------------
# Scheduling invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_tokens_independent_of_slot_packing(tiny_model, temperature):
    """Same request -> same tokens for batch_slots in {1, 2, 4}, greedy
    AND seeded sampling, with staggered lengths forcing mid-flight
    refills next to live slots (the slot-reset helper must only touch
    the admitted slot)."""
    cfg, model, params = tiny_model
    sp = SamplingParams(temperature=temperature, top_k=16, top_p=0.95,
                        seed=13)
    results = []
    for slots in (1, 2, 4):
        eng = _engine(cfg, model, params, slots)
        for r in _reqs(sp):
            eng.submit(r)
        results.append([c.tokens for c in eng.drain()])
    assert results[0] == results[1] == results[2]


def test_submit_mid_flight_and_drain(tiny_model):
    """Requests submitted while others are decoding still complete, and
    drain returns every undrained completion exactly once."""
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 2)
    eng.submit(Request(0, [1, 2, 3], max_new_tokens=6))
    eng.step()
    eng.step()
    eng.submit(Request(1, [4, 5], max_new_tokens=3))
    done = eng.drain()
    assert [c.request_id for c in done] == [0, 1]
    assert [len(c.tokens) for c in done] == [6, 3]
    assert eng.drain() == []                     # nothing left undrained


def test_step_events_cover_every_token(tiny_model):
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 2)
    for r in _reqs(lens=(3, 5), max_new=(4, 3)):
        eng.submit(r)
    events = []
    while eng.has_work():
        events += eng.step()
    done = eng.drain()
    toks = {c.request_id: [e.token for e in events
                           if e.kind == TOKEN and e.request_id
                           == c.request_id] for c in done}
    assert all(toks[c.request_id] == c.tokens for c in done)
    fins = [e for e in events if e.kind == FINISHED]
    assert sorted(e.request_id for e in fins) == [0, 1]
    assert all(e.finish_reason == "length" for e in fins)


def test_invalid_requests_rejected_before_any_state(tiny_model):
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 1)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(0, []))
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(Request(1, list(range(64)), max_new_tokens=1))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(2, [1, 2], max_new_tokens=0))
    assert not eng.has_work()


def test_greedy_sampler_rejects_sampled_requests(tiny_model):
    """A GreedySampler engine must fail fast on requests whose sampling
    knobs it would silently ignore (e.g. CLI --sampler greedy
    --temperature 0.8)."""
    from repro.serving import GreedySampler
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 1, sampler=GreedySampler())
    with pytest.raises(ValueError, match="GreedySampler ignores"):
        eng.submit(Request(0, [1, 2],
                           sampling=SamplingParams(temperature=0.5)))
    eng.submit(Request(1, [1, 2], max_new_tokens=3))     # greedy is fine
    assert len(eng.drain()[0].tokens) == 3


# ---------------------------------------------------------------------------
# Streaming
# ---------------------------------------------------------------------------


def test_stream_event_ordering_per_handle(tiny_model):
    """stream(handle) yields that handle's TOKEN events with contiguous
    indices, terminated by exactly one FINISHED — even while another
    handle decodes in the same lockstep."""
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 2)
    h0 = eng.submit(Request(0, [1, 2, 3], max_new_tokens=5))
    h1 = eng.submit(Request(1, [9, 8], max_new_tokens=3))
    evs0 = list(eng.stream(h0))
    assert [e.kind for e in evs0] == [TOKEN] * 5 + [FINISHED]
    assert [e.index for e in evs0[:-1]] == list(range(5))
    assert all(e.request_id == 0 for e in evs0)
    # h1 finished during h0's stream; its queued events replay in order
    evs1 = list(eng.stream(h1))
    assert [e.kind for e in evs1] == [TOKEN] * 3 + [FINISHED]
    assert [e.index for e in evs1[:-1]] == [0, 1, 2]
    # streamed-to-FINISHED handles are fully released: drain has nothing
    # left and a second stream raises a clear error, so a streaming-only
    # server holds no per-request state
    assert eng.drain() == []
    assert not eng._completions and not eng._queues
    with pytest.raises(ValueError, match="unknown, already streamed"):
        next(eng.stream(h0))


def test_mid_stream_drain_does_not_double_deliver(tiny_model):
    """drain() releasing a handle mid-stream must stop the generator —
    not replay the drained tokens from an orphaned queue."""
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 1)
    h = eng.submit(Request(0, [1, 2], max_new_tokens=4))
    it = eng.stream(h)
    next(it)                                     # consume one TOKEN
    done = eng.drain()                           # delivers everything
    assert len(done[0].tokens) == 4
    assert list(it) == []


# ---------------------------------------------------------------------------
# Finish reasons (incl. the cache-capacity satellite)
# ---------------------------------------------------------------------------


def test_cache_capacity_finish_reason_and_single_warning(tiny_model):
    """A slot hitting max_len - 1 mid-generation used to 'finish'
    indistinguishably from EOS; it must now surface as
    finish_reason='cache_capacity' and warn once per engine."""
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 1, max_len=32)
    eng.submit(Request(0, [1] * 20, max_new_tokens=100))
    eng.submit(Request(1, [2] * 20, max_new_tokens=100))
    with pytest.warns(RuntimeWarning, match="cache_capacity") as rec:
        done = eng.drain()
    assert [c.finish_reason for c in done] == ["cache_capacity"] * 2
    # prompt rows 0..19, generated rows 20..30; stops when the next
    # write position reaches max_len - 1 = 31 (pre-redesign cutoff)
    assert [len(c.tokens) for c in done] == [12, 12]
    assert len([w for w in rec
                if issubclass(w.category, RuntimeWarning)]) == 1


def test_eos_stop_and_length_reasons(tiny_model):
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 1)
    eng.submit(Request(0, [1, 2, 3], max_new_tokens=6))
    ref = eng.drain()[0]
    assert ref.finish_reason == "length"
    # replay greedily: the 2nd token as eos, then as a stop token
    eng2 = _engine(cfg, model, params, 1)
    eng2.submit(Request(0, [1, 2, 3], max_new_tokens=6,
                        eos_id=ref.tokens[1]))
    out = eng2.drain()[0]
    assert out.tokens == ref.tokens[:2] and out.finish_reason == "eos"
    eng3 = _engine(cfg, model, params, 1)
    eng3.submit(Request(0, [1, 2, 3], max_new_tokens=6,
                        sampling=SamplingParams(stop=(ref.tokens[1],))))
    out = eng3.drain()[0]
    assert out.tokens == ref.tokens[:2] and out.finish_reason == "stop"


# ---------------------------------------------------------------------------
# Fused bucketed prefill: plan accounting (paper's O(1)-launch claim)
# ---------------------------------------------------------------------------


def test_fused_prefill_o1_launches_and_bucket_reuse(tiny_model):
    """Each admission is exactly ONE planned prefill launch; prefill
    plans live in the same PlanCache as decode plans, keyed per
    prompt-length bucket, and same-bucket prompts re-use the plan
    (hits, not recompiles).  The policy never runs in-trace."""
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 2, max_len=300)
    ops.reset_policy_eval_count()
    # three prompts in the 128 bucket, one in the 256 bucket
    for i, n in enumerate((5, 40, 100, 200)):
        eng.submit(Request(i, [1 + i] * n, max_new_tokens=3))
    eng.drain()
    st = eng.stats
    assert ops.policy_eval_count() == 0
    assert eng.planned_prefill_buckets() == [128, 256]
    assert st.launches[("prefill", 128)] == 3    # reused across prompts
    assert st.launches[("prefill", 256)] == 1
    pre_launches = sum(v for k, v in st.launches.items()
                       if isinstance(k, tuple))
    assert pre_launches == 4                     # == admissions: O(1) each
    pre_misses = sum(1 for k in st.seen_buckets if isinstance(k, tuple))
    assert pre_misses == 2                       # one compile per bucket
    # decode plans ride the same cache, under their legacy int keys
    assert set(eng.planned_splits()) <= {128, 256, 384}


def test_fused_prefill_matches_loop_prefill_tokens(tiny_model):
    cfg, model, params = tiny_model
    out = {}
    for mode in ("fused", "loop"):
        eng = _engine(cfg, model, params, 2, prefill_mode=mode)
        for r in _reqs():
            eng.submit(r)
        out[mode] = [c.tokens for c in eng.drain()]
    assert out["fused"] == out["loop"]


@pytest.mark.parametrize("arch", ["minicpm3-4b", "whisper-large-v3"])
def test_fused_prefill_other_families(arch):
    """MLA (latent cache) and encdec (self+cross caches) support the
    single-slot fused prefill and agree with teacher-forcing."""
    cfg = reduced_config(arch, num_layers=2, d_model=32)
    model = build_model(cfg)
    assert model.supports_fused_prefill
    params = model.init_params(jax.random.PRNGKey(0))
    out = {}
    for mode in ("fused", "loop"):
        eng = ServingEngine(model, ServeConfig(model=cfg), max_len=64,
                            batch_slots=2, prefill_mode=mode)
        eng.load(params)
        eng.submit(Request(0, [1, 2, 3], max_new_tokens=4))
        eng.submit(Request(1, [4] * 9, max_new_tokens=4))
        out[mode] = [c.tokens for c in eng.drain()]
    assert out["fused"] == out["loop"]


def test_recurrent_families_gate_fused_prefill():
    cfg = reduced_config("mamba2-780m", num_layers=2, d_model=32)
    model = build_model(cfg)
    assert not model.supports_fused_prefill
    with pytest.raises(ValueError, match="loop"):
        ServingEngine(model, ServeConfig(model=cfg), max_len=64,
                      batch_slots=1, prefill_mode="fused")
    # auto resolves to loop and works
    eng = ServingEngine(model, ServeConfig(model=cfg), max_len=64,
                        batch_slots=1)
    assert eng.prefill_mode == "loop"


# ---------------------------------------------------------------------------
# Legacy wrapper: bit-equality against the pre-redesign engine
# ---------------------------------------------------------------------------


def _reference_generate(model, scfg, params, requests, *, max_len,
                        batch_slots):
    """Faithful port of the pre-redesign ``DecodeEngine.generate``
    (greedy argmax, metadata path, per-bucket specialized steps, eager
    un-jitted slot zeroing) — the bit-equality oracle for the wrapper.

    One deliberate divergence: the old ``_zero_slot`` indexed the LAYER
    axis (``a.at[i]``), zeroing layer ``i`` of every slot — with
    staggered request lengths that corrupts live neighbours' KV, the
    exact bug this PR fixes.  The oracle zeroes the batch column
    (``a.at[:, i]``) so it oracles everything *except* the fixed bug:
    bucket selection, plan specialization, launch order, argmax."""
    cfg = model.cfg
    B = batch_slots
    planner = Planner(policy=scfg.split_policy,
                      num_splits_override=scfg.num_splits_override)
    plans = PlanCache(scfg.plan_cache_capacity)
    caches = model.init_cache(B, max_len)

    def step_impl(params, caches, token, t, plan=None):
        logits, caches = model.decode_step(params, caches, token, t,
                                           plan=plan,
                                           policy=scfg.split_policy)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    def plan_step(t_max):
        lk = bucket_seqlen(min(int(t_max) + 1, max_len),
                           scfg.seqlen_bucket)

        def build():
            spec = AttentionSpec.decode(
                B, lk, cfg.num_heads,
                1 if cfg.mla else cfg.num_kv_heads, cfg.resolved_head_dim)
            plan = planner.plan(spec, bucket=lk)
            return jax.jit(functools.partial(step_impl, plan=plan),
                           donate_argnums=(1,))

        return plans.get_or_build(lk, build)

    pending = list(requests)
    slots = [None] * B
    budget, eos = [0] * B, [None] * B
    slot_pos = np.zeros(B, np.int32)
    slot_prompt_left = [[] for _ in range(B)]
    next_token = np.zeros(B, np.int32)
    done = []

    def refill(i):
        nonlocal caches
        if not pending:
            return
        req = pending.pop(0)
        slots[i] = {"id": req.request_id, "tokens": []}
        budget[i], eos[i] = req.max_new_tokens, req.eos_id
        slot_prompt_left[i] = list(req.prompt)
        slot_pos[i] = 0
        next_token[i] = slot_prompt_left[i].pop(0)
        caches = jax.tree.map(
            lambda a: a.at[:, i].set(jnp.zeros_like(a[:, i])), caches)

    for i in range(B):
        refill(i)
    while any(s is not None for s in slots):
        t_max = max(int(slot_pos[i]) for i, s in enumerate(slots)
                    if s is not None)
        out, caches = plan_step(t_max)(params, caches,
                                       jnp.asarray(next_token),
                                       jnp.asarray(slot_pos))
        out = np.asarray(out)
        for i, comp in enumerate(slots):
            if comp is None:
                continue
            slot_pos[i] += 1
            if slot_prompt_left[i]:
                next_token[i] = slot_prompt_left[i].pop(0)
                continue
            tok = int(out[i])
            comp["tokens"].append(tok)
            if (len(comp["tokens"]) >= budget[i]
                    or (eos[i] is not None and tok == eos[i])
                    or slot_pos[i] >= max_len - 1):
                done.append(comp)
                slots[i] = None
                refill(i)
            else:
                next_token[i] = tok
    done.sort(key=lambda c: c["id"])
    return [c["tokens"] for c in done]


def test_legacy_wrapper_bit_identical_greedy(tiny_model):
    """DecodeEngine.generate must reproduce the pre-redesign engine's
    greedy completions bit-exactly: serial refills, bucket crossings,
    and an EOS mid-batch."""
    cfg, model, params = tiny_model
    scfg = ServeConfig(model=cfg)

    def mk():
        return [Request(0, [9, 8, 7], max_new_tokens=4),
                Request(1, [5, 5], max_new_tokens=6),
                Request(2, [1, 2, 3, 4, 5], max_new_tokens=8),
                Request(3, [2] * 140, max_new_tokens=10),  # 256 bucket
                Request(4, [6], max_new_tokens=12)]

    for slots in (1, 3):
        eng = DecodeEngine(model, scfg, max_len=300, batch_slots=slots)
        eng.load(params)
        got = [c.tokens for c in eng.generate(mk())]
        want = _reference_generate(model, scfg, params, mk(),
                                   max_len=300, batch_slots=slots)
        assert got == want, f"greedy drift at batch_slots={slots}"


def test_legacy_wrapper_bit_identical_with_eos(tiny_model):
    cfg, model, params = tiny_model
    scfg = ServeConfig(model=cfg)
    probe = DecodeEngine(model, scfg, max_len=64, batch_slots=1)
    probe.load(params)
    toks = probe.generate([Request(0, [3, 1], max_new_tokens=6)])[0].tokens
    reqs = lambda: [Request(0, [3, 1], max_new_tokens=6, eos_id=toks[2]),
                    Request(1, [2, 2], max_new_tokens=5)]
    eng = DecodeEngine(model, scfg, max_len=64, batch_slots=2)
    eng.load(params)
    got = [c.tokens for c in eng.generate(reqs())]
    want = _reference_generate(model, scfg, params, reqs(),
                               max_len=64, batch_slots=2)
    assert got == want
    assert got[0][-1] == toks[2]                 # actually cut by eos
