"""Roofline tooling: HLO collective parser, probe math, memory model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import COLLECTIVES, collective_bytes, wire_bytes


HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = bf16[16,4096]{1,0} parameter(0)
  %ag = bf16[256,4096]{1,0} all-gather(bf16[16,4096]{1,0} %p0), dimensions={0}
  %ar = f32[128,128]{1,0} all-reduce(f32[128,128]{1,0} %x), to_apply=%sum
  %rs = (f32[8,64]{1,0}, f32[8,64]{1,0}) reduce-scatter(f32[64,64]{1,0} %y, f32[64,64]{1,0} %z)
  %a2a = bf16[4,32]{1,0} all-to-all(bf16[4,32]{1,0} %w), dimensions={0}
  %cp = u32[10]{0} collective-permute(u32[10]{0} %v), source_target_pairs={{0,1}}
  %ags = bf16[2,2]{1,0} all-gather-start(bf16[1,2]{1,0} %q)
}
"""


def test_collective_parser_categories():
    by = collective_bytes(HLO_SAMPLE)
    assert by["all-gather"] == 256 * 4096 * 2 + 2 * 2 * 2  # incl. -start
    assert by["all-reduce"] == 128 * 128 * 4
    assert by["reduce-scatter"] == 2 * 8 * 64 * 4          # tuple result
    assert by["all-to-all"] == 4 * 32 * 2
    assert by["collective-permute"] == 10 * 4


def test_wire_bytes_ring_model():
    by = {c: 0 for c in COLLECTIVES}
    by["all-reduce"] = 100
    by["all-gather"] = 50
    assert wire_bytes(by) == 2 * 100 + 50


def test_parser_on_real_compiled_module():
    """End to end: a jitted psum over 1 device still emits no collectives;
    the parser must return zeros, not crash."""
    f = jax.jit(lambda x: x * 2 + 1)
    txt = f.lower(jnp.ones((8, 8))).compile().as_text()
    by = collective_bytes(txt)
    assert all(v == 0 for v in by.values())


def test_analytic_memory_decode_scales_with_cache():
    from repro.configs import SHAPES, get_arch
    from repro.roofline.probe import analytic_memory_bytes

    class FakeDevs:
        size = 256
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
        devices = FakeDevs()

    cfg = get_arch("qwen2.5-3b")
    m16 = analytic_memory_bytes(cfg, SHAPES["decode_32k"], FakeMesh(),
                                microbatches=1, kind="decode",
                                seq_split=True)
    m8 = analytic_memory_bytes(cfg, SHAPES["decode_32k"], FakeMesh(),
                               microbatches=1, kind="decode",
                               seq_split=True, kv_dtype="int8")
    assert m8 < m16                        # int8 shrinks cache traffic
    mt = analytic_memory_bytes(cfg, SHAPES["train_4k"], FakeMesh(),
                               microbatches=4, kind="train")
    assert mt > m16                        # train streams params 12x


def test_flash_combine_kernel_vs_ref():
    from repro.kernels.flash_combine import flash_combine
    from repro.kernels import ref

    rng = jax.random.PRNGKey(0)
    S, B, H, G, D = 4, 2, 2, 8, 128
    ks = jax.random.split(rng, 3)
    acc = jax.random.normal(ks[0], (S, B, H, G, D), jnp.float32)
    l = jax.random.uniform(ks[1], (S, B, H, G), jnp.float32, 0.5, 2.0)
    m = jax.random.normal(ks[2], (S, B, H, G), jnp.float32)
    want = ref.lse_combine(acc, l, m)
    got = flash_combine(acc, l, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-6, atol=2e-6)


def test_flash_combine_bitwise_deterministic():
    from repro.kernels.flash_combine import flash_combine
    rng = jax.random.PRNGKey(7)
    acc = jax.random.normal(rng, (3, 1, 1, 4, 128), jnp.float32)
    l = jnp.ones((3, 1, 1, 4), jnp.float32)
    m = jax.random.normal(rng, (3, 1, 1, 4), jnp.float32)
    a = flash_combine(acc, l, m)
    b = flash_combine(acc, l, m)
    assert (np.asarray(a) == np.asarray(b)).all()
