"""Hypothesis compatibility shim: real package when installed, vendored
deterministic fallback otherwise.

The property tests (`test_kernels.py`, `test_policy_properties.py`,
`test_training.py`) import ``given`` / ``settings`` / ``strategies``
from here instead of from ``hypothesis`` directly, so tier-1 collection
works on a clean machine with no extra dependencies.  When the real
package is importable it is re-exported unchanged (full shrinking,
database, coverage-guided generation); the fallback below keeps the same
call surface and runs each property over a fixed-seed deterministic
sample — strictly weaker at finding new counterexamples, but it keeps
the invariants executable and regressions visible everywhere.
"""
from __future__ import annotations

try:                                        # pragma: no cover - env-dependent
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random
    from types import SimpleNamespace

    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 25
    _SEED = 0xC0FFEE

    class _Strategy:
        """A draw rule: deterministic given the shared Random instance."""

        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng: random.Random):
            return self._draw_fn(rng)

    def _integers(min_value: int, max_value: int) -> _Strategy:
        def draw(rng):
            # over-weight the endpoints — the cheap stand-in for
            # hypothesis's boundary-value bias
            r = rng.random()
            if r < 0.125:
                return min_value
            if r < 0.25:
                return max_value
            return rng.randint(min_value, max_value)
        return _Strategy(draw)

    def _floats(min_value: float, max_value: float, *, allow_nan: bool = True,
                allow_infinity: bool = True) -> _Strategy:
        def draw(rng):
            r = rng.random()
            if r < 0.1:
                return float(min_value)
            if r < 0.2:
                return float(max_value)
            if r < 0.3:
                return 0.0
            return rng.uniform(min_value, max_value)
        return _Strategy(draw)

    def _sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    def _just(value) -> _Strategy:
        return _Strategy(lambda rng: value)

    def _booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _lists(elements: _Strategy, *, min_size: int = 0,
               max_size: int | None = None) -> _Strategy:
        hi = max_size if max_size is not None else min_size + 16

        def draw(rng):
            n = rng.randint(min_size, hi)
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def _tuples(*parts: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(p.draw(rng) for p in parts))

    def _builds(target, *args: _Strategy, **kwargs: _Strategy) -> _Strategy:
        def draw(rng):
            return target(*(a.draw(rng) for a in args),
                          **{k: v.draw(rng) for k, v in kwargs.items()})
        return _Strategy(draw)

    strategies = SimpleNamespace(
        integers=_integers,
        floats=_floats,
        sampled_from=_sampled_from,
        just=_just,
        booleans=_booleans,
        lists=_lists,
        tuples=_tuples,
        builds=_builds,
    )

    def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
        """Decorator: records max_examples on the (given-wrapped) test."""
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
        """Run the test body over a fixed-seed deterministic sample."""
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                rng = random.Random(_SEED)
                for _ in range(n):
                    drawn_args = tuple(s.draw(rng) for s in arg_strategies)
                    drawn_kw = {k: s.draw(rng)
                                for k, s in kw_strategies.items()}
                    try:
                        fn(*args, *drawn_args, **kwargs, **drawn_kw)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (shim, seed={_SEED:#x}): "
                            f"args={drawn_args!r} kwargs={drawn_kw!r}"
                        ) from e

            # pytest must not mistake the drawn parameters for fixtures:
            # hide the wrapped signature and present a 0-arg test.
            del wrapper.__wrapped__
            params = [
                p for name, p in
                inspect.signature(fn).parameters.items()
                if name not in kw_strategies
            ]
            if arg_strategies:      # positional draws fill rightmost params
                params = params[:-len(arg_strategies)]
            wrapper.__signature__ = inspect.Signature(params)
            return wrapper
        return deco
