"""Training substrate: optimizer, schedules, microbatching, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.configs.base import OptimizerConfig, ShapeConfig, TrainConfig
from repro.configs.reduced import reduced_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.training.compression import (
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)
from repro.training.optimizer import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    lr_schedule,
)
from repro.training.train_step import build_train_step


def _bundle(micro=1, **okw):
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    mesh = make_host_mesh()
    shape = ShapeConfig("t", 16, 4, "train")
    tcfg = TrainConfig(model=cfg, shape=shape, microbatches=micro,
                       optimizer=OptimizerConfig(warmup_steps=2,
                                                 total_steps=50, **okw))
    return build_train_step(model, tcfg, mesh), cfg


def test_loss_decreases_on_memorization():
    bundle, cfg = _bundle(lr=3e-3)
    params, opt = bundle.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 4, seed=3))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    losses = []
    for _ in range(8):
        params, opt, m = bundle.step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9


def test_microbatching_matches_full_batch():
    """grad-accum over 4 microbatches == one big batch (same math)."""
    b1, cfg = _bundle(micro=1)
    b4, _ = _bundle(micro=4)
    p0, o0 = b1.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 4, seed=1))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    p1, _, m1 = b1.step(p0, o0, batch)
    p0b, o0b = b4.init(jax.random.PRNGKey(0))
    p4, _, m4 = b4.step(p0b, o0b, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.1, atol=2e-2)


def test_lr_schedule_shapes():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          schedule="cosine")
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]            # warmup rises
    assert lrs[2] == pytest.approx(1e-3, rel=1e-5)
    assert lrs[3] < lrs[2] and lrs[4] < lrs[3]  # cosine decays


def test_grad_clip():
    tree = {"a": jnp.full((10,), 10.0), "b": jnp.full((10,), -10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(2000.0), rel=1e-5)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_adamw_step_reference():
    """One AdamW step against a hand-computed reference."""
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=1,
                          schedule="constant", weight_decay=0.0,
                          grad_clip_norm=0.0, b1=0.9, b2=0.999, eps=1e-8)
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([0.5, -0.5])}
    state = adamw_init(params)
    new, state, _ = adamw_update(grads, state, params, cfg)
    # bias-corrected first step: update = lr * g / (|g| + eps) = lr*sign
    np.testing.assert_allclose(np.asarray(new["w"]),
                               [1.0 - 0.1, 2.0 + 0.1], rtol=1e-4)


# ---------------------------------------------------------------------------
# int8 + error-feedback compression
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4,
                max_size=64))
def test_quantize_roundtrip_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0
    q = quantize_int8(x, scale)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert (err <= float(scale) * 0.5 + 1e-6).all()


def test_error_feedback_is_unbiased_over_steps():
    """Sum of EF-compressed grads converges to the sum of true grads."""
    rng = np.random.default_rng(0)
    g_true = rng.normal(size=(100,)).astype(np.float32)
    ef = jnp.zeros(100, jnp.float32)
    tot_c = np.zeros(100, np.float32)
    for step in range(50):
        g = jnp.asarray(g_true)
        gf = g + ef
        scale = jnp.max(jnp.abs(gf)) / 127.0
        q = quantize_int8(gf, scale)
        deq = dequantize_int8(q, scale)
        ef = gf - deq
        tot_c += np.asarray(deq)
    # mean compressed grad ~= true grad (EF pushes residual forward)
    np.testing.assert_allclose(tot_c / 50, g_true, atol=2e-2)
