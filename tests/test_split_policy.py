"""The paper's policy, bit-exact against Fig. 2 + Table 1 + §5.3."""
import math

import pytest

from repro.core.split_policy import (
    KV_BLOCK,
    DecodeWorkload,
    choose_mesh_splits,
    choose_num_splits,
    fa3_baseline,
    paper_policy,
    tpu_adaptive,
)


def w(batch=1, lk=512, hq=64, hkv=1, lq=1, d=128):
    return DecodeWorkload(batch, lq, lk, hq, hkv, d)


class TestPaperFig2:
    """The C++ policy decision table, literally (paper Fig. 2)."""

    @pytest.mark.parametrize("lk", [1, 128, 256, 384])
    @pytest.mark.parametrize("hkv", [1, 2, 4, 8, 32])
    def test_guard1_short_contexts_unchanged(self, lk, hkv):
        # nblk <= 3 -> s = 1 no matter how starved
        assert paper_policy(w(lk=lk, hkv=hkv)) == 1

    @pytest.mark.parametrize("batch,hkv", [(1, 4), (1, 8), (2, 2),
                                           (4, 1), (8, 8), (2, 32)])
    def test_guard2_saturated_boundary_unchanged(self, batch, hkv):
        # nblk = 4 with tiles >= 4 -> s = 1
        wl = w(batch=batch, lk=512, hkv=hkv)
        assert wl.num_n_blocks == 4 and wl.total_mblocks >= 4
        assert paper_policy(wl) == 1

    @pytest.mark.parametrize("batch,hkv", [(1, 1), (1, 2)])
    def test_low_tile_boundary_override_s3(self, batch, hkv):
        # nblk = 4 and tiles < 4 -> s = 3 (the paper's single override)
        wl = w(batch=batch, lk=512, hkv=hkv)
        assert wl.num_n_blocks == 4 and wl.total_mblocks < 4
        assert paper_policy(wl) == 3

    def test_longer_contexts_fall_through_to_efficiency_loop(self):
        # nblk > 4: identical to the baseline's efficiency loop
        for lk in (640, 1024, 2048, 4096, 8192):
            for hkv in (1, 2, 8):
                wl = w(lk=lk, hkv=hkv)
                assert paper_policy(wl) == fa3_baseline(wl)


class TestBaselineFlaw:
    def test_static_guard_ignores_tiles(self):
        # the flaw: baseline returns 1 for L_K <= 512 even fully starved
        assert fa3_baseline(w(lk=512, hkv=1)) == 1
        assert fa3_baseline(w(lk=512, hkv=2)) == 1

    def test_nblk_math(self):
        assert w(lk=512).num_n_blocks == 512 // KV_BLOCK == 4
        assert w(lk=513).num_n_blocks == 5
        assert w(lk=1).num_n_blocks == 1


class TestTable1:
    """Paper Table 1: which (L_K, H_KV) cells change under the patch."""

    @pytest.mark.parametrize("lk", [128, 256, 384, 2048, 4096])
    @pytest.mark.parametrize("hkv", [1, 2, 8])
    def test_unchanged_rows(self, lk, hkv):
        assert paper_policy(w(lk=lk, hkv=hkv)) == \
            fa3_baseline(w(lk=lk, hkv=hkv))

    @pytest.mark.parametrize("hkv,expect", [(1, 3), (2, 3), (8, 1)])
    def test_512_rows(self, hkv, expect):
        assert paper_policy(w(lk=512, hkv=hkv)) == expect
        assert fa3_baseline(w(lk=512, hkv=hkv)) == 1


class TestSafetySweep:
    """§5.3: the paper's 160-config regression matrix, on the policy."""

    def test_no_policy_regression_vs_baseline(self):
        # the patched policy only ever *adds* splits in the starved
        # boundary bucket; everywhere else it equals the baseline
        for batch in (1, 2, 4, 8):
            for lk in (128, 256, 384, 512, 1024, 2048, 4096, 8192):
                for hkv in (1, 2, 4, 8, 32):
                    wl = w(batch=batch, lk=lk, hkv=hkv)
                    p, b = paper_policy(wl), fa3_baseline(wl)
                    if p != b:
                        assert wl.num_n_blocks == 4
                        assert wl.total_mblocks < 4
                        assert p == 3


class TestAdaptive:
    def test_splits_when_starved(self):
        s = tpu_adaptive(w(lk=4096, hkv=1), num_cores=16)
        assert s > 1

    def test_never_splits_when_saturated(self):
        s = tpu_adaptive(w(batch=8, lk=512, hkv=8), num_cores=8)
        assert s == 1

    def test_bounded_by_nblk(self):
        for lk in (128, 256, 512, 4096):
            wl = w(lk=lk, hkv=1)
            s = choose_num_splits(wl, policy="tpu_adaptive", num_cores=64)
            assert 1 <= s <= wl.num_n_blocks


class TestMeshSplits:
    def test_divides_axis(self):
        for chips in (4, 8, 16, 32):
            for hkv in (1, 2, 8, 20):
                s = choose_mesh_splits(w(lk=32768, hkv=hkv), chips)
                assert chips % s == 0

    def test_mqa_splits_full_axis_long_context(self):
        assert choose_mesh_splits(w(lk=32768, hkv=1), 16,
                                  policy="tpu_adaptive") > 1

    def test_saturated_heads_no_split(self):
        assert choose_mesh_splits(w(batch=16, lk=512, hkv=32), 16) == 1
