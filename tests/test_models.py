"""Per-architecture smoke + cross-path consistency on reduced configs.

The assignment requires a smoke test per assigned arch: instantiate a
REDUCED config of the same family and run one forward/train step on CPU
asserting output shapes + no NaNs.  We additionally check decode-loop
and prefill consistency (per-family tolerances: capacity-dropping MoE
and chunked-vs-sequential recurrences legitimately differ in low
precision; attention families are near-exact).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs
from repro.configs.base import OptimizerConfig, ShapeConfig, TrainConfig
from repro.configs.reduced import reduced_config
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models import encdec as encdec_mod
from repro.training.train_step import build_train_step

ARCHS = list_archs()
B, L = 2, 32

# cross-path relative tolerance per family (see module docstring)
TOL = {"dense": 5e-3, "vlm": 5e-3, "encdec": 5e-3,
       "mla": 4e-2, "moe": 5e-2, "ssm": 4e-2, "hybrid": 4e-2}

# families whose train path uses a different summation order than decode
# (associative scan vs sequential; grouped capacity dispatch): compare in
# f32 — with bf16 + random untrained weights the rounding noise is
# amplified unboundedly through near-argmax softmax (chaos, not a bug:
# f64 agreement is ~4e-6, verified during bring-up)
F32_FAMILIES = ("ssm", "hybrid", "moe", "mla")


def _maybe_f32(cfg, params, caches=None):
    if cfg.family not in F32_FAMILIES:
        return params, caches
    f32 = lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a
    params = jax.tree.map(f32, params)
    if caches is not None:
        caches = jax.tree.map(f32, caches)
    return params, caches


def _batch(model, cfg, rng):
    Lt = model.text_len(L)
    batch = {"tokens": jax.random.randint(rng, (B, Lt), 0,
                                          cfg.vocab_size)}
    for k, (shape, dt) in model.frontend_inputs(B, L).items():
        batch[k] = (jax.random.normal(rng, shape, jnp.float32) * 0.1
                    ).astype(dt)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    batch = _batch(model, cfg, rng)
    logits, aux = model.forward(params, batch)
    Ltot = batch["tokens"].shape[1] + (
        cfg.frontend.num_positions if cfg.frontend.kind == "vision" else 0)
    assert logits.shape == (B, Ltot, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    mesh = make_host_mesh()
    shape = ShapeConfig("t", L, B, "train")
    tcfg = TrainConfig(model=cfg, shape=shape,
                       optimizer=OptimizerConfig(warmup_steps=1,
                                                 total_steps=4))
    bundle = build_train_step(model, tcfg, mesh)
    params, opt = bundle.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    batch = _batch(model, cfg, rng)
    batch["labels"] = batch["tokens"]
    params, opt, metrics = bundle.step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert not any(bool(jnp.isnan(x).any())
                   for x in jax.tree.leaves(params))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = reduced_config(arch)
    if cfg.family == "vlm":
        pytest.skip("vlm decode starts from a prefilled patch prefix "
                    "(covered by test_prefill_then_decode)")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init_params(rng)
    batch = _batch(model, cfg, rng)
    tokens = batch["tokens"]
    Lt = tokens.shape[1]
    caches = model.init_cache(B, 48)
    params, caches = _maybe_f32(cfg, params, caches)
    logits_full, _ = model.forward(params, batch)
    if cfg.family == "encdec":
        memory = encdec_mod.encode(params, cfg, batch["frames"])
        cross = encdec_mod.build_cross_caches(params, cfg, memory)
        caches = {"self": caches["self"], "cross": cross}
    outs = []
    for i in range(Lt):
        lg, caches = model.decode_step(params, caches, tokens[:, i],
                                       jnp.int32(i))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    rel = float(jnp.max(jnp.abs(dec - logits_full))) / scale
    assert rel < TOL[cfg.family], f"{arch}: rel={rel}"


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(2)
    params = model.init_params(rng)
    params, _ = _maybe_f32(cfg, params)
    batch = _batch(model, cfg, rng)
    Lt = batch["tokens"].shape[1]
    Ltot = Lt + (cfg.frontend.num_positions
                 if cfg.frontend.kind == "vision" else 0)

    logits_pf, caches = model.prefill(params, batch, 48)
    logits_full, _ = model.forward(params, batch)
    scale = float(jnp.max(jnp.abs(logits_full[:, -1]))) + 1e-9
    rel = float(jnp.max(jnp.abs(logits_pf - logits_full[:, -1]))) / scale
    assert rel < TOL[cfg.family], f"{arch}: prefill rel={rel}"

    nxt = jnp.argmax(logits_pf, -1).astype(jnp.int32)
    lg, _ = model.decode_step(params, caches, nxt, jnp.int32(Ltot))
    assert lg.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(lg).any())


def test_vector_positions_match_scalar():
    """Per-slot t (continuous batching) == scalar t in lockstep."""
    cfg = reduced_config("qwen2.5-3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 4), 0,
                              cfg.vocab_size)
    c1 = model.init_cache(B, 16)
    c2 = model.init_cache(B, 16)
    for i in range(4):
        l1, c1 = model.decode_step(params, c1, toks[:, i], jnp.int32(i))
        l2, c2 = model.decode_step(params, c2, toks[:, i],
                                   jnp.full((B,), i, jnp.int32))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_param_counts_full_configs():
    """Full-config param counts are in the advertised ballpark."""
    from repro.models.common import param_count
    expect = {"stablelm-12b": (11e9, 14e9),
              "qwen2.5-3b": (2.6e9, 3.6e9),
              "codeqwen1.5-7b": (6.5e9, 9e9),
              "qwen3-moe-235b-a22b": (2.1e11, 2.6e11),
              "mamba2-780m": (6e8, 9.5e8)}
    for arch, (lo, hi) in expect.items():
        from repro.configs import get_arch
        n = param_count(build_model(get_arch(arch)).param_specs())
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B params"
