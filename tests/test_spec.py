"""repro.spec — speculative decoding: drafters, SpecConfig/VerifyOutcome
validation, the batched accept/reject rule (acceptance-rule oracle as a
hypothesis property), engine-level greedy bit-identity with speculation
on vs off, page conservation under reject-heavy interleavings, verify
plan keys / PlanCacheStats counters, and submit-time validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.kernels import ops
from repro.models import build_model
from repro.serving import Request, SamplingParams, ServingEngine
from repro.serving.sampling import CategoricalSampler, GreedySampler
from repro.spec import (
    Drafter,
    NGramDrafter,
    PromptLookupDrafter,
    SpecConfig,
    VerifyOutcome,
    available_drafters,
    get_drafter,
    register_drafter,
)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


class GarbageDrafter(Drafter):
    """Adversarial drafter: always proposes out-of-distribution junk
    (cycling tokens unrelated to the history) — the reject-heavy path."""

    def propose(self, history, k):
        n = len(history)
        return [(n * 7 + j * 13) % 50 + 1 for j in range(k)]


class OracleDrafter(Drafter):
    """Replays a reference run's continuation per prompt — every draft
    verifies (the acceptance upper bound, and the extension seam a real
    draft-model backend would plug into)."""

    script = {}                                  # prompt tuple -> tokens

    def propose(self, history, k):
        h = tuple(history)
        for prompt, toks in self.script.items():
            if h[:len(prompt)] == prompt:
                done = len(h) - len(prompt)
                return list(toks[done:done + k])
        return []


register_drafter("garbage", GarbageDrafter)
register_drafter("test_oracle", OracleDrafter)


# ---------------------------------------------------------------------------
# config / outcome / drafter units
# ---------------------------------------------------------------------------


def test_spec_config_validates():
    assert SpecConfig().k == 4
    with pytest.raises(ValueError):
        SpecConfig(k=0)
    with pytest.raises(ValueError):
        SpecConfig(k=65)
    with pytest.raises(ValueError):
        SpecConfig(max_rejects=0)
    with pytest.raises(ValueError):
        SpecConfig(method="")
    assert "ngram" in SpecConfig().describe()


def test_verify_outcome_validates():
    o = VerifyOutcome(slot=0, proposed=4, accepted=2, emitted=(1, 2, 3))
    assert o.tokens_gained == 2
    with pytest.raises(ValueError):
        VerifyOutcome(slot=0, proposed=2, accepted=3, emitted=())


def test_drafter_registry():
    assert {"ngram", "prompt_lookup", "garbage"} <= set(
        available_drafters())
    assert get_drafter("ngram") is NGramDrafter
    with pytest.raises(KeyError, match="unknown drafter"):
        get_drafter("nope")
    assert get_drafter("garbage")().name == "garbage"


def test_ngram_drafter_copies_most_recent_continuation():
    d = NGramDrafter(n=3)
    #          0  1  2  3  4  5  6  7
    h = [5, 6, 7, 9, 5, 6, 8, 5, 6]
    # trailing bigram (5, 6) last recurred at index 4 -> continues 8, 5, 6
    assert d.propose(h, 3) == [8, 5, 6]
    assert d.propose(h, 1) == [8]
    assert d.propose([1, 2], 4) == []          # history shorter than n
    assert d.propose([1, 2, 3], 4) == []       # no earlier occurrence
    with pytest.raises(ValueError):
        NGramDrafter(n=1)


def test_prompt_lookup_prefers_longest_suffix_match():
    d = PromptLookupDrafter(min_ngram=1, max_ngram=3)
    h = [1, 2, 3, 4, 9, 2, 3, 4]
    # trailing 3-gram (2,3,4) matches at index 1 -> continues with 9
    assert d.propose(h, 2) == [9, 2]
    # falls back to shorter n-grams when long ones never recurred
    assert d.propose([7, 8, 7], 1) == [8]
    assert d.propose([4], 3) == []


# ---------------------------------------------------------------------------
# acceptance rule (hypothesis property): speculative greedy == sequential
# ---------------------------------------------------------------------------

_VOCAB = 16


def _true_next(history):
    """A deterministic stand-in language model: next token is a hash of
    the last three tokens (repetitive enough that lookup drafters
    sometimes verify, chaotic enough that they sometimes reject)."""
    a, b, c = ([0, 0, 0] + list(history))[-3:]
    return (a * 31 + b * 7 + c * 3 + 1) % _VOCAB


def _onehot_logits(contexts):
    """(M, V) greedy-argmax logits for each context's true next token."""
    rows = np.full((len(contexts), _VOCAB), -5.0, np.float32)
    for j, ctx in enumerate(contexts):
        rows[j, _true_next(ctx)] = 5.0
    return rows


def _speculative_greedy(drafter, prompt, n, k):
    """Emulate the engine's verify loop against the _true_next oracle,
    accepting via the REAL GreedySampler.verify kernel."""
    sampler = GreedySampler()
    hist = list(prompt)
    out = []
    while len(out) < n:
        draft = list(drafter.propose(hist, k))[:k]
        m = len(draft) + 1
        # row j scores position len(hist) + j given [hist, draft[:j]]
        contexts = [hist + draft[:j] for j in range(m)]
        logits = jnp.asarray(_onehot_logits(contexts))[None]
        toks, acc = sampler.verify(
            logits, jnp.asarray([draft], jnp.int32).reshape(1, m - 1),
            {}, jnp.asarray([len(hist)], jnp.int32))
        a = int(acc[0])
        emit = draft[:a] + [int(np.asarray(toks)[0, a])]
        for t in emit:
            out.append(t)
            hist.append(t)
            if len(out) >= n:
                break
    return out


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, _VOCAB - 1), min_size=3, max_size=12),
       st.sampled_from(["ngram", "prompt_lookup", "garbage"]),
       st.integers(1, 6))
def test_property_speculative_greedy_is_bit_identical(prompt, name, k):
    """For ANY drafter and ANY token history, the accept rule commits
    exactly the tokens sequential greedy decode would emit."""
    n = 12
    hist = list(prompt)
    sequential = []
    for _ in range(n):
        t = _true_next(hist)
        sequential.append(t)
        hist.append(t)
    spec = _speculative_greedy(get_drafter(name)(), prompt, n, k)
    assert spec == sequential


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(0, 5))
def test_property_accepted_is_longest_matching_prefix(k, agree):
    """accepted == |common prefix(draft, argmax)| for crafted logits."""
    agree = min(agree, k)
    rng = np.random.default_rng(k * 10 + agree)
    hist = rng.integers(0, _VOCAB, size=5).tolist()
    contexts = [hist]
    draft = []
    for j in range(k):
        true = _true_next(contexts[-1])
        tok = true if j < agree else (true + 1) % _VOCAB
        draft.append(tok)
        contexts.append(contexts[-1] + [tok])
    logits = jnp.asarray(_onehot_logits(contexts))[None]
    _, acc = GreedySampler().verify(
        logits, jnp.asarray([draft], jnp.int32), {},
        jnp.asarray([len(hist)], jnp.int32))
    assert int(acc[0]) == agree


def test_categorical_verify_greedy_rows_match_greedy_sampler():
    """temperature == 0 rows take the exact argmax-prefix rule, so the
    two samplers agree bit-for-bit on greedy traffic."""
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2, 4, _VOCAB)), jnp.float32)
    draft = jnp.asarray(rng.integers(0, _VOCAB, size=(2, 3)), jnp.int32)
    pos = jnp.asarray([5, 9], jnp.int32)
    state = {"temperature": jnp.zeros(2, jnp.float32),
             "top_k": jnp.zeros(2, jnp.int32),
             "top_p": jnp.ones(2, jnp.float32),
             "key": jnp.stack([jax.random.PRNGKey(i) for i in range(2)])}
    tg, ag = GreedySampler().verify(logits, draft, {}, pos)
    tc, ac = CategoricalSampler().verify(logits, draft, state, pos)
    assert jnp.array_equal(tg, tc) and jnp.array_equal(ag, ac)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

_PROMPTS = [[5, 6, 7, 5, 6, 7, 5, 6], [1, 2, 3, 4, 1, 2, 3],
            [9, 9, 8, 9, 9, 8, 9], [2, 4, 6, 8, 2, 4, 6, 8, 2]]


def _drain(model, params, *, spec=None, layout="paged", max_new=16,
           scfg_kw=None, prompts=_PROMPTS, slots=4, max_len=96,
           sampling_kw=None):
    eng = ServingEngine(
        model, ServeConfig(model=model.cfg, cache_layout=layout,
                           **(scfg_kw or {})),
        max_len=max_len, batch_slots=slots)
    eng.load(params)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=max_new,
                           sampling=SamplingParams(
                               speculation=spec, **(sampling_kw or {}))))
    outs = eng.drain()
    return [c.tokens for c in outs], eng


@pytest.mark.parametrize("layout", ["dense", "paged"])
@pytest.mark.parametrize("method", ["ngram", "prompt_lookup"])
def test_engine_speculative_greedy_bit_identical(tiny_model, layout,
                                                 method):
    cfg, model, params = tiny_model
    base, _ = _drain(model, params, layout=layout)
    spec, eng = _drain(model, params, layout=layout,
                       spec=SpecConfig(method=method, k=4))
    assert spec == base
    if eng.cache.is_paged:
        eng.cache.check_conservation()


def test_engine_verify_plans_and_stats(tiny_model):
    cfg, model, params = tiny_model
    ops.reset_policy_eval_count()
    base, _ = _drain(model, params)
    OracleDrafter.script = {tuple(p): t for p, t in zip(_PROMPTS, base)}
    spec, eng = _drain(model, params,
                       spec=SpecConfig(method="test_oracle", k=4))
    assert spec == base
    st = eng.stats
    # oracle drafts always verify: real multi-token acceptance happened
    assert st.spec_steps > 0 and st.spec_proposed > 0
    assert st.spec_accepted == st.spec_proposed
    assert st.spec_acceptance_rate == 1.0
    assert st.spec_tokens_per_step > 1.0
    # verify launches were planned and frozen under tuple keys in the
    # SAME plan cache as decode/prefill entries
    keys = eng.sched.planned_verify_keys()
    assert keys and all(k >= 1 and b >= 1 for k, b in keys)
    assert any(key[0] == "verify" for key in eng.sched.plans.keys()
               if isinstance(key, tuple))
    snap = st.to_json()
    assert snap["spec_tokens_per_step"] > 1.0
    assert any(k.startswith("verify/") for k in snap["launches"])
    # the split policy never ran inside traced code
    assert ops.policy_eval_count() == 0


def test_engine_mixed_spec_and_plain_traffic(tiny_model):
    """Speculating and non-speculating requests share lockstep verify
    launches (plain slots ride as 1-token rows) without divergence."""
    cfg, model, params = tiny_model
    base, _ = _drain(model, params)
    eng = ServingEngine(model, ServeConfig(model=cfg,
                                           cache_layout="paged"),
                        max_len=96, batch_slots=4)
    eng.load(params)
    for i, p in enumerate(_PROMPTS):
        sp = SpecConfig(method="ngram", k=3) if i % 2 == 0 else None
        eng.submit(Request(i, p, max_new_tokens=16,
                           sampling=SamplingParams(speculation=sp)))
    outs = eng.drain()
    assert [c.tokens for c in outs] == base
    eng.cache.check_conservation()


def test_engine_loop_prefill_rides_verify_launches(tiny_model):
    """prompt_left slots ride verify launches as teacher-forcing rows."""
    cfg, model, params = tiny_model
    base, _ = _drain(model, params, scfg_kw=dict(prefill_mode="loop"))
    spec, eng = _drain(model, params, scfg_kw=dict(prefill_mode="loop"),
                       spec=SpecConfig(method="ngram", k=3))
    assert spec == base
    eng.cache.check_conservation()


def test_engine_default_speculation_from_serve_config(tiny_model):
    cfg, model, params = tiny_model
    base, _ = _drain(model, params)
    spec, eng = _drain(model, params,
                       scfg_kw=dict(speculation="ngram",
                                    speculation_k=4))
    assert spec == base
    assert eng.stats.spec_steps > 0


def test_reject_heavy_conservation_and_rollback(tiny_model):
    """A drafter that always proposes junk forces the maximal
    reject/rollback traffic — every verify step truncates kv_len back
    over speculative rows — under a tight page budget that also forces
    mid-draft allocation failure.  Page conservation must hold
    throughout and tokens must still match plain decode bit-exact."""
    cfg, model, params = tiny_model
    kw = dict(scfg_kw=dict(cache_page_size=4, cache_page_budget=40),
              max_len=48, max_new=10)
    base, beng = _drain(model, params, **kw)
    spec, eng = _drain(model, params,
                       spec=SpecConfig(method="garbage", k=4), **kw)
    assert spec == base
    eng.cache.check_conservation()
    st = eng.stats
    assert st.spec_proposed > 0
    assert st.spec_accepted < st.spec_proposed   # junk mostly rejects
    # every request still finished for the same reasons as baseline
    assert ([c.finish_reason for c in eng._completions.values()]
            == [c.finish_reason for c in beng._completions.values()])


def test_max_rejects_disables_speculation(tiny_model):
    cfg, model, params = tiny_model
    spec, eng = _drain(model, params,
                       spec=SpecConfig(method="garbage", k=3,
                                       max_rejects=2),
                       max_new=12)
    st = eng.stats
    assert st.spec_disabled == len(_PROMPTS)
    # after disabling, slots stop drafting: far fewer verify steps than
    # a never-disabled garbage run would pay
    assert st.spec_steps <= 3 * len(_PROMPTS)
    base, _ = _drain(model, params, max_new=12)
    assert spec == base


def test_sampled_speculation_runs_and_conserves(tiny_model):
    """Rejection sampling path: sampled speculative requests complete
    with the right lengths and page accounting (distributional
    equivalence is the design property; bit-equality is only a greedy
    guarantee)."""
    cfg, model, params = tiny_model
    toks, eng = _drain(model, params,
                       spec=SpecConfig(method="ngram", k=3),
                       sampling_kw=dict(temperature=0.8, seed=7),
                       max_new=12)
    assert all(len(t) == 12 for t in toks)
    eng.cache.check_conservation()


# ---------------------------------------------------------------------------
# submit-time validation
# ---------------------------------------------------------------------------


def test_submit_rejects_unknown_drafter(tiny_model):
    cfg, model, params = tiny_model
    eng = ServingEngine(model, ServeConfig(model=cfg), max_len=64,
                        batch_slots=1)
    eng.load(params)
    with pytest.raises(ValueError, match="unknown drafter"):
        eng.submit(Request(0, [1, 2], sampling=SamplingParams(
            speculation=SpecConfig(method="nope"))))


def test_sampling_params_speculation_type_checked():
    with pytest.raises(TypeError, match="SpecConfig"):
        SamplingParams(speculation="ngram")


def test_submit_rejects_unsupported_family():
    cfg = reduced_config("mamba2-780m", num_layers=2, d_model=32)
    model = build_model(cfg)
    assert not model.supports_speculation
    eng = ServingEngine(model, ServeConfig(model=cfg), max_len=64,
                        batch_slots=1)
    with pytest.raises(ValueError, match="supports_speculation"):
        eng.validate(Request(0, [1, 2], sampling=SamplingParams(
            speculation=SpecConfig())))


def test_engine_default_speculation_validated_at_init(tiny_model):
    cfg, model, params = tiny_model
    with pytest.raises(ValueError, match="unknown drafter"):
        ServingEngine(model, ServeConfig(model=cfg, speculation="nope"),
                      max_len=64, batch_slots=1)
    with pytest.raises(ValueError, match="metadata-enabled"):
        ServingEngine(model,
                      ServeConfig(model=cfg, speculation="ngram",
                                  use_scheduler_metadata=False),
                      max_len=64, batch_slots=1)


def test_supports_speculation_gates():
    for arch, ok in [("qwen2.5-3b", True), ("granite-moe-3b-a800m", True),
                     ("mamba2-780m", False), ("recurrentgemma-9b", False),
                     ("whisper-large-v3", False), ("minicpm3-4b", False)]:
        cfg = reduced_config(arch, num_layers=2, d_model=32)
        assert build_model(cfg).supports_speculation is ok, arch
