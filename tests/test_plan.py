"""repro.plan subsystem: Spec -> Plan -> Cache, scope, shims, equivalence.

The load-bearing guarantees:

- the :class:`Planner` reproduces the committed golden decision table
  bit-exact for all three policy backends (no second decision path),
- :class:`PlanCache` eviction re-specializes and keeps stats consistent,
- ``distinct_buckets`` survives trace trimming (persistent seen set),
- scope-precedence regression: a context policy override applies even
  with ``num_cores`` unset (the old ``DecodeContext`` bug),
- the single plan_scope stack keeps decode / prefill plans apart,
- the deprecated ``DecodeContext`` / ``AttnContext`` shims still work.
"""
import json
import re
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.core.split_policy import DecodeWorkload, analytic_policies
from repro.kernels import ops
from repro.models import build_model
from repro.plan import (
    AttentionSpec,
    LaunchPlan,
    PlanCache,
    PlanCacheStats,
    Planner,
    bucket_seqlen,
    current_plan,
    plan_scope,
)
from repro.serving.engine import DecodeEngine, Request

GOLDEN = Path(__file__).parent / "golden" / "split_policy_table.json"
_KEY = re.compile(
    r"^(\w+)\|B(\d+)\|L(\d+)\|Hq(\d+)\|Hkv(\d+)\|C(\d+)(?:\|(\w+))?$")


# ---------------------------------------------------------------------------
# Planner: decision equivalence
# ---------------------------------------------------------------------------


def test_planner_reproduces_golden_table_bit_exact():
    """Every cell of the committed decision table, via the public
    Planner API — the new subsystem must not introduce a second
    decision surface.  (Analytic backends only: the table-backed
    ``measured`` policy has its own golden gate in test_tune.py.)"""
    table = json.loads(GOLDEN.read_text())
    assert table, "golden table empty?"
    seen_policies = set()
    for key, want in table.items():
        m = _KEY.match(key)
        assert m, f"unparseable golden key {key!r}"
        policy = m.group(1)
        b, lk, hq, hkv, cores = map(int, m.groups()[1:6])
        kv_dtype = m.group(7) or "bfloat16"   # quant-family rows
        seen_policies.add(policy)
        spec = AttentionSpec.decode(b, lk, hq, hkv, 128, kv_dtype=kv_dtype)
        got = Planner(policy=policy, num_cores=cores).plan(spec).num_splits
        assert got == want, f"{key}: planner={got} golden={want}"
    assert seen_policies == set(analytic_policies())


def test_planner_override_clamps_and_prefill_never_splits():
    spec = AttentionSpec.decode(1, 512, 64, 1, 128)     # 4 KV blocks
    assert Planner(num_splits_override=3).plan(spec).num_splits == 3
    assert Planner(num_splits_override=99).plan(spec).num_splits == 4
    pre = AttentionSpec("prefill", 1, 512, 512, 64, 1, 128)
    assert Planner(policy="tpu_adaptive",
                   num_cores=132).plan(pre).num_splits == 1


def test_planner_rejects_unknown_policy():
    with pytest.raises(KeyError):
        Planner(policy="nope")


def test_plan_carries_superset_fields():
    plan = Planner(policy="paper", impl="pallas",
                   block_k=256).plan(AttentionSpec.decode(1, 512, 64, 1),
                                     bucket=512)
    assert plan.frozen and plan.pack_gqa and plan.bucket == 512
    assert plan.impl == "pallas" and plan.block_k == 256
    assert plan.workload == DecodeWorkload(1, 1, 512, 64, 1, 128)
    ctx = plan.context_only()
    assert not ctx.frozen and ctx.policy == "paper"
    d = plan.describe()
    assert d["num_splits"] == plan.num_splits and "shape" in d


def test_mesh_plan_storage_vs_occupancy():
    # H_KV=2 does not divide a 16-axis -> storage-forced full-axis shard
    p = Planner(policy="paper").mesh_plan(
        AttentionSpec.decode(1, 512, 16, 2, 128), axis_size=16)
    # kernel split forced to the axis but clamped to the 4 KV blocks
    assert p.mesh_splits == 16 and p.num_splits == 4
    # H_KV=16 divides the axis and fills it -> head-sharded, no seq shard
    p2 = Planner(policy="paper").mesh_plan(
        AttentionSpec.decode(8, 512, 16, 16, 128), axis_size=16)
    assert p2.mesh_splits == 1


# ---------------------------------------------------------------------------
# PlanCache: eviction + stats
# ---------------------------------------------------------------------------


def test_plan_cache_eviction_respecializes_and_stats_consistent():
    cache = PlanCache(capacity=1)
    built = []

    def builder(k):
        return lambda: built.append(k) or f"plan-{k}"

    assert cache.get_or_build(128, builder(128)) == "plan-128"   # miss
    assert cache.get_or_build(128, builder(128)) == "plan-128"   # hit
    assert cache.get_or_build(256, builder(256)) == "plan-256"   # miss+evict
    assert 128 not in cache and len(cache) == 1
    # re-visiting the evicted bucket re-builds (re-specializes) = miss
    assert cache.get_or_build(128, builder(128)) == "plan-128"
    assert built == [128, 256, 128]
    st = cache.stats
    assert (st.hits, st.misses) == (1, 3)
    assert st.total_launches == len(st.trace) == sum(st.launches.values())
    assert st.distinct_buckets == 2
    assert cache.cache_info().currsize == 1
    cache.clear()
    assert len(cache) == 0 and st.total_launches == 0
    assert st.distinct_buckets == 0


def test_distinct_buckets_survives_trace_trim():
    """Regression: distinct_buckets used to read set(trace), undercounting
    once the trace was trimmed at TRACE_CAP in a long-lived engine."""
    st = PlanCacheStats()
    st.record_launch(256)
    for _ in range(2 * PlanCacheStats.TRACE_CAP + 1):
        st.record_launch(128)
    assert len(st.trace) <= 2 * PlanCacheStats.TRACE_CAP
    assert 256 not in st.trace                 # trimmed away...
    assert st.distinct_buckets == 2            # ...but still counted
    assert st.launches[256] == 1


def test_measured_fallback_trace_is_trace_capped():
    """Regression: measured_fallback_trace grew without bound — a
    long-lived engine on a tune-table family the grid does not cover
    appended one tuple per launch forever, unlike trace/fallback_trace
    which trim at TRACE_CAP.  Counters must survive the trim."""
    st = PlanCacheStats()
    n = 2 * PlanCacheStats.TRACE_CAP + 7
    for i in range(n):
        st.record_measured((1, 4, 1, 8, "xla", 2, i), fallback=True)
        st.record_measured((1, 4, 1, 8, "xla", 2, i), fallback=False)
    assert len(st.measured_fallback_trace) <= 2 * PlanCacheStats.TRACE_CAP
    # the trimmed tail keeps the most RECENT entries
    assert st.measured_fallback_trace[-1] == (1, 4, 1, 8, "xla", 2, n - 1)
    # aggregate counters are exact despite the trim
    assert st.measured_lookups == 2 * n
    assert st.measured_fallbacks == n
    # the other two traces hold the same bound under the shared helper
    for _ in range(2 * PlanCacheStats.TRACE_CAP + 7):
        st.record_fallback(100, 512)
    assert len(st.fallback_trace) <= 2 * PlanCacheStats.TRACE_CAP
    assert st.fallback_launches == 2 * PlanCacheStats.TRACE_CAP + 7


def test_engine_revisits_evicted_bucket_as_fresh_miss():
    cfg = reduced_config("qwen2.5-3b", num_layers=1, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = DecodeEngine(model, ServeConfig(model=cfg, plan_cache_capacity=1),
                       max_len=300, batch_slots=1)
    eng.load(params)
    # crosses the 128 -> 256 bucket boundary: 128 gets evicted
    eng.generate([Request(0, [1, 2], max_new_tokens=150)])
    assert list(eng.planned_splits()) == [256]
    assert eng.stats.misses == 2
    # a fresh short request re-visits the evicted 128 bucket -> miss #3
    eng.generate([Request(1, [3, 4], max_new_tokens=4)])
    assert eng.stats.misses == 3
    assert eng.stats.distinct_buckets == 2


def test_engine_num_splits_override():
    """ServeConfig.num_splits_override reaches the engine's Planner (the
    FA3 explicit-num_splits API end to end)."""
    cfg = reduced_config("qwen2.5-3b", num_layers=1, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = DecodeEngine(model,
                       ServeConfig(model=cfg, num_splits_override=2),
                       max_len=512, batch_slots=1)
    eng.load(params)
    md = eng._metadata(400)                    # 512 bucket: 4 KV blocks
    assert md.num_splits == 2
    eng.generate([Request(0, [1, 2, 3], max_new_tokens=4)])
    assert all(s == min(2, lk // 128) or s == 2
               for lk, s in eng.planned_splits().items())


# ---------------------------------------------------------------------------
# plan_scope: single stack, kind filtering, precedence
# ---------------------------------------------------------------------------


def test_scope_policy_override_applies_without_num_cores():
    """Regression for the old DecodeContext precedence bug: ``policy``
    was only honored when ``num_cores`` was also set."""
    q = jnp.ones((1, 8, 64))
    k = jnp.ones((1, 512, 1, 64))
    v = jnp.ones((1, 512, 1, 64))
    kv_len = jnp.array([512], jnp.int32)
    ops.reset_policy_eval_count()
    with plan_scope(LaunchPlan(kind="decode", policy="tpu_adaptive")):
        ops.decode_attention(q, k, v, kv_len)
    assert ops.policy_eval_count() == 1
    inline = ops.last_inline_plan()
    assert inline is not None and inline.policy == "tpu_adaptive"
    # explicit plan overrides the ambient scope
    ops.reset_policy_eval_count()
    with plan_scope(LaunchPlan(kind="decode", policy="tpu_adaptive")):
        ops.decode_attention(
            q, k, v, kv_len,
            plan=LaunchPlan(kind="decode", policy="fa3_baseline"))
    assert ops.last_inline_plan().policy == "fa3_baseline"


def test_scope_kind_filtering_keeps_decode_and_prefill_apart():
    dec = LaunchPlan(kind="decode", policy="paper")
    pre = LaunchPlan(kind="prefill")
    with plan_scope(dec):
        assert current_plan("decode") is dec
        assert current_plan("cross") is dec        # decode family
        assert current_plan("prefill") is None
        with plan_scope(pre):                      # inner scope shadows
            assert current_plan("prefill") is pre
            assert current_plan("decode") is None
    assert current_plan() is None


def test_frozen_scope_plan_consumed_zero_inline_evals():
    spec = AttentionSpec.decode(1, 512, 8, 1, 64)
    plan = Planner(policy="paper").plan(spec)
    q = jnp.ones((1, 8, 64))
    k = jnp.ones((1, 512, 1, 64))
    v = jnp.ones((1, 512, 1, 64))
    kv_len = jnp.array([512], jnp.int32)
    ops.reset_policy_eval_count()
    with plan_scope(plan):
        ops.decode_attention(q, k, v, kv_len)
    assert ops.policy_eval_count() == 0
    # use_ctx_metadata=False opts out of the ambient frozen plan
    with plan_scope(plan):
        ops.decode_attention(q, k, v, kv_len, use_ctx_metadata=False)
    assert ops.policy_eval_count() == 1


def test_deprecated_context_shims_warn_and_map_to_plans():
    with pytest.warns(DeprecationWarning):
        ctx = ops.DecodeContext(policy="tpu_adaptive", min_splits=2)
    assert isinstance(ctx, LaunchPlan)
    assert ctx.kind == "decode" and ctx.min_splits == 2
    with pytest.warns(DeprecationWarning):
        actx = ops.AttnContext()
    assert actx.kind == "prefill"
    with ops.decode_context(ctx):
        assert ops.current_decode_context() is ctx
        assert current_plan("decode") is ctx


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------


def test_bucket_seqlen_moved_but_stable():
    assert bucket_seqlen(1) == 128
    assert bucket_seqlen(400) == 512
    assert bucket_seqlen(512) == 512
    spec = AttentionSpec.decode(1, 400, 8, 1)
    assert spec.bucketed().seqlen_k == 512


def test_spec_rejects_unknown_kind():
    with pytest.raises(ValueError):
        AttentionSpec("flurb", 1, 1, 512, 8, 1)
