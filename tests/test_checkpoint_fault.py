"""Checkpointing (atomic/async/keep-k) + fault tolerance + elastic resume."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import OptimizerConfig, ShapeConfig, TrainConfig
from repro.configs.reduced import reduced_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.fault.elastic import resumable_train_loop
from repro.fault.watchdog import Heartbeat, StragglerDetector, Watchdog
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.training.train_step import build_train_step


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 3, t)
    step, r = ckpt.restore(tmp_path, t)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_last_k(tmp_path):
    for s in range(6):
        ckpt.save(tmp_path, s, _tree(), keep=2)
    steps = sorted(p.name for p in tmp_path.iterdir())
    assert steps == ["step_00000004", "step_00000005"]


def test_crashed_tmp_dir_ignored(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    # simulate a crashed mid-write checkpoint
    (tmp_path / "step_00000002.tmp").mkdir()
    assert ckpt.latest_step(tmp_path) == 1
    step, _ = ckpt.restore(tmp_path, _tree())
    assert step == 1


def test_async_checkpointer(tmp_path):
    w = ckpt.AsyncCheckpointer(tmp_path, keep=3)
    for s in range(3):
        w.save(s, _tree())
    w.wait()
    assert ckpt.latest_step(tmp_path) == 2


def test_restore_dtype_cast(tmp_path):
    """Restore recasts to the target tree's dtypes (elastic config drift)."""
    ckpt.save(tmp_path, 0, {"w": jnp.ones((3,), jnp.float32)})
    _, r = ckpt.restore(tmp_path, {"w": jnp.zeros((3,), jnp.bfloat16)})
    assert r["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# watchdog / straggler
# ---------------------------------------------------------------------------


def test_watchdog_detects_stale_heartbeat():
    now = [0.0]
    clock = lambda: now[0]
    dead = []
    wd = Watchdog(timeout_s=5.0, on_dead=dead.append, clock=clock)
    hbs = [Heartbeat(f"w{i}", clock) for i in range(3)]
    for hb in hbs:
        wd.register(hb)
    now[0] = 4.0
    hbs[0].beat()
    hbs[1].beat()              # w2 never beats
    now[0] = 6.0
    assert wd.check_once() == ["w2"]
    assert dead == ["w2"]
    now[0] = 20.0              # everyone stale now; w2 not re-reported
    assert sorted(wd.check_once()) == ["w0", "w1"]


def test_straggler_detector():
    det = StragglerDetector(window=16, threshold=2.0, min_samples=4)
    for step in range(8):
        for w in range(4):
            det.record(f"w{w}", 0.1)
        det.record("w_slow", 0.5)
    assert det.stragglers() == ["w_slow"]
    assert "w0" not in det.stragglers()


# ---------------------------------------------------------------------------
# elastic resume: crash mid-run, resume, bit-identical final state
# ---------------------------------------------------------------------------


def _mk_bundle(model_axis=1):
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    mesh = make_host_mesh(model_axis)
    shape = ShapeConfig("t", 16, 4, "train")
    tcfg = TrainConfig(model=cfg, shape=shape,
                       optimizer=OptimizerConfig(warmup_steps=2,
                                                 total_steps=30))
    return build_train_step(model, tcfg, mesh), cfg


def test_crash_resume_matches_uninterrupted(tmp_path):
    bundle, cfg = _mk_bundle()
    data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 4, seed=5))
    quiet = lambda s: None

    # uninterrupted reference
    ref = resumable_train_loop(
        bundle, data, total_steps=12, ckpt_dir=str(tmp_path / "ref"),
        ckpt_every=4, async_ckpt=False, log_fn=quiet)

    # crash at step 7, then resume (restores step 8 from ckpt at 7)
    with pytest.raises(RuntimeError, match="injected failure"):
        resumable_train_loop(
            bundle, data, total_steps=12, ckpt_dir=str(tmp_path / "cr"),
            ckpt_every=4, async_ckpt=False, fail_at_step=7, log_fn=quiet)
    out = resumable_train_loop(
        bundle, data, total_steps=12, ckpt_dir=str(tmp_path / "cr"),
        ckpt_every=4, async_ckpt=False, log_fn=quiet)
    assert out["loss"] == pytest.approx(ref["loss"], rel=1e-5)


def test_data_pipeline_deterministic_and_host_sharded():
    d1 = SyntheticLM(DataConfig(64, 8, 4, seed=1))
    d2 = SyntheticLM(DataConfig(64, 8, 4, seed=1))
    np.testing.assert_array_equal(d1.batch_at(5)["tokens"],
                                  d2.batch_at(5)["tokens"])
    assert not np.array_equal(d1.batch_at(5)["tokens"],
                              d1.batch_at(6)["tokens"])
    # host sharding partitions the batch
    h0 = SyntheticLM(DataConfig(64, 8, 4, seed=1, num_hosts=2, host_id=0))
    h1 = SyntheticLM(DataConfig(64, 8, 4, seed=1, num_hosts=2, host_id=1))
    b0, b1 = h0.batch_at(3)["tokens"], h1.batch_at(3)["tokens"]
    assert b0.shape == (2, 8) and b1.shape == (2, 8)
    assert not np.array_equal(b0, b1)
