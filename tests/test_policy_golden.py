"""Golden decision table: lock the three policies' split choices.

``golden/split_policy_table.json`` pins ``choose_num_splits`` for every
policy over a committed grid of (batch, L_K, H_Q, H_KV, num_cores)
shapes — the decision surface the paper's Table 1 / Fig. 2 claims live
on.  A policy refactor that changes ANY cell now fails loudly and the
diff documents exactly which shapes moved; regenerate intentionally
with:

    PYTHONPATH=src python tests/test_policy_golden.py --regen
"""
import json
import sys
from pathlib import Path

from repro.core.split_policy import (
    KV_DTYPES,
    DecodeWorkload,
    analytic_policies,
    choose_num_splits,
)

GOLDEN = Path(__file__).parent / "golden" / "split_policy_table.json"

# the committed grid: low-head-count decode shapes (the paper's regime),
# the nblk=4 boundary bucket at several tile counts, and long-context
# shapes that exercise the upstream efficiency loop
BATCHES = (1, 2, 8, 64)
SEQLENS_K = (128, 256, 384, 448, 512, 640, 1024, 4096, 32768)
HEADS = ((64, 1), (32, 4), (16, 2), (40, 8), (20, 20), (8, 8))
NUM_CORES = (8, 16, 132)

# quant-family rows (repro.quant): keys carry the kv_dtype suffix so a
# byte-sensitive policy (tpu_adaptive reads ``dtype_bytes``) is pinned
# per family — and the int8/fp8 rows pin that the ANALYTIC surface is
# byte-driven, never name-driven (same bytes => same decision; the
# name-keyed distinction lives in the measured table, `make tune-golden`)
QUANT_DTYPES_GRID = ("int8", "fp8")
QUANT_BATCHES = (1, 8)
QUANT_SEQLENS_K = (384, 512, 1024, 4096)
QUANT_HEADS = ((64, 1), (16, 2), (32, 4))
QUANT_NUM_CORES = (8, 132)


def compute_table() -> dict:
    # analytic backends only: the table-backed ``measured`` policy's
    # decisions live in experiments/tune/ artifacts (make tune-golden)
    table = {}
    for policy in analytic_policies():
        for b in BATCHES:
            for lk in SEQLENS_K:
                for hq, hkv in HEADS:
                    for cores in NUM_CORES:
                        w = DecodeWorkload(b, 1, lk, hq, hkv, 128)
                        key = f"{policy}|B{b}|L{lk}|Hq{hq}|Hkv{hkv}|C{cores}"
                        table[key] = choose_num_splits(
                            w, policy=policy, num_cores=cores)
        for dtype in QUANT_DTYPES_GRID:
            for b in QUANT_BATCHES:
                for lk in QUANT_SEQLENS_K:
                    for hq, hkv in QUANT_HEADS:
                        for cores in QUANT_NUM_CORES:
                            w = DecodeWorkload(
                                b, 1, lk, hq, hkv, 128,
                                dtype_bytes=KV_DTYPES[dtype],
                                kv_dtype=dtype)
                            key = (f"{policy}|B{b}|L{lk}|Hq{hq}|"
                                   f"Hkv{hkv}|C{cores}|{dtype}")
                            table[key] = choose_num_splits(
                                w, policy=policy, num_cores=cores)
    return table


def test_policy_decision_table_matches_golden():
    assert GOLDEN.exists(), (
        f"golden file missing: {GOLDEN} — regenerate with "
        "`PYTHONPATH=src python tests/test_policy_golden.py --regen`")
    want = json.loads(GOLDEN.read_text())
    got = compute_table()
    changed = {k: (want.get(k), got.get(k))
               for k in set(want) | set(got) if want.get(k) != got.get(k)}
    assert not changed, (
        f"{len(changed)} policy decisions drifted from the golden table "
        f"(first 10: {dict(list(sorted(changed.items()))[:10])}); if "
        "intentional, regenerate via --regen and commit the diff")


def test_golden_pins_the_papers_headline_cell():
    """The table must contain the paper's motivating decision: B=1, MQA,
    L_K=512 — fa3_baseline refuses to split, paper picks 3."""
    want = json.loads(GOLDEN.read_text())
    assert want["fa3_baseline|B1|L512|Hq64|Hkv1|C132"] == 1
    assert want["paper|B1|L512|Hq64|Hkv1|C132"] == 3


def test_golden_quant_rows_are_byte_aware():
    """The quant families are pinned: a byte-sensitive policy decides
    differently for a 1-byte cache than for bf16 somewhere on the grid,
    and int8/fp8 (same width) always agree on the ANALYTIC surface —
    the name-keyed distinction is the measured table's job."""
    want = json.loads(GOLDEN.read_text())
    diverged = 0
    for b in QUANT_BATCHES:
        for lk in QUANT_SEQLENS_K:
            for hq, hkv in QUANT_HEADS:
                for cores in QUANT_NUM_CORES:
                    stem = f"B{b}|L{lk}|Hq{hq}|Hkv{hkv}|C{cores}"
                    for policy in analytic_policies():
                        i8 = want[f"{policy}|{stem}|int8"]
                        assert i8 == want[f"{policy}|{stem}|fp8"]
                        if i8 != want[f"{policy}|{stem}"]:
                            diverged += 1
    assert diverged > 0, \
        "no analytic policy read dtype_bytes anywhere on the quant grid"


if __name__ == "__main__":
    if "--regen" in sys.argv:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(compute_table(), indent=0,
                                     sort_keys=True) + "\n")
        print(f"wrote {GOLDEN}")
    else:
        print(__doc__)
