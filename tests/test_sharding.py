"""Sharding rules: divisibility fallback, conflicts, per-device bytes."""
import jax

from repro.compat import make_mesh
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.models import build_model
from repro.models.common import ParamSpec, abstract_params, logical_axes
from repro.sharding.rules import (
    ShardingRules,
    activation_rules,
    cache_rules,
    param_rules,
    spec_for,
    tree_shardings,
)


class FakeMesh:
    """Duck-typed mesh: axis_names + shape dict (spec_for needs no more)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_basic_tp_fsdp_spec():
    s = spec_for((4096, 11008), ("embed", "ff"), param_rules(), MESH)
    assert s == P("data", "model")


def test_divisibility_fallback_replicates():
    # 20 kv heads on a 16-way axis: cannot shard -> None
    s = spec_for((1280, 20, 64), ("embed", "kv_heads", "head_dim"),
                 param_rules(), MESH)
    assert s == P("data",)          # trailing Nones trimmed


def test_conflict_first_dim_wins():
    # experts and ff both want "model": experts (dim 0) wins
    s = spec_for((128, 4096, 1536), ("experts", "embed", "ff"),
                 param_rules(), MESH)
    assert s == P("model", "data")


def test_multi_axis_prefix():
    # embed -> ("pod", "data"): 4096 divides 2 and 2*16
    s = spec_for((4096, 100), ("embed", None), param_rules(), POD)
    assert s == P(("pod", "data"))


def test_multi_axis_partial_prefix():
    # dim 6 divides pod (2) but not pod*data (32): greedy prefix stops
    s = spec_for((6, 100), ("embed", None), param_rules(), POD)
    assert s == P("pod")


def test_batch_one_replicates():
    s = spec_for((1, 2048), ("batch", None), activation_rules(), MESH)
    assert s == P()


def test_cache_rules_seq_split_toggle():
    on = cache_rules(True)
    off = cache_rules(False)
    shape = (128, 32768, 32, 128)     # 32 kv heads divide the axis
    axes = ("batch", "seq", "kv_heads", "head_dim")
    assert spec_for(shape, axes, on, MESH) == P("data", "model")
    assert spec_for(shape, axes, off, MESH) == P("data", None, "model")
    # kv heads that DON'T divide the axis fall back to replicated — the
    # serving builder then forces the storage-driven sequence split
    assert spec_for((128, 32768, 20, 128), axes, off, MESH) == P("data",)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "qwen3-moe-235b-a22b",
                                  "whisper-large-v3", "mamba2-780m"])
def test_tree_shardings_cover_all_params(arch):
    mesh = make_mesh((1, 1), ("data", "model"))
    model = build_model(get_arch(arch))
    ap = abstract_params(model.param_specs())
    sh = tree_shardings(mesh, ap, model.param_axes(), param_rules())
    n_p = len(jax.tree.leaves(ap))
    n_s = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n_p == n_s
