"""Pallas kernels vs the pure-jnp oracles (interpret=True on CPU).

Shape/dtype sweeps per the assignment: every kernel asserts allclose
against ref.py on a grid of (batch, heads, lengths, dims, splits).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_decode import flash_decode_partials
from repro.kernels.flash_prefill import flash_prefill


def _rand(rng, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# split_decode_xla: the oracle's own invariance (schedule != math)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    hkv=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 4, 8]),
    lk=st.integers(2, 300),
    s=st.integers(1, 16),
    d=st.sampled_from([16, 64]),
)
def test_split_decode_invariant_to_split_count(b, hkv, g, lk, s, d):
    rng = jax.random.PRNGKey(lk * 131 + s)
    ks = jax.random.split(rng, 4)
    q = _rand(ks[0], (b, hkv * g, d))
    k = _rand(ks[1], (b, lk, hkv, d))
    v = _rand(ks[2], (b, lk, hkv, d))
    kv_len = jax.random.randint(ks[3], (b,), 1, lk + 1)
    want = ref.naive_decode_attention(q, k, v, kv_len)
    got = ref.split_decode_xla(q, k, v, kv_len, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_split_decode_mla_shapes():
    """Dv != Dqk (absorbed MLA latent attention)."""
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 3)
    q = _rand(ks[0], (2, 8, 40))          # latent+rope width 40
    k = _rand(ks[1], (2, 64, 1, 40))
    v = k[..., :32]                       # v = latent slice
    kv_len = jnp.array([64, 10], jnp.int32)
    want = ref.naive_decode_attention(q, k, v, kv_len)
    got = ref.split_decode_xla(q, k, v, kv_len, 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Pallas flash decode kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hkv,g,lk,s", [
    (1, 1, 8, 128, 1),
    (1, 1, 8, 512, 3),        # the paper's target shape (B=1, MQA, L=512)
    (1, 2, 4, 512, 3),        # H_KV=2 row of Table 1
    (2, 2, 2, 384, 1),
    (1, 1, 4, 1024, 4),
    (2, 4, 1, 256, 2),        # MHA-style (g=1)
    (1, 1, 1, 2048, 8),
])
def test_flash_decode_kernel_vs_oracle(b, hkv, g, lk, s, dtype):
    rng = jax.random.PRNGKey(b * 7 + lk)
    ks = jax.random.split(rng, 4)
    d = 128
    hq = hkv * g
    q = _rand(ks[0], (b, hq, d), dtype)
    k = _rand(ks[1], (b, lk, hkv, d), dtype)
    v = _rand(ks[2], (b, lk, hkv, d), dtype)
    kv_len = jax.random.randint(ks[3], (b,), 1, lk + 1)

    got = ops.decode_attention(
        q, k, v, kv_len, impl="pallas", interpret=True,
        metadata=__import__("repro.core.scheduler_metadata",
                            fromlist=["get_scheduler_metadata"]
                            ).get_scheduler_metadata(
            b, 1, lk, hq, hkv, d, num_splits_override=s))
    want = ref.naive_decode_attention(q, k, v, kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_flash_decode_split_determinism():
    """TPU combine is a deterministic reduction: same split -> same bits."""
    rng = jax.random.PRNGKey(3)
    ks = jax.random.split(rng, 4)
    q = _rand(ks[0], (1, 8, 128), jnp.bfloat16)
    k = _rand(ks[1], (1, 512, 1, 128), jnp.bfloat16)
    v = _rand(ks[2], (1, 512, 1, 128), jnp.bfloat16)
    kv_len = jnp.array([512], jnp.int32)
    md = __import__("repro.core.scheduler_metadata",
                    fromlist=["get_scheduler_metadata"]
                    ).get_scheduler_metadata(1, 1, 512, 8, 1, 128,
                                             num_splits_override=3)
    a = ops.decode_attention(q, k, v, kv_len, impl="pallas", metadata=md)
    b = ops.decode_attention(q, k, v, kv_len, impl="pallas", metadata=md)
    assert (np.asarray(a) == np.asarray(b)).all()


def test_flash_decode_partials_lse_combine_algebra():
    """Partials from the kernel + ref.lse_combine == unsplit softmax."""
    rng = jax.random.PRNGKey(11)
    ks = jax.random.split(rng, 3)
    B, Hkv, G, D, L, S = 2, 2, 4, 128, 512, 4
    q = _rand(ks[0], (B, Hkv, G, D)) * D ** -0.5
    k = _rand(ks[1], (B, L, Hkv, D))
    v = _rand(ks[2], (B, L, Hkv, D))
    kv_len = jnp.array([512, 300], jnp.int32)
    acc, l, m = flash_decode_partials(q, k, v, kv_len, num_splits=S)
    out = ref.lse_combine(acc, l, m).reshape(B, Hkv * G, D)
    want = ref.naive_decode_attention(
        q.reshape(B, Hkv * G, D), k, v, kv_len, scale=1.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Pallas flash prefill kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,lq,lk,window,offset", [
    (1, 4, 1, 128, 128, None, 0),
    (2, 4, 2, 256, 256, None, 0),
    (1, 8, 8, 128, 128, None, 0),          # MHA
    (1, 4, 1, 200, 200, None, 0),          # non-multiple of block
    (1, 4, 1, 256, 256, 64, 0),            # local window
    (1, 2, 1, 64, 320, None, 256),         # chunked prefill offset
])
def test_flash_prefill_vs_oracle(b, hq, hkv, lq, lk, window, offset, dtype):
    rng = jax.random.PRNGKey(lq + lk)
    ks = jax.random.split(rng, 3)
    d = 64
    q = _rand(ks[0], (b, lq, hq, d), dtype)
    k = _rand(ks[1], (b, lk, hkv, d), dtype)
    v = _rand(ks[2], (b, lk, hkv, d), dtype)
    got = flash_prefill(q, k, v, causal=True, window=window,
                        q_offset=offset, interpret=True)
    want = ref.naive_attention(q, k, v, causal=True, window=window,
                               q_offset=offset)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_int8_kv_cache_decode_accuracy():
    """int8 KV cache (§Perf C.4): <=3% attention-output error vs bf16."""
    from repro.configs.reduced import reduced_config
    from repro.models import attention as am
    from repro.models.common import init_params

    cfg = reduced_config("qwen2.5-3b")
    p = init_params(am.attention_specs(cfg), jax.random.PRNGKey(3))
    B, L = 2, 24
    x = (jax.random.normal(jax.random.PRNGKey(4), (B, L, cfg.d_model),
                           jnp.float32) * 0.3).astype(jnp.bfloat16)

    def run(kv_dtype):
        dt = "int8" if kv_dtype == "int8" else "bfloat16"
        c = init_params(am.kv_cache_specs(cfg, B, 32, dt),
                        jax.random.PRNGKey(0))
        outs = []
        for i in range(L):
            y, c = am.attention_decode(p, cfg, x[:, i:i + 1], c,
                                       jnp.int32(i))
            outs.append(y[:, 0])
        return jnp.stack(outs, 1).astype(jnp.float32)

    a, b = run(jnp.bfloat16), run("int8")
    rel = float(jnp.max(jnp.abs(a - b))) / float(jnp.max(jnp.abs(a)))
    assert rel < 0.03, rel


def test_int8_quantize_roundtrip():
    from repro.models.attention import dequantize_kv, quantize_kv
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 2, 16),
                          jnp.float32) * 4.0
    q, s = quantize_kv(x)
    err = np.abs(np.asarray(dequantize_kv(q, s) - x))
    bound = np.asarray(s)[..., None] * 0.5 + 1e-6
    assert (err <= bound).all()


def test_flash_xla_vs_naive_sweep():
    """The blocked-scan XLA path (train default) vs naive."""
    rng = jax.random.PRNGKey(5)
    for (lq, lk, w) in [(64, 64, None), (96, 96, 32), (128, 128, None)]:
        ks = jax.random.split(jax.random.fold_in(rng, lq), 3)
        q = _rand(ks[0], (2, lq, 4, 32))
        k = _rand(ks[1], (2, lk, 2, 32))
        v = _rand(ks[2], (2, lk, 2, 32))
        got = ref.flash_attention_xla(q, k, v, causal=True, window=w,
                                      block_q=32, block_k=32)
        want = ref.naive_attention(q, k, v, causal=True, window=w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
