"""Property-based tests (hypothesis) on the scheduling invariants."""
import math

from _hyp_compat import given, settings, strategies as st

from repro.core.occupancy import (
    H100_SXM,
    TPU_V5E,
    modeled_latency_us,
    occupancy_fraction,
)
from repro.core.scheduler_metadata import bucket_seqlen, get_scheduler_metadata
from repro.core.split_policy import (
    DecodeWorkload,
    choose_mesh_splits,
    choose_num_splits,
    fa3_baseline,
    paper_policy,
    tpu_adaptive,
)

workloads = st.builds(
    DecodeWorkload,
    batch=st.integers(1, 64),
    seqlen_q=st.just(1),
    seqlen_k=st.integers(1, 65536),
    num_heads_q=st.sampled_from([8, 16, 20, 32, 40, 64]),
    num_heads_kv=st.sampled_from([1, 2, 4, 8, 20, 32]),
    head_dim=st.sampled_from([64, 128, 256]),
)


@settings(max_examples=60, deadline=None)
@given(w=workloads, policy=st.sampled_from(["fa3_baseline", "paper",
                                            "tpu_adaptive"]))
def test_split_count_always_valid(w, policy):
    s = choose_num_splits(w, policy=policy)
    assert 1 <= s <= max(1, w.num_n_blocks)


@settings(max_examples=60, deadline=None)
@given(w=workloads, cores=st.sampled_from([4, 8, 16, 132]))
def test_adaptive_never_regresses_modeled_latency(w, cores):
    """tpu_adaptive <= fa3_baseline on the cost model, ALWAYS (its
    argmin includes the baseline's choice)."""
    s_base = fa3_baseline(w, num_cores=cores)
    s_ada = tpu_adaptive(w, num_cores=cores)
    t_base = modeled_latency_us(w, s_base, num_cores=cores)
    t_ada = modeled_latency_us(w, s_ada, num_cores=cores)
    assert t_ada <= t_base * 1.0000001


@settings(max_examples=60, deadline=None)
@given(w=workloads)
def test_paper_only_deviates_in_boundary_bucket(w):
    p, b = paper_policy(w), fa3_baseline(w)
    if p != b:
        assert w.num_n_blocks == 4 and w.total_mblocks < 4 and p == 3


@settings(max_examples=40, deadline=None)
@given(w=workloads, s=st.integers(1, 64))
def test_occupancy_monotone_in_splits(w, s):
    """More splits never DECREASE occupancy (they add tiles)."""
    o1 = occupancy_fraction(w, s)
    o2 = occupancy_fraction(w, s + 1)
    assert o2 >= o1 - 1e-12


@settings(max_examples=40, deadline=None)
@given(w=workloads, chips=st.sampled_from([2, 4, 8, 16, 32]),
       policy=st.sampled_from(["paper", "tpu_adaptive"]))
def test_mesh_splits_divide_axis(w, chips, policy):
    s = choose_mesh_splits(w, chips, policy=policy)
    assert chips % s == 0 and s >= 1


@settings(max_examples=40, deadline=None)
@given(lk=st.integers(1, 100000))
def test_bucketing_is_policy_lossless(lk):
    """Quantizing L_K to the KV block never changes the decision."""
    w1 = DecodeWorkload(1, 1, lk, 64, 1)
    w2 = DecodeWorkload(1, 1, bucket_seqlen(lk), 64, 1)
    for pol in ("fa3_baseline", "paper", "tpu_adaptive"):
        assert choose_num_splits(w1, policy=pol) == \
            choose_num_splits(w2, policy=pol)


def test_metadata_caching_and_override():
    m1 = get_scheduler_metadata(1, 1, 512, 64, 1)
    m2 = get_scheduler_metadata(1, 1, 512, 64, 1)
    assert m1 is m2                       # lru cache hit
    assert m1.num_splits == 3             # paper boundary override
    forced = get_scheduler_metadata(1, 1, 512, 64, 1,
                                    num_splits_override=16)
    assert forced.num_splits == 4         # clamped to nblk


def test_modeled_u_curve_shape():
    """Fig. 3 structure: under-split slow, plateau past the knee."""
    w = DecodeWorkload(1, 1, 512, 64, 1)
    t1 = modeled_latency_us(w, 1, hw=H100_SXM, num_cores=132)
    t3 = modeled_latency_us(w, 3, hw=H100_SXM, num_cores=132)
    t16 = modeled_latency_us(w, 4, hw=H100_SXM, num_cores=132)
    assert t3 < t1                        # splitting wins at the boundary
    assert abs(t16 - t3) / t3 < 0.35      # broad plateau, no cliff
