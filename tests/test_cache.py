"""repro.cache subsystem: spec/manager invariants, layout round trips,
the dense-vs-paged serving oracle, resident-bucket plan keying, page
budgets, ragged kv_len masking, and fallback plan attribution."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.cache import CacheSpec
from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.kernels import ops
from repro.models import build_model
from repro.models.common import init_params
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _reqs(lens=(5, 33, 70, 9), max_new=4, start_id=0):
    return [Request(start_id + i,
                    [(7 * i + j) % 150 + 1 for j in range(n)],
                    max_new_tokens=max_new)
            for i, n in enumerate(lens)]


def _drain(model, cfg, layout, *, max_len=128, slots=2, reqs=None, **kw):
    eng = ServingEngine(
        model, ServeConfig(model=cfg, cache_layout=layout, **kw),
        max_len=max_len, batch_slots=slots)
    eng.load(model.init_params(jax.random.PRNGKey(0)))
    for r in (reqs or _reqs()):
        eng.submit(r)
    outs = eng.drain()
    return [c.tokens for c in outs], outs, eng


# ---------------------------------------------------------------------------
# CacheSpec / CacheManager invariants
# ---------------------------------------------------------------------------


def test_cache_spec_validation_and_extents():
    with pytest.raises(ValueError, match="unknown cache layout"):
        CacheSpec("dense", 2, 64, layout="ragged")
    with pytest.raises(ValueError, match="page_size"):
        CacheSpec("dense", 2, 64, layout="paged", page_size=0)
    with pytest.raises(ValueError, match="page_budget"):
        CacheSpec("dense", 2, 64, layout="paged", page_budget=0)
    s = CacheSpec("dense", 3, 100, layout="paged", page_size=32)
    assert s.slot_pages == 4                   # ceil(100 / 32)
    assert s.total_pages == 12                 # dense-equivalent default
    assert s.pool_pages == 13                  # + trash page
    assert s.pages_for(0) == 0 and s.pages_for(1) == 1
    assert s.pages_for(64) == 2 and s.pages_for(65) == 3
    assert s.view_pages(128) == 4              # capped at slot_pages


def test_manager_free_list_reserve_release(tiny_model):
    cfg, model, _ = tiny_model
    mgr = model.cache_manager(2, 128, layout="paged", page_size=32,
                              page_budget=5)
    assert mgr.free_pages == 5
    assert mgr.can_reserve(128) and not mgr.can_reserve(129 + 32)
    assert mgr.reserve(0, 70)                  # 3 pages
    assert mgr.free_pages == 2
    # all-or-nothing: a grab that cannot complete leaves NO state
    assert not mgr.reserve(1, 100)             # needs 4, only 2 free
    assert mgr.free_pages == 2
    assert mgr.reserve(1, 33)                  # 2 pages
    assert mgr.free_pages == 0
    # ensure() grows one page at a time; exhausted pool refuses
    assert mgr.ensure(0, 69)                   # already covered
    assert not mgr.ensure(0, 96)               # page 4: pool empty
    mgr.release(1)
    assert mgr.free_pages == 2
    assert mgr.ensure(0, 96)
    # released slot's table row is all trash again
    tab = np.asarray(mgr.table_device())
    assert (tab[1] == 0).all()
    # allocated entries are real (non-trash) pages, no duplicates
    live = tab[0][tab[0] != 0]
    assert len(live) == 4 and len(set(live.tolist())) == 4


def test_manager_resident_lengths(tiny_model):
    cfg, model, _ = tiny_model
    mgr = model.cache_manager(2, 64, layout="paged", page_size=32)
    mgr.note_write(0, 9)
    mgr.note_write(1, 41)
    assert mgr.resident_max() == 42
    mgr.release(1)
    assert mgr.resident_max() == 10
    d = mgr.describe()
    assert d["layout"] == "paged" and d["resident_max"] == 10


# ---------------------------------------------------------------------------
# Layout round trips
# ---------------------------------------------------------------------------


def test_paged_gather_scatter_write_token_round_trip(tiny_model):
    cfg, model, _ = tiny_model
    B, L, ps = 2, 128, 32
    mgr = model.cache_manager(B, L, layout="paged", page_size=ps)
    storage = mgr.init_storage()
    assert mgr.reserve(0, 50) and mgr.reserve(1, L)
    table = mgr.table_device()
    n = mgr.spec.view_pages(L)                 # full-capacity view

    key = iter(jax.random.split(jax.random.PRNGKey(1), 64))
    ref_view = jax.tree.map(
        lambda a: jax.random.normal(
            next(key), a.shape[:1] + (B, L) + a.shape[3:]
        ).astype(a.dtype) if a.dtype != jnp.int8 else a,
        mgr.layout.gather_view(storage, table, n))
    storage = mgr.layout.scatter_view(storage, ref_view, table, n)
    got = mgr.layout.gather_view(storage, table, n)

    # slot 1 owns every page -> all rows round-trip; slot 0 owns 2 pages
    # -> its first 64 rows round-trip (the tail went to the trash page)
    for g, r in zip(jax.tree.leaves(got),
                    jax.tree.leaves(ref_view)):
        np.testing.assert_array_equal(np.asarray(g)[:, 1],
                                      np.asarray(r)[:, 1])
        np.testing.assert_array_equal(np.asarray(g)[:, 0, :64],
                                      np.asarray(r)[:, 0, :64])

    # write_token: only the page holding each slot's row changes
    t = jnp.array([49, 99], jnp.int32)
    new_view = jax.tree.map(lambda a: a + 1 if a.dtype != jnp.int8 else a,
                            got)
    storage = mgr.layout.write_token(storage, new_view, table, t, n)
    after = mgr.layout.gather_view(storage, table, n)
    for a, nv, g in zip(jax.tree.leaves(after),
                        jax.tree.leaves(new_view),
                        jax.tree.leaves(got)):
        a, nv, g = np.asarray(a), np.asarray(nv), np.asarray(g)
        # slot 0 wrote row 49's page [32, 64); rows [0, 32) untouched
        np.testing.assert_array_equal(a[:, 0, :32], g[:, 0, :32])
        np.testing.assert_array_equal(a[:, 0, 32:64], nv[:, 0, 32:64])
        # slot 1 wrote row 99's page [96, 128)
        np.testing.assert_array_equal(a[:, 1, :96], g[:, 1, :96])
        np.testing.assert_array_equal(a[:, 1, 96:], nv[:, 1, 96:])


def test_dense_layout_is_bit_identical_legacy(tiny_model):
    cfg, model, _ = tiny_model
    legacy = init_params(model.cache_specs(2, 32, "bfloat16"),
                         jax.random.PRNGKey(0))
    via_manager = model.init_cache(2, 32)
    for a, b in zip(jax.tree.leaves(legacy),
                    jax.tree.leaves(via_manager)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unsupported_families_stay_dense():
    cfg = reduced_config("mamba2-780m", num_layers=2, d_model=32)
    model = build_model(cfg)
    assert not model.supports_paged_cache
    with pytest.raises(ValueError, match="not position-linear"):
        model.cache_spec(2, 64, layout="paged")
    with pytest.raises(ValueError, match="not position-linear"):
        ServingEngine(model, ServeConfig(model=cfg, cache_layout="paged"),
                      max_len=64, batch_slots=2)


# ---------------------------------------------------------------------------
# Dense-vs-paged serving oracle (the acceptance bit-equality claim)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "minicpm3-4b",
                                  "whisper-large-v3"])
def test_paged_matches_dense_greedy_oracle(arch):
    cfg = reduced_config(arch, num_layers=2, d_model=32)
    model = build_model(cfg)
    dense, _, _ = _drain(model, cfg, "dense")
    ops.reset_policy_eval_count()
    paged, _, eng = _drain(model, cfg, "paged", cache_page_size=32)
    assert dense == paged, f"{arch}: paged layout changed greedy tokens"
    if cfg.family != "encdec":
        # encdec cross-attention evaluates the policy once per TRACE
        # (fixed encoder length, pre-existing); self-attention families
        # must stay at zero even across compiles
        assert ops.policy_eval_count() == 0
    assert eng.cache_stats()["free_pages"] == \
        eng.cache_stats()["total_pages"]       # drained engine: all freed


def test_paged_matches_dense_int8_kv(tiny_model):
    cfg, model, _ = tiny_model
    dense, _, _ = _drain(model, cfg, "dense", kv_cache_dtype="int8")
    paged, _, _ = _drain(model, cfg, "paged", kv_cache_dtype="int8",
                         cache_page_size=32)
    assert dense == paged, "int8 scales leaf broke under paging"


def test_paged_loop_admission_matches_dense(tiny_model):
    cfg, model, _ = tiny_model
    dense, _, _ = _drain(model, cfg, "dense", prefill_mode="loop")
    paged, _, _ = _drain(model, cfg, "paged", prefill_mode="loop",
                         cache_page_size=32)
    assert dense == paged


def test_paged_requires_metadata_path(tiny_model):
    cfg, model, _ = tiny_model
    with pytest.raises(ValueError, match="metadata-enabled"):
        ServingEngine(model, ServeConfig(model=cfg, cache_layout="paged",
                                         use_scheduler_metadata=False),
                      max_len=64, batch_slots=2)
    with pytest.raises(ValueError, match="divide"):
        ServingEngine(model, ServeConfig(model=cfg, cache_layout="paged",
                                         cache_page_size=48),
                      max_len=96, batch_slots=2)


# ---------------------------------------------------------------------------
# Resident-length plan keying (the acceptance planning claim)
# ---------------------------------------------------------------------------


def test_plans_key_on_resident_buckets_not_padded_capacity(tiny_model):
    """A short-context request in a LONG-capacity engine must plan (and
    under the paged layout, attend) on the resident bucket — and that
    plan must be smaller-split than the padded-``max_len`` plan the old
    keying would have frozen."""
    cfg, model, params = tiny_model
    eng = ServingEngine(
        model, ServeConfig(model=cfg, cache_layout="paged"),
        max_len=2048, batch_slots=2)
    eng.load(params)
    eng.submit(Request(0, [3, 1, 4, 1, 5], max_new_tokens=4))
    eng.drain()
    splits = eng.planned_splits()
    assert set(splits) == {128}, \
        f"expected only the 128-resident bucket, got {sorted(splits)}"
    assert eng.stats.seen_buckets == {("prefill", 128), 128}
    padded = eng.sched.planner.plan(eng.sched.decode_spec(2048),
                                    bucket=2048)
    assert splits[128] < padded.num_splits, (
        "resident-bucket plan must be smaller-split than the padded "
        f"max_len plan ({splits[128]} vs {padded.num_splits})")


# ---------------------------------------------------------------------------
# Page-budget admission + per-request exhaustion
# ---------------------------------------------------------------------------


def test_page_budget_gates_admission_and_finishes_per_request(tiny_model):
    cfg, model, params = tiny_model
    eng = ServingEngine(
        model, ServeConfig(model=cfg, cache_layout="paged",
                           cache_page_size=16, cache_page_budget=5),
        max_len=128, batch_slots=2)
    eng.load(params)
    # prompt that could NEVER fit the pool is refused at submit
    with pytest.raises(ValueError, match="page budget"):
        eng.submit(Request(9, list(range(1, 100)), max_new_tokens=1))
    eng.submit(Request(0, list(range(1, 40)), max_new_tokens=60))  # 3 pages
    eng.submit(Request(1, list(range(1, 30)), max_new_tokens=60))  # 2 pages
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        outs = eng.drain()
    # oversubscribed (5 pages, both want to grow): each request finishes
    # with its OWN page-exhaustion signal, not an engine-wide wall
    assert [c.finish_reason for c in outs] == ["cache_capacity"] * 2
    assert all(c.tokens for c in outs)
    assert any("page pool" in str(x.message) for x in w)
    assert eng.cache_stats()["free_pages"] == 5


def test_budget_blocks_fifo_head_until_pages_free(tiny_model):
    cfg, model, params = tiny_model
    eng = ServingEngine(
        model, ServeConfig(model=cfg, cache_layout="paged",
                           cache_page_size=16, cache_page_budget=4),
        max_len=128, batch_slots=2)
    eng.load(params)
    eng.submit(Request(0, list(range(1, 40)), max_new_tokens=3))  # 3 pages
    eng.submit(Request(1, list(range(1, 40)), max_new_tokens=3))  # 3 pages
    ev = eng.step()
    # only ONE admission fit the pool: a free slot alone is not enough
    assert len(eng.sched.live()) == 1
    outs = eng.drain()                         # head unblocks on finish
    assert sorted(c.request_id for c in outs) == [0, 1]
    assert all(c.finish_reason == "length" for c in outs)


# ---------------------------------------------------------------------------
# Ragged kv_len masking (property, xla + pallas)
# ---------------------------------------------------------------------------


def _trimmed_reference(q, k, v, kv_len):
    """Independent per-slot oracle: attention over the TRIMMED cache."""
    outs = []
    for b in range(q.shape[0]):
        n = int(kv_len[b])
        qb = q[b].astype(np.float32)                     # (Hq, D)
        kb = k[b, :n].astype(np.float32)                 # (n, Hkv, D)
        vb = v[b, :n].astype(np.float32)
        g = qb.shape[0] // kb.shape[1]
        kb = np.repeat(kb, g, axis=1)
        vb = np.repeat(vb, g, axis=1)
        s = np.einsum("hd,nhd->hn", qb, kb) / np.sqrt(q.shape[-1])
        s = s - s.max(axis=1, keepdims=True)
        p = np.exp(s)
        p /= p.sum(axis=1, keepdims=True)
        outs.append(np.einsum("hn,nhd->hd", p, vb))
    return np.stack(outs)


@settings(max_examples=12, deadline=None)
@given(batch=st.integers(1, 4), seqlen=st.sampled_from([32, 64, 96]),
       heads=st.sampled_from([(4, 1), (4, 2), (2, 2)]),
       seed=st.integers(0, 2 ** 16))
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_ragged_kv_len_masking_matches_trimmed_reference(
        impl, batch, seqlen, heads, seed):
    """Per-slot ``kv_len``-masked decode over a PADDED cache (garbage in
    the tail — exactly what paged gathers produce past a slot's
    residency) is bit-equal in math to trimmed-cache attention."""
    hq, hkv = heads
    D = 8
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((batch, hq, D), np.float32)
    k = rng.standard_normal((batch, seqlen, hkv, D), np.float32)
    v = rng.standard_normal((batch, seqlen, hkv, D), np.float32)
    kv_len = rng.integers(1, seqlen + 1, size=batch).astype(np.int32)
    # poison the padded tail: masking, not luck, must keep it out
    for b in range(batch):
        k[b, kv_len[b]:] = 1e4
        v[b, kv_len[b]:] = -1e4
    got = ops.decode_attention(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), jnp.asarray(kv_len),
                               impl=impl)
    want = _trimmed_reference(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5,
                               atol=2e-5)


def test_decode_attention_accepts_paged_kv_views():
    """kernels.ops.decode_attention's layout-aware gather path: a
    per-tensor :class:`ops.PagedKV` view (pool + page table + static
    num_pages) attends identically to its gathered dense equivalent."""
    rng = np.random.default_rng(0)
    B, hq, hkv, D, ps, n = 2, 4, 1, 8, 16, 3   # view_len = 48
    pool = 2 * n + 1                           # page 0 = trash
    kp = jnp.asarray(rng.standard_normal((pool, ps, hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((pool, ps, hkv, D)), jnp.float32)
    table = jnp.asarray([[1, 2, 3, 0], [4, 5, 6, 0]], jnp.int32)
    kv_len = jnp.asarray([40, 17], jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, hq, D)), jnp.float32)
    pk = ops.PagedKV(kp, table, n)
    pv = ops.PagedKV(vp, table, n)
    assert pk.view_len == 48
    kd = ops.gather_pages(kp, table, num_pages=n)
    vd = ops.gather_pages(vp, table, num_pages=n)
    got = ops.decode_attention(q, pk, pv, kv_len)
    want = ops.decode_attention(q, kd, vd, kv_len)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Fallback-plan attribution (PlanCacheStats.fallback_trace)
# ---------------------------------------------------------------------------


def test_fallback_launches_record_resident_summary(tiny_model):
    """The internal-heuristic path traces ONE step on the padded cache
    length; every launch must record (resident_max, traced_len) so A/Bs
    can attribute fallback plans to the residency they served."""
    cfg, model, params = tiny_model
    eng = ServingEngine(
        model, ServeConfig(model=cfg, use_scheduler_metadata=False),
        max_len=256, batch_slots=2)
    eng.load(params)
    eng.submit(Request(0, [5, 6, 7], max_new_tokens=4))
    eng.drain()
    st = eng.stats
    assert st.fallback_launches > 0
    assert len(st.fallback_trace) == st.fallback_launches
    residents = [r for r, _ in st.fallback_trace]
    assert all(t == 256 for _, t in st.fallback_trace)
    assert residents == sorted(residents)      # lockstep growth
    assert max(residents) < 256                # plan covered padding only
    # the metadata-enabled engine records NO fallback launches
    eng2 = ServingEngine(model, ServeConfig(model=cfg), max_len=256,
                         batch_slots=2)
    eng2.load(params)
    eng2.submit(Request(0, [5, 6, 7], max_new_tokens=4))
    eng2.drain()
    assert eng2.stats.fallback_launches == 0
    assert eng2.stats.fallback_trace == []
    st.reset()
    assert st.fallback_launches == 0 and st.fallback_trace == []
