"""repro.quant subsystem: QuantSpec -> Quantizer -> QuantizedKV.

The load-bearing guarantees:

- the int8 ``per_head`` / ``abs_max`` path is bit-identical to the
  legacy ``models.attention.quantize_kv`` (existing engines, caches and
  golden token streams unchanged by construction),
- fused in-kernel dequant (Pallas) agrees with the unfused
  dequant-then-attend reference within ``AB_ATOL`` per dtype, across
  random shapes, ragged ``kv_len`` and page layouts, with POISONED
  unallocated tails (data and scales) — masking, not luck,
- roundtrip error is bounded by ``Quantizer.row_error_bound``,
- ``AttentionSpec.quantized`` is deprecated with a compat shim
  (warns once, normalizes to ``kv_dtype="int8"``; replace/bucketed
  never re-warn) and fp8 never keys or serves int8 table families,
- the serving engine under ``ServeConfig.kv_quant="int8"`` emits
  identical greedy streams across dense / paged / prefix-sharing /
  speculation, with the split policy out of traced code and page
  conservation intact.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp_compat import given, settings, strategies as st

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.core.split_policy import KV_DTYPES, DecodeWorkload
from repro.kernels import ops
from repro.models import build_model
from repro.models.attention import dequantize_kv, quantize_kv
from repro.plan import AttentionSpec, Planner
from repro.quant import (
    AB_ATOL,
    QUANT_DTYPES,
    QuantizedKV,
    QuantSpec,
    Quantizer,
)
from repro.serving import Request, ServingEngine
from repro.tune import Calibrator, SplitTable, TuneSpec


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# QuantSpec: validation surface
# ---------------------------------------------------------------------------


def test_spec_validates_fields():
    assert QuantSpec().kv_dtype == "int8"
    assert QuantSpec(kv_dtype="fp8").dtype_bytes == 1
    with pytest.raises(ValueError, match="kv_dtype"):
        QuantSpec(kv_dtype="int4")
    with pytest.raises(ValueError, match="granularity"):
        QuantSpec(granularity="per_tensor")
    with pytest.raises(ValueError, match="amax mode"):
        QuantSpec(amax_mode="percentile")
    with pytest.raises(ValueError, match="static_amax"):
        QuantSpec(amax_mode="static")          # needs the value
    with pytest.raises(ValueError, match="eps"):
        QuantSpec(eps=0.0)


def test_quant_dtypes_registry_is_the_policy_registry():
    """One byte-width registry: every QUANT_DTYPES entry must exist in
    split_policy.KV_DTYPES with the width the storage dtype actually
    has — the planner and the quantizer can never disagree on bytes."""
    for name, qd in QUANT_DTYPES.items():
        assert KV_DTYPES[name] == jnp.dtype(qd.storage).itemsize == 1


# ---------------------------------------------------------------------------
# Quantizer: numerics
# ---------------------------------------------------------------------------


def test_int8_bit_identical_to_legacy_quantize_kv():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 17, 3, 8)), jnp.float32)
    qz = Quantizer()
    q, s = qz.quantize(x)
    lq, ls = quantize_kv(x)
    assert q.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q), np.asarray(lq))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ls))
    np.testing.assert_array_equal(np.asarray(qz.dequantize(q, s)),
                                  np.asarray(dequantize_kv(lq, ls)))


@settings(max_examples=25, deadline=None)
@given(kv_dtype=st.sampled_from(["int8", "fp8"]),
       L=st.integers(1, 40), H=st.integers(1, 4),
       D=st.sampled_from([4, 8, 16]), seed=st.integers(0, 99))
def test_roundtrip_error_within_bound(kv_dtype, L, H, D, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(10.0 * rng.standard_normal((L, H, D)), jnp.float32)
    qz = Quantizer.from_kv_dtype(kv_dtype)
    q, s = qz.quantize(x)
    err = jnp.abs(qz.dequantize(q, s) - x)
    bound = qz.row_error_bound(s)[..., None]
    assert bool(jnp.all(err <= bound + 1e-7))


def test_per_page_granularity_pools_scales():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 10, 2, 4)), jnp.float32)
    qz = Quantizer(QuantSpec(granularity="per_page"))
    with pytest.raises(ValueError, match="page_size"):
        qz.quantize(x)
    _, s = qz.quantize(x, page_size=4)
    s = np.asarray(s)
    assert s.shape == (1, 10, 2)
    for p0 in (0, 4):                    # full pages share one scale
        assert np.all(s[:, p0:p0 + 4] == s[:, p0:p0 + 1])
    # the ragged last page pools over its own rows only
    assert np.all(s[:, 8:10] == s[:, 8:9])


def test_static_amax_mode():
    x = jnp.asarray([[[0.5, -2.0]]], jnp.float32)
    qz = Quantizer(QuantSpec(amax_mode="static", static_amax=4.0))
    _, s = qz.quantize(x)
    np.testing.assert_allclose(np.asarray(s), 4.0 / 127.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# Fused (in-kernel dequant) vs unfused (dequant-then-attend): the oracle
# ---------------------------------------------------------------------------


def _poisoned(rng, B, Lk, hq, hkv, D, kv_dtype):
    q = jnp.asarray(rng.standard_normal((B, hq, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Lk, hkv, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Lk, hkv, D)), jnp.float32)
    kv_len = jnp.asarray(rng.integers(1, Lk + 1, size=B), jnp.int32)
    art = Quantizer.from_kv_dtype(kv_dtype).quantized_kv(k, v)
    rows = jnp.arange(Lk)[None, :, None] >= kv_len[:, None, None]
    return q, art._replace(
        k=jnp.where(rows[..., None], jnp.asarray(127, art.k.dtype), art.k),
        v=jnp.where(rows[..., None], jnp.asarray(-127, art.v.dtype), art.v),
        k_scale=jnp.where(rows, 1e4, art.k_scale),
        v_scale=jnp.where(rows, 1e4, art.v_scale)), kv_len


@settings(max_examples=12, deadline=None)
@given(kv_dtype=st.sampled_from(["int8", "fp8"]),
       batch=st.integers(1, 3),
       seqlen=st.sampled_from([32, 64, 96, 160, 257]),
       heads=st.sampled_from([(4, 1), (8, 2), (4, 4)]),
       seed=st.integers(0, 99))
def test_fused_matches_unfused_within_tolerance(kv_dtype, batch, seqlen,
                                                heads, seed):
    """Fused Pallas in-register dequant vs the materialized reference,
    SAME artifact both sides: the quantization error cancels, the bound
    covers kernel accumulation drift only.  Tails past each row's
    kv_len are poisoned in data AND scales."""
    hq, hkv = heads
    rng = np.random.default_rng(seed)
    q, art, kv_len = _poisoned(rng, batch, seqlen, hq, hkv, 8, kv_dtype)
    fused = ops.decode_attention_quant(q, art, kv_len, impl="pallas")
    unfused = ops.decode_attention_quant(q, art, kv_len, impl="xla")
    assert bool(jnp.all(jnp.isfinite(fused)))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               atol=AB_ATOL[kv_dtype], rtol=0)


def test_unfused_is_exactly_dequant_then_attend():
    rng = np.random.default_rng(3)
    q, art, kv_len = _poisoned(rng, 2, 64, 4, 1, 8, "int8")
    qz = Quantizer()
    got = ops.decode_attention_quant(q, art, kv_len, impl="xla")
    want = ops.decode_attention(q, qz.dequantize(art.k, art.k_scale),
                                qz.dequantize(art.v, art.v_scale),
                                kv_len, impl="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(kv_dtype=st.sampled_from(["int8", "fp8"]),
       page_size=st.sampled_from([8, 16]),
       num_pages=st.integers(2, 4), seed=st.integers(0, 99))
def test_fused_paged_views_match_dense_gather(kv_dtype, page_size,
                                              num_pages, seed):
    """PagedKV quant views (scale pools page with the data pools under
    ONE page table) attend bit-equal to their dense-gathered launch —
    trash-page rows land past kv_len and are masked."""
    B, hq, hkv, D = 2, 4, 1, 8
    rng = np.random.default_rng(seed)
    pool = B * num_pages + 1                    # page 0 = trash
    kp = jnp.asarray(rng.standard_normal((pool, page_size, hkv, D)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((pool, page_size, hkv, D)),
                     jnp.float32)
    table = jnp.asarray(
        [[1 + b * num_pages + p for p in range(num_pages)] + [0]
         for b in range(B)], jnp.int32)
    view = num_pages * page_size
    kv_len = jnp.asarray(rng.integers(1, view + 1, size=B), jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, hq, D)), jnp.float32)
    qz = Quantizer.from_kv_dtype(kv_dtype)
    kq, ks = qz.quantize(kp)
    vq, vs = qz.quantize(vp)
    paged = ops.decode_attention_quant(
        q, (ops.PagedKV(kq, table, num_pages),
            ops.PagedKV(vq, table, num_pages),
            ops.PagedKV(ks, table, num_pages),
            ops.PagedKV(vs, table, num_pages)), kv_len, impl="pallas")
    dense = ops.decode_attention_quant(
        q, (ops.gather_pages(kq, table, num_pages=num_pages),
            ops.gather_pages(vq, table, num_pages=num_pages),
            ops.gather_pages(ks, table, num_pages=num_pages),
            ops.gather_pages(vs, table, num_pages=num_pages)),
        kv_len, impl="pallas")
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))


# ---------------------------------------------------------------------------
# AttentionSpec: the deprecated boolean, and name-keyed families
# ---------------------------------------------------------------------------


def test_quantized_flag_warns_and_normalizes_to_int8():
    with pytest.warns(DeprecationWarning, match="kv_dtype"):
        spec = AttentionSpec.decode(1, 512, 64, 1, 128, quantized=True)
    assert spec.kv_dtype == "int8"
    assert spec == AttentionSpec.decode(1, 512, 64, 1, 128,
                                        kv_dtype="int8")
    # normalized specs never re-warn through replace / bucketed
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert spec.bucketed().kv_dtype == "int8"
        assert dataclasses.replace(spec, seqlen_k=640).quantized


def test_explicit_kv_dtype_does_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        s8 = AttentionSpec.decode(1, 512, 64, 1, 128, kv_dtype="int8")
        sf = AttentionSpec.decode(1, 512, 64, 1, 128, kv_dtype="fp8")
    assert s8.quantized and sf.quantized
    assert s8 != sf                         # same bytes, distinct family
    with pytest.raises(ValueError, match="kv_dtype"):
        AttentionSpec.decode(1, 512, 64, 1, 128, kv_dtype="int4")


def test_fp8_never_matches_int8_table_cells():
    """Same byte width, different family: an fp8 workload must fall
    back (counted), never serve an int8 cell."""
    spec = TuneSpec(lk_buckets=(512,), batches=(1,),
                    head_shapes=((64, 1, 128),), dtypes=("int8",))
    table = Calibrator(spec, mode="modeled", seed=0).calibrate()
    w8 = DecodeWorkload(1, 1, 512, 64, 1, 128,
                        dtype_bytes=1, kv_dtype="int8")
    wf = DecodeWorkload(1, 1, 512, 64, 1, 128,
                        dtype_bytes=1, kv_dtype="fp8")
    assert table.covers(w8) and not table.covers(wf)
    before = table.fallbacks
    _, tuned = table.choose(wf)
    assert not tuned and table.fallbacks == before + 1
    planner = Planner(policy="measured", table=table)
    assert planner.plan(AttentionSpec.from_workload(w8)).tuned
    assert not planner.plan(AttentionSpec.from_workload(wf)).tuned


def test_workload_dtype_name_consistency():
    with pytest.raises(ValueError, match="kv_dtype"):
        DecodeWorkload(1, 1, 512, 64, 1, 128,
                       dtype_bytes=2, kv_dtype="int8")
    w = DecodeWorkload(1, 1, 512, 64, 1, 128, dtype_bytes=1)
    assert w.kv_dtype == "int8"             # legacy byte-width inference


# ---------------------------------------------------------------------------
# Calibrator: the fused-quant wallclock harness + validate()'s message
# ---------------------------------------------------------------------------


def test_wallclock_quant_cells_record_wallclock_source():
    spec = TuneSpec(lk_buckets=(128,), batches=(1,),
                    head_shapes=((4, 1, 8),), dtypes=("bfloat16", "int8"),
                    repeats=2, warmup=1)
    table = Calibrator(spec, mode="wallclock", seed=0).calibrate()
    srcs = {e["kv_dtype"]: e["source"] for e in table.entries}
    assert srcs == {"bfloat16": "measured", "int8": "wallclock"}
    assert table.fingerprint["sources"] == "measured"
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # fully measured: no nag
        table.validate()


def test_validate_flags_mixed_sources_actionably():
    spec = TuneSpec(lk_buckets=(128, 256), batches=(1,),
                    head_shapes=((4, 1, 8),), dtypes=("int8",),
                    budget_s=0.0)
    table = Calibrator(spec, mode="wallclock", seed=0).calibrate()
    assert table.fingerprint["sources"] == "mixed"
    with pytest.warns(UserWarning, match="--mode wallclock"):
        table.validate()


# ---------------------------------------------------------------------------
# Engine: one greedy stream across the whole serving matrix at int8
# ---------------------------------------------------------------------------

_MATRIX = [
    ("dense", {}),
    ("paged", {"cache_layout": "paged"}),
    ("paged+prefix", {"cache_layout": "paged", "share_prefix": True}),
    ("paged+spec", {"cache_layout": "paged", "speculation": "ngram",
                    "speculation_k": 3}),
]


def _stream(model, params, kv_quant, **kw):
    eng = ServingEngine(
        model, ServeConfig(model=model.cfg, kv_quant=kv_quant, **kw),
        max_len=128, batch_slots=2)
    eng.load(params)
    ops.reset_policy_eval_count()
    shared = [7, 3, 7, 3, 7, 3, 7, 3]
    for i in range(3):
        eng.submit(Request(i, shared + [11 + i, 5, 11 + i],
                           max_new_tokens=6))
    outs = eng.drain()
    assert ops.policy_eval_count() == 0
    if kw.get("cache_layout") == "paged":
        eng.cache.check_conservation()
    return [c.tokens for c in sorted(outs, key=lambda c: c.request_id)]


def test_engine_int8_streams_identical_across_matrix(tiny_model):
    cfg, model, params = tiny_model
    streams = {name: _stream(model, params, "int8", **kw)
               for name, kw in _MATRIX}
    for name, toks in streams.items():
        assert toks == streams["dense"], f"{name} diverged"


def test_engine_kv_quant_resolution_and_family_keying(tiny_model):
    cfg, model, params = tiny_model
    eng = ServingEngine(model, ServeConfig(model=cfg, kv_quant="fp8"),
                        max_len=128, batch_slots=2)
    assert eng.kv_dtype == "fp8"
    w = eng.sched.decode_spec(128).workload()
    assert (w.dtype_bytes, w.kv_dtype) == (1, "fp8")
    d = eng.sched.decode_plan(100).describe()
    assert d["kv_dtype"] == "fp8" and d["dtype_bytes"] == 1
    # kv_quant wins over the legacy dtype knob; unknown names fail fast
    eng2 = ServingEngine(
        model, ServeConfig(model=cfg, kv_quant="int8",
                           kv_cache_dtype="bfloat16"),
        max_len=128, batch_slots=2)
    assert eng2.kv_dtype == "int8"
    with pytest.raises(ValueError, match="kv_quant"):
        ServingEngine(model, ServeConfig(model=cfg, kv_quant="int4"),
                      max_len=128, batch_slots=2)


def test_engine_fp8_generates_and_differs_from_int8_plans(tiny_model):
    """fp8 serves end-to-end (cache leaves in float8 storage) and its
    plans key the fp8 family — never the int8 one."""
    cfg, model, params = tiny_model
    toks = _stream(model, params, "fp8")
    assert all(len(t) == 6 for t in toks)
    s8 = _stream(model, params, "int8")
    assert all(len(t) == 6 for t in s8)
