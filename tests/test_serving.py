"""Serving engine: continuous batching, metadata path, policy A/B."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.core.scheduler_metadata import get_scheduler_metadata
from repro.models import build_model
from repro.serving.engine import DecodeEngine, Request


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(cfg, model, params, slots, policy="paper"):
    eng = DecodeEngine(model, ServeConfig(model=cfg, split_policy=policy),
                       max_len=64, batch_slots=slots)
    eng.load(params)
    return eng


def test_generation_deterministic_across_slot_counts(tiny_model):
    """Continuous batching must not change results: the same requests
    produce the same tokens with 1 slot (serial) and 3 slots (batched +
    refill)."""
    cfg, model, params = tiny_model
    reqs = [Request(i, [1 + i, 2, 3], max_new_tokens=6) for i in range(5)]
    out1 = _engine(cfg, model, params, 1).generate(
        [Request(r.request_id, list(r.prompt), r.max_new_tokens)
         for r in reqs])
    out3 = _engine(cfg, model, params, 3).generate(
        [Request(r.request_id, list(r.prompt), r.max_new_tokens)
         for r in reqs])
    assert [c.tokens for c in out1] == [c.tokens for c in out3]


def test_engine_honors_budget_and_eos(tiny_model):
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 2)
    outs = eng.generate([Request(0, [1, 2], max_new_tokens=3),
                         Request(1, [3], max_new_tokens=10)])
    assert len(outs[0].tokens) == 3
    assert len(outs[1].tokens) == 10


def test_slot_reset_no_state_leak(tiny_model):
    """A request running after a refill matches the same request run
    fresh — recurrent/cache state must not leak between requests."""
    cfg, model, params = tiny_model
    # one slot: r0 then r1 reuse the same slot
    outs = _engine(cfg, model, params, 1).generate(
        [Request(0, [9, 8, 7], max_new_tokens=4),
         Request(1, [5, 5], max_new_tokens=4)])
    fresh = _engine(cfg, model, params, 1).generate(
        [Request(1, [5, 5], max_new_tokens=4)])
    assert outs[1].tokens == fresh[0].tokens


def test_policies_agree_on_tokens(tiny_model):
    """The split policy changes the SCHEDULE, never the math: greedy
    tokens agree between the flawed baseline and the paper policy."""
    cfg, model, params = tiny_model
    reqs = lambda: [Request(0, [2, 4, 6], max_new_tokens=5)]
    base = _engine(cfg, model, params, 1, "fa3_baseline").generate(reqs())
    pap = _engine(cfg, model, params, 1, "paper").generate(reqs())
    ada = _engine(cfg, model, params, 1, "tpu_adaptive").generate(reqs())
    assert base[0].tokens == pap[0].tokens == ada[0].tokens


def test_metadata_plan_lookup(tiny_model):
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 2)
    md = eng._metadata(500)
    # clamped to the engine's cache (64) then bucketed to the KV block
    assert md.workload.seqlen_k == 128
    assert md.num_splits >= 1
    eng_big = DecodeEngine(model, ServeConfig(model=cfg), max_len=1024,
                           batch_slots=2)
    md2 = eng_big._metadata(500)
    assert md2.workload.seqlen_k == 512         # bucketed, not clamped
