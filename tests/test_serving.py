"""Serving engine: continuous batching, metadata path, policy A/B."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.core.scheduler_metadata import get_scheduler_metadata
from repro.kernels import ops
from repro.models import build_model
from repro.serving.engine import DecodeEngine, Request


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(cfg, model, params, slots, policy="paper"):
    eng = DecodeEngine(model, ServeConfig(model=cfg, split_policy=policy),
                       max_len=64, batch_slots=slots)
    eng.load(params)
    return eng


def test_generation_deterministic_across_slot_counts(tiny_model):
    """Continuous batching must not change results: the same requests
    produce the same tokens with 1 slot (serial) and 3 slots (batched +
    refill)."""
    cfg, model, params = tiny_model
    reqs = [Request(i, [1 + i, 2, 3], max_new_tokens=6) for i in range(5)]
    out1 = _engine(cfg, model, params, 1).generate(
        [Request(r.request_id, list(r.prompt), r.max_new_tokens)
         for r in reqs])
    out3 = _engine(cfg, model, params, 3).generate(
        [Request(r.request_id, list(r.prompt), r.max_new_tokens)
         for r in reqs])
    assert [c.tokens for c in out1] == [c.tokens for c in out3]


def test_engine_honors_budget_and_eos(tiny_model):
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 2)
    outs = eng.generate([Request(0, [1, 2], max_new_tokens=3),
                         Request(1, [3], max_new_tokens=10)])
    assert len(outs[0].tokens) == 3
    assert len(outs[1].tokens) == 10


def test_slot_reset_no_state_leak(tiny_model):
    """A request running after a refill matches the same request run
    fresh — recurrent/cache state must not leak between requests."""
    cfg, model, params = tiny_model
    # one slot: r0 then r1 reuse the same slot
    outs = _engine(cfg, model, params, 1).generate(
        [Request(0, [9, 8, 7], max_new_tokens=4),
         Request(1, [5, 5], max_new_tokens=4)])
    fresh = _engine(cfg, model, params, 1).generate(
        [Request(1, [5, 5], max_new_tokens=4)])
    assert outs[1].tokens == fresh[0].tokens


def test_policies_agree_on_tokens(tiny_model):
    """The split policy changes the SCHEDULE, never the math: greedy
    tokens agree between the flawed baseline and the paper policy."""
    cfg, model, params = tiny_model
    reqs = lambda: [Request(0, [2, 4, 6], max_new_tokens=5)]
    base = _engine(cfg, model, params, 1, "fa3_baseline").generate(reqs())
    pap = _engine(cfg, model, params, 1, "paper").generate(reqs())
    ada = _engine(cfg, model, params, 1, "tpu_adaptive").generate(reqs())
    assert base[0].tokens == pap[0].tokens == ada[0].tokens


def test_metadata_plan_lookup(tiny_model):
    cfg, model, params = tiny_model
    eng = _engine(cfg, model, params, 2)
    md = eng._metadata(500)
    # clamped to the engine's cache (64) then bucketed to the KV block
    assert md.workload.seqlen_k == 128
    assert md.num_splits >= 1
    eng_big = DecodeEngine(model, ServeConfig(model=cfg), max_len=1024,
                           batch_slots=2)
    md2 = eng_big._metadata(500)
    assert md2.workload.seqlen_k == 512         # bucketed, not clamped


# ---------------------------------------------------------------------------
# Metadata-enabled path: plan cache, specialization, policy A/B
# ---------------------------------------------------------------------------


def test_plan_cache_hits_and_recompile_count(tiny_model):
    """Repeated buckets HIT the plan cache; the recompile count (== plan
    misses) equals the number of distinct buckets actually visited."""
    cfg, model, params = tiny_model
    eng = DecodeEngine(model, ServeConfig(model=cfg), max_len=300,
                       batch_slots=2)
    eng.load(params)
    # run past position 128 so both the 128 and 256 buckets are visited
    eng.generate([Request(0, [1, 2, 3], max_new_tokens=8),
                  Request(1, [4, 5], max_new_tokens=150)])
    st = eng.stats
    assert st.total_launches == len(st.trace) == sum(st.launches.values())
    assert st.distinct_buckets == 2                  # 128 then 256
    assert st.misses == st.distinct_buckets          # one compile per bucket
    assert st.misses == len(eng.planned_splits())
    assert st.hits == st.total_launches - st.misses > 0
    assert st.launches[128] > 0 and st.launches[256] > 0


def test_plan_cache_capacity_evicts_oldest(tiny_model):
    cfg, model, params = tiny_model
    eng = DecodeEngine(
        model, ServeConfig(model=cfg, plan_cache_capacity=1),
        max_len=300, batch_slots=1)
    eng.load(params)
    eng.generate([Request(0, [1, 2], max_new_tokens=150)])
    assert eng.stats.distinct_buckets == 2
    assert len(eng.planned_splits()) == 1            # oldest plan evicted
    assert list(eng.planned_splits()) == [256]


def test_policy_never_evaluated_inside_metadata_step(tiny_model):
    """The frozen-plan step must not run the split policy at trace time;
    the internal-heuristic fallback must (that is the A/B the paper
    draws).  Fresh engines force a fresh trace either way."""
    cfg, model, params = tiny_model
    reqs = lambda: [Request(0, [1, 2, 3], max_new_tokens=6)]

    eng = _engine(cfg, model, params, 1)
    ops.reset_policy_eval_count()
    out_md = eng.generate(reqs())
    assert ops.policy_eval_count() == 0

    eng_fb = DecodeEngine(
        model, ServeConfig(model=cfg, use_scheduler_metadata=False),
        max_len=64, batch_slots=1)
    eng_fb.load(params)
    out_fb = eng_fb.generate(reqs())
    assert ops.policy_eval_count() > 0               # trace-time eval
    assert eng_fb.stats.total_launches == 0          # plan cache idle
    assert [c.tokens for c in out_md] == [c.tokens for c in out_fb]


def test_policy_ab_low_head_count_shape(tiny_model):
    """The paper's target shape (B=1, MQA H_KV=1, L_K=512): fa3_baseline
    and paper policies freeze DIFFERENT split plans, yet decode the same
    tokens (the policy changes the schedule, never the math)."""
    cfg, model, params = tiny_model
    assert cfg.num_kv_heads == 1                     # reduced qwen is MQA

    def engine(policy):
        eng = DecodeEngine(
            model, ServeConfig(model=cfg, split_policy=policy),
            max_len=512, batch_slots=1)
        eng.load(params)
        return eng

    base, pap = engine("fa3_baseline"), engine("paper")
    md_base, md_pap = base._metadata(500), pap._metadata(500)
    assert md_base.workload.seqlen_k == 512
    assert md_base.num_splits == 1                   # flawed guard: no split
    assert md_pap.num_splits == 3                    # paper Fig. 2 override
    # run both engines THROUGH the 512 bucket: 400-token prompt + decode
    prompt = [1 + (i * 7) % 250 for i in range(400)]
    out_b = base.generate([Request(0, list(prompt), max_new_tokens=8)])
    out_p = pap.generate([Request(0, list(prompt), max_new_tokens=8)])
    assert base.planned_splits()[512] == 1
    assert pap.planned_splits()[512] == 3            # plan actually differs
    assert out_b[0].tokens == out_p[0].tokens        # math identical
