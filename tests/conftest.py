"""Shared pytest config.

NOTE: no XLA_FLAGS here — the dry-run rules require tests to see ONE
device; multi-device tests spawn subprocesses (test_multidevice.py).

``jax.clear_caches()`` runs after every test module: a full-suite run
compiles ~800 programs and jaxlib's in-process JIT dylib cache otherwise
exhausts late in the run ("Failed to materialize symbols" INTERNAL
errors from otherwise-green tests).
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
