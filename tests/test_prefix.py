"""Prefix sharing on the paged KV cache: trie match/insert/evict,
refcounted page lifetime (idempotent release, copy-on-write,
copy-on-adopt), admission accounting, the shared-vs-unshared serving
oracle, and the page-conservation property under random interleavings."""
import jax
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.cache import CacheSpec, PrefixTrie, TRASH_PAGE
from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.kernels import ops
from repro.models import build_model
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def tiny_model():
    cfg = reduced_config("qwen2.5-3b", num_layers=2, d_model=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _mgr(model, *, batch=2, max_len=32, page_size=4, budget=None,
         capacity=None):
    return model.cache_manager(batch, max_len, layout="paged",
                               page_size=page_size, page_budget=budget,
                               share_prefix=True,
                               prefix_capacity=capacity)


# ---------------------------------------------------------------------------
# PrefixTrie
# ---------------------------------------------------------------------------


def test_trie_match_insert_roundtrip():
    t = PrefixTrie(4)
    toks = list(range(10, 20))                 # 10 tokens = 2.5 pages
    assert t.insert(toks, [5, 6]) == [5, 6]
    assert t.anchored == 2
    m = t.match(toks)
    assert m.pages == [5, 6] and m.boundary_page is None
    # a diverging prompt matches only the common full pages
    m = t.match(toks[:4] + [99] * 6)
    assert m.pages == [5] and m.boundary_page is None
    # re-inserting the same prefix anchors nothing new (dedup)
    assert t.insert(toks, [7, 8]) == []
    assert t.match(toks).pages == [5, 6]       # original pages kept


def test_trie_full_page_match_is_capped():
    """The LAST prompt token's logits are never cached, so a prompt that
    IS an anchored prefix can adopt at most (n-1)//ps full pages — the
    remainder arrives as a boundary copy, leaving >= 1 row to compute."""
    t = PrefixTrie(4)
    toks = list(range(30, 42))                 # 3 full pages
    t.insert(toks, [1, 2, 3])
    m = t.match(toks)                          # n = 12: cap = 11//4 = 2
    assert m.pages == [1, 2]
    assert m.boundary_page == 3 and m.boundary_rows == 3
    # page-multiple-plus-one adopts all full pages, no boundary
    m = t.match(toks + [77])
    assert m.pages == [1, 2, 3] and m.boundary_page is None


def test_trie_boundary_match():
    t = PrefixTrie(4)
    toks = list(range(50, 62))                 # 3 full pages anchored
    t.insert(toks, [1, 2, 3])
    # prompt ends 2 tokens into the second page: page 2 holds a superset
    m = t.match(toks[:6])
    assert m.pages == [1]
    assert m.boundary_page == 2 and m.boundary_rows == 1
    # a 1-token remainder has nothing cachable to copy (its only row is
    # the recomputed last one): no boundary match
    m = t.match(toks[:5])
    assert m.pages == [1] and m.boundary_page is None
    # diverging remainder: no donor
    m = t.match(toks[:4] + [99, 98])
    assert m.pages == [1] and m.boundary_page is None


def test_trie_insert_capacity_hook_and_eviction():
    t = PrefixTrie(4)
    budget = [1]

    def can_add():
        if budget[0] <= 0:
            return False
        budget[0] -= 1
        return True

    new = t.insert(list(range(8)), [1, 2], can_add=can_add)
    assert new == [1] and t.anchored == 1      # stopped at the bound
    # LRU leaf-first eviction: deepest, least-recently-touched first
    t2 = PrefixTrie(2)
    t2.insert([1, 2, 3, 4], [7, 8])
    t2.insert([1, 2, 9, 9], [7, 6])            # sibling at depth 2
    t2.match([1, 2, 9, 9])                     # touch the [9,9] branch
    assert t2.pop_evictable(lambda p: True) == 8   # LRU leaf
    assert t2.pop_evictable(lambda p: p != 7) == 6
    # 7 now a leaf but the predicate refuses it
    assert t2.pop_evictable(lambda p: p != 7) is None
    assert t2.pop_evictable(lambda p: True) == 7
    assert t2.anchored == 0


# ---------------------------------------------------------------------------
# CacheManager: refcounts, COW, adoption, release
# ---------------------------------------------------------------------------


def test_release_is_idempotent(tiny_model):
    """Satellite: a double-finish (streamed handle also swept by
    drain()) must not double-decrement — under refcounting that frees
    pages other owners still read, silently aliasing two live slots."""
    _, model, _ = tiny_model
    mgr = _mgr(model, budget=6)
    assert mgr.reserve(0, 9)                   # 3 pages
    assert mgr.reserve(1, 5)                   # 2 pages
    free_before = mgr.free_pages
    mgr.release(0)
    assert mgr.free_pages == free_before + 3
    mgr.release(0)                             # double-free: no-op
    mgr.release(0)
    assert mgr.free_pages == free_before + 3
    assert sorted(mgr._free) == sorted(set(mgr._free))
    mgr.check_conservation()
    # slot 1's pages were never touched
    assert int(mgr._allocated[1]) == 2
    mgr.release(1)
    mgr.check_conservation()


def test_adoption_refcounts_and_release(tiny_model):
    _, model, _ = tiny_model
    mgr = _mgr(model, budget=8)
    prompt = [(3 * j) % 11 + 1 for j in range(9)]   # 2 full pages + 1 row
    assert mgr.admit_prompt(0, prompt) == 0         # cold trie
    assert mgr.register_prefix(0, prompt) == 2
    mgr.check_conservation()
    shared = mgr.admit_prompt(1, prompt)
    assert shared == 8                              # both full pages
    pages = [int(p) for p in mgr._table[0, :2]]
    for p in pages:
        assert mgr.refcount[p] == 3                 # owner + adopter + trie
    mgr.check_conservation()
    # owner's death must not free the shared pages (twice: idempotent)
    free_before = mgr.free_pages
    mgr.release(0)
    mgr.release(0)
    assert mgr.free_pages == free_before + 1        # only the private page
    for p in pages:
        assert mgr.refcount[p] == 2
    mgr.check_conservation()
    # adopter's death leaves them trie-only; reset frees them
    mgr.release(1)
    assert all(mgr.refcount[p] == 1 for p in pages)
    mgr.check_conservation()
    assert mgr.reset_prefix() == 2
    assert all(mgr.refcount[p] == 0 for p in pages)
    assert mgr.free_pages == mgr.spec.total_pages
    mgr.check_conservation()


def test_copy_on_write_on_shared_page(tiny_model):
    """ensure() on a row whose page another owner still reads must move
    the writer onto a fresh private page and queue a device copy."""
    _, model, _ = tiny_model
    mgr = _mgr(model, budget=8)
    prompt = list(range(1, 10))                     # 2 full pages + 1 row
    mgr.admit_prompt(0, prompt)
    mgr.register_prefix(0, prompt)
    mgr.admit_prompt(1, prompt)
    mgr.drain_copies()
    shared_page = int(mgr._table[1, 0])
    assert shared_page == int(mgr._table[0, 0])
    assert mgr.ensure(1, 0)                         # write INTO the prefix
    private = int(mgr._table[1, 0])
    assert private != shared_page
    assert mgr.refcount[shared_page] == 2           # owner + trie remain
    assert mgr.refcount[private] == 1
    assert mgr.drain_copies() == [(shared_page, private)]
    assert mgr.prefix_copies >= 1
    mgr.check_conservation()


def test_boundary_copy_on_adopt(tiny_model):
    _, model, _ = tiny_model
    mgr = _mgr(model, budget=8)
    toks = list(range(1, 13))                       # 3 full pages
    mgr.admit_prompt(0, toks)
    mgr.register_prefix(0, toks)
    donor = int(mgr._table[0, 1])
    shared = mgr.admit_prompt(1, toks[:6])          # ends 2 rows into pg 2
    assert shared == 5                              # 4 full + 1 copied row
    private = int(mgr._table[1, 1])
    assert private != donor
    assert mgr.refcount[donor] == 2                 # NOT bumped by adopt
    assert (donor, private) in mgr.drain_copies()
    mgr.check_conservation()


def test_admission_accounting_and_eviction(tiny_model):
    _, model, _ = tiny_model
    mgr = _mgr(model, batch=2, max_len=16, budget=4)
    a = list(range(1, 10))                          # needs 3 pages
    assert mgr.can_admit(a)
    mgr.admit_prompt(0, a)
    mgr.register_prefix(0, a)
    # same prompt: only 1 NEW page needed (2 adopted) -> admissible
    assert mgr.can_admit(a)
    # a disjoint prompt needs 3 fresh pages; only 1 free and the 2
    # anchored pages are pinned by their live owner -> refused
    b = [90 + j for j in range(9)]
    assert not mgr.can_admit(b)
    mgr.release(0)
    # owner gone: the anchored pages are evictable now
    assert mgr.can_admit(b)
    assert mgr.admit_prompt(1, b) == 0
    assert mgr.trie.anchored < 2                    # evicted to make room
    mgr.check_conservation()


def test_admit_rollback_on_exhaustion(tiny_model):
    """A failed admission must leave NO trace: adopted refcounts undone,
    popped pages freed, no pending copies."""
    _, model, _ = tiny_model
    mgr = _mgr(model, batch=2, max_len=16, budget=3)
    a = list(range(1, 9))                           # 2 pages, both full
    mgr.admit_prompt(0, a)
    mgr.register_prefix(0, a)
    free_before = mgr.free_pages
    rc_before = mgr.refcount.copy()
    # matches both anchored full pages, but the suffix needs more pages
    # than remain (the live owner pins them) -> all-or-nothing failure
    big = a + [50 + j for j in range(8)]            # 4 pages total
    assert mgr.admit_prompt(1, big) is None
    assert mgr.free_pages == free_before
    assert (mgr.refcount == rc_before).all()
    assert mgr.drain_copies() == []
    assert int(mgr._allocated[1]) == 0
    mgr.check_conservation()


# ---------------------------------------------------------------------------
# Page-conservation property (random interleavings)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def prop_model(tiny_model):
    return tiny_model[1]


@settings(max_examples=20, deadline=None)
@given(script=st.lists(st.tuples(st.integers(0, 4), st.integers(0, 2),
                                 st.integers(1, 12)),
                       min_size=1, max_size=50))
def test_page_conservation_property(prop_model, script):
    """Random admit/decode/finish/double-release/reset interleavings:
    after every op, (# pages with refcount > 0) + free == total, every
    refcount equals its reachability count, a page in two slots has
    refcount > 1, and the trash page is never refcounted or freed."""
    mgr = _mgr(prop_model, batch=3, max_len=32, page_size=4, budget=10,
               capacity=6)
    pos = {}                                    # live slot -> next row
    base = [(7 * j) % 5 + 1 for j in range(16)]
    for op, slot, arg in script:
        if op == 0 and slot not in pos:         # admit (prefix family)
            prompt = base[:max(1, arg)]
            if arg % 3 == 0:
                prompt = prompt[:-1] + [99]     # diverging tail
            if mgr.can_admit(prompt):
                shared = mgr.admit_prompt(slot, prompt)
                assert shared is not None, "can_admit over-promised"
                assert shared < len(prompt)     # last row never adopted
                mgr.register_prefix(slot, prompt)
                mgr.drain_copies()
                pos[slot] = len(prompt)
        elif op == 1 and slot in pos:           # decode one row
            if pos[slot] >= 32:                 # request hit max_len
                mgr.release(slot)
                del pos[slot]
            elif mgr.ensure(slot, pos[slot]):
                mgr.note_write(slot, pos[slot])
                mgr.drain_copies()
                pos[slot] += 1
            else:                               # pool exhausted: finish
                mgr.release(slot)
                del pos[slot]
        elif op == 2 and slot in pos:           # finish
            mgr.release(slot)
            del pos[slot]
        elif op == 3:                           # stray double-release
            mgr.release(slot)
            pos.pop(slot, None)
        elif op == 4 and arg == 12:             # rare: drop all anchors
            mgr.reset_prefix()
        mgr.check_conservation()
        live = int((mgr.refcount > 0).sum())
        assert live + mgr.free_pages == mgr.spec.total_pages
        assert mgr.refcount[TRASH_PAGE] == 0
        assert TRASH_PAGE not in mgr._free


# ---------------------------------------------------------------------------
# Submit-time page-budget rejection (off-by-one regression)
# ---------------------------------------------------------------------------


def test_submit_rejects_pool_filling_prompt(tiny_model):
    """Regression: a prompt whose pages exactly fill the pool used to be
    admitted, then deadlock the FIFO head forever on its first
    decode-token page (alone in the pool, no finish can free a page)."""
    cfg, model, params = tiny_model
    eng = ServingEngine(
        model, ServeConfig(model=cfg, cache_layout="paged",
                           cache_page_size=16, cache_page_budget=3),
        max_len=128, batch_slots=2)
    eng.load(params)
    # 48 tokens = exactly 3 pages; row 48 (first decode token) needs a
    # 4th page that can never exist -> must be rejected at submit
    with pytest.raises(ValueError, match="page budget"):
        eng.submit(Request(0, list(range(1, 49)), max_new_tokens=4))
    # one row of headroom: admitted, decodes its first token, and the
    # engine's per-request capacity finish handles the rest
    eng.submit(Request(1, list(range(1, 48)), max_new_tokens=1))
    outs = eng.drain()
    assert len(outs) == 1 and outs[0].finish_reason == "length"
    assert len(outs[0].tokens) == 1


# ---------------------------------------------------------------------------
# Serving oracle: shared vs unshared
# ---------------------------------------------------------------------------


def _serve(model, cfg, reqs, *, share, page_size=32, **kw):
    eng = ServingEngine(
        model, ServeConfig(model=cfg, cache_layout="paged",
                           cache_page_size=page_size, prefill_bucket=32,
                           share_prefix=share, **kw),
        max_len=256, batch_slots=4)
    eng.load(model.init_params(jax.random.PRNGKey(0)))
    for r in reqs:
        eng.submit(r)
    outs = eng.drain()
    return {c.request_id: c.tokens for c in outs}, eng


def test_shared_matches_unshared_and_skips_prefill(tiny_model):
    """The tentpole oracle: N requests sharing a system prompt produce
    identical greedy tokens with sharing on vs off, allocate fewer
    pages, and issue ZERO full-prefill launches for the followers —
    their admissions are suffix launches under ("sprefill", ...) keys."""
    cfg, model, _ = tiny_model
    system = [(3 * j) % 150 + 1 for j in range(100)]
    reqs = [Request(i, system + [(7 * i + j) % 150 + 1 for j in range(9)],
                    max_new_tokens=4) for i in range(4)]
    ops.reset_policy_eval_count()
    ta, ea = _serve(model, cfg, [Request(r.request_id, list(r.prompt),
                                         max_new_tokens=r.max_new_tokens)
                                 for r in reqs], share=True)
    tb, eb = _serve(model, cfg, reqs, share=False)
    assert ta == tb
    assert ops.policy_eval_count() == 0         # plans stay frozen
    sa, sb = ea.stats, eb.stats
    full = lambda s: sum(v for k, v in s.launches.items()
                         if isinstance(k, tuple) and k[0] == "prefill")
    sfx = lambda s: sum(v for k, v in s.launches.items()
                        if isinstance(k, tuple) and k[0] == "sprefill")
    assert full(sa) == 1 and sfx(sa) == 3       # leader + 3 suffix
    assert full(sb) == 4 and sfx(sb) == 0
    assert ea.cache.pages_allocated_total < eb.cache.pages_allocated_total
    ca = ea.cache_stats()
    assert ca["prefix_hits"] == 3
    assert ca["prefix_shared_rows"] == 3 * 96   # 3 full 32-row pages each
    ea.cache.check_conservation()
    assert ea.planned_suffix_buckets() == [(128, 32)]


def test_boundary_copy_on_adopt_end_to_end(tiny_model):
    """A shorter prompt that is a strict prefix of an already-served one
    adopts its full pages AND copies the boundary page — greedy tokens
    still match the unshared engine bit-for-bit."""
    cfg, model, _ = tiny_model
    leader = [(5 * j) % 150 + 1 for j in range(100)]    # 3 full pages
    reqs = [Request(0, list(leader), max_new_tokens=3),
            Request(1, leader[:70], max_new_tokens=3)]  # ends mid-page 3
    ta, ea = _serve(model, cfg,
                    [Request(r.request_id, list(r.prompt),
                             max_new_tokens=r.max_new_tokens)
                     for r in reqs], share=True)
    tb, _ = _serve(model, cfg, reqs, share=False)
    assert ta == tb
    cs = ea.cache_stats()
    assert cs["prefix_copies"] >= 1             # the boundary page copy
    assert cs["prefix_shared_rows"] >= 64 + 5   # 2 full pages + boundary
    ea.cache.check_conservation()


def test_trie_eviction_under_pool_pressure(tiny_model):
    """Anchored-only pages yield to new admissions: disjoint prompts
    sweep through a pool too small to keep every prefix anchored."""
    cfg, model, _ = tiny_model
    reqs = [Request(i, [(i * 37 + j) % 150 + 1 for j in range(40)],
                    max_new_tokens=2) for i in range(5)]
    toks, eng = _serve(model, cfg, reqs, share=True,
                       cache_page_budget=6)
    assert sorted(toks) == [0, 1, 2, 3, 4]
    assert all(len(t) == 2 for t in toks.values())
    eng.cache.check_conservation()
    cs = eng.cache_stats()
    assert cs["free_pages"] + cs["prefix_anchored_pages"] \
        <= eng.cache.spec.total_pages


def test_share_prefix_config_gates(tiny_model):
    cfg, model, _ = tiny_model
    with pytest.raises(ValueError, match="cache_layout='paged'"):
        ServingEngine(model, ServeConfig(model=cfg, share_prefix=True),
                      max_len=64, batch_slots=2)
    with pytest.raises(ValueError, match="prefill_mode='loop'"):
        ServingEngine(model, ServeConfig(model=cfg, share_prefix=True,
                                         cache_layout="paged",
                                         prefill_mode="loop"),
                      max_len=64, batch_slots=2)
    mla = build_model(reduced_config("minicpm3-4b", num_layers=2,
                                     d_model=32))
    with pytest.raises(ValueError, match="share prefix"):
        ServingEngine(mla, ServeConfig(model=mla.cfg, share_prefix=True,
                                       cache_layout="paged"),
                      max_len=64, batch_slots=2)
    with pytest.raises(ValueError, match="share_prefix"):
        CacheSpec("dense", 2, 64, share_prefix=True)
