"""Reproduce the paper's §3: evolutionary discovery of the guard flaw.

A plain (no-LLM) evolutionary search over the same genome the paper's
OpenEvolve agent manipulated — per-(L_K, H_KV, B)-bucket ``num_splits``
plus global ``pack_gqa`` / ``sm_margin`` — with modeled TPOT on the
short-prompt chat workload as fitness.  The run re-discovers the paper's
observation: low-tile short-context buckets evolve aggressive splits
(the paper saw 12-16), saturated buckets stay at 1.

    PYTHONPATH=src python examples/evolve_heuristic.py
"""
from repro.core.evolve import evolve, summarize_low_tile_genes
from repro.core.occupancy import H100_SXM
from repro.core.split_policy import DecodeWorkload, fa3_baseline

CORES = 132          # search on the paper's H100


def main() -> None:
    result = evolve(num_cores=CORES, hw=H100_SXM, generations=40,
                    population=32, seed=0)
    genome = result.best

    print("evolved splits in STARVED buckets (tiles < cores):")
    for (lk, hkv, b), s in list(summarize_low_tile_genes(
            genome, CORES).items())[:12]:
        print(f"  L_K<={lk:5d} H_KV<={hkv:2d} B<={b}:  s={s}")
    print(f"pack_gqa={genome.pack_gqa} sm_margin={genome.sm_margin}")
    gain = result.best_fitness - result.baseline_fitness
    print(f"fitness: baseline {-result.baseline_fitness:.1f}us total -> "
          f"evolved {-result.best_fitness:.1f}us "
          f"(saved {gain:.1f}us across the workload set)")

    # the paper's headline observation, recovered by search:
    w = DecodeWorkload(1, 1, 512, 64, 1, 128)
    s = genome.num_splits_for(w)
    print(f"\nB=1, L_K=512, H_KV=1: static guard s={fa3_baseline(w)} "
          f"-> evolved s={s} (paper's agent found 12-16 here; "
          f"the distilled C++ rule uses 3)")
    assert s > 1, "search failed to rediscover the flaw"
    assert result.best_fitness > result.baseline_fitness


if __name__ == "__main__":
    main()
