"""End-to-end driver: train a ~100M-param GQA model for a few hundred
steps on synthetic data, with checkpoint/resume and (if the process is
killed) crash recovery — the deliverable (b) end-to-end example.

Sized so CPU finishes in minutes; on a real slice, swap
``make_host_mesh`` for ``make_production_mesh`` and raise the batch.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse

from repro.launch.train import run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_100m")
    args = ap.parse_args()

    # ~100M params: 12 layers x d_model 768, GQA 12:4, vocab 32k
    metrics = run_training(
        "qwen2.5-3b",            # family/wiring; dims overridden below
        steps=args.steps,
        d_model=256,             # ~25M on CPU-friendly dims; raise to 768
        num_layers=8,            # for the full ~100M run on real hardware
        seq_len=256,
        global_batch=8,
        microbatches=2,
        lr=1e-3,
        remat_policy="nothing_saveable",
        ckpt_dir=args.ckpt,
        ckpt_every=100,
    )
    print("final metrics:", {k: round(v, 4) for k, v in metrics.items()})
    assert metrics["loss"] < 6.0, "training should make progress"


if __name__ == "__main__":
    main()
