"""Quickstart: the paper's policy, end to end, in two minutes on CPU.

1. Shows the FA3 guard flaw and the sequence-aware fix on the paper's
   own shapes (policy decisions + modeled latency).
2. Trains a tiny GQA model for a few steps (full substrate: synthetic
   data, AdamW, remat, checkpointing).
3. Serves it through the continuous-batching engine under the paper
   policy (metadata-enabled split decode).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_arch
from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.core.occupancy import H100_SXM, modeled_latency_us
from repro.core.split_policy import (
    DecodeWorkload,
    fa3_baseline,
    paper_policy,
)
from repro.launch.train import run_training
from repro.models import build_model
from repro.serving.engine import DecodeEngine, Request


def policy_demo():
    print("== 1. the paper's boundary bucket (B=1, L_K=512, D=128) ==")
    for hkv in (1, 2, 8):
        w = DecodeWorkload(1, 1, 512, 64, hkv, 128)
        s0, s1 = fa3_baseline(w, 132), paper_policy(w, 132)
        t0 = modeled_latency_us(w, s0, hw=H100_SXM, num_cores=132)
        t1 = modeled_latency_us(w, s1, hw=H100_SXM, num_cores=132)
        print(f"  H_KV={hkv}: baseline s={s0} ({t0:.2f}us) -> "
              f"paper s={s1} ({t1:.2f}us)  x{t0/t1:.2f}")


def train_demo():
    print("\n== 2. train a tiny qwen2.5-style model (synthetic data) ==")
    metrics = run_training("qwen2.5-3b", steps=60, d_model=64,
                           num_layers=2, seq_len=64, global_batch=8,
                           lr=3e-3, ckpt_dir="/tmp/repro_quickstart",
                           ckpt_every=30)
    print(f"  final loss {metrics['loss']:.3f}")


def serve_demo():
    print("\n== 3. serve through the split-policy decode engine ==")
    cfg = reduced_config(get_arch("qwen2.5-3b"), num_layers=2, d_model=64)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = DecodeEngine(model, ServeConfig(model=cfg, split_policy="paper"),
                       max_len=128, batch_slots=3)
    eng.load(params)
    outs = eng.generate([Request(i, [1 + i, 2, 3], max_new_tokens=8)
                         for i in range(5)])
    for c in outs:
        print(f"  req {c.request_id}: {c.tokens}")


if __name__ == "__main__":
    policy_demo()
    train_demo()
    serve_demo()
    print("\nquickstart OK")
