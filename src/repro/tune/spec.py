"""TuneSpec: the declarative input to the calibrator.

A tune spec answers "WHAT do we measure" — the workload grid (L_K
buckets x head shapes x batch x impl x dtype), the candidate split set,
and the timing budget — and nothing about HOW the timing runs (jit,
warmup discard, wall-clock vs modeled): that is the
:class:`~repro.tune.Calibrator`'s business, exactly mirroring the
``AttentionSpec -> Planner`` and ``CacheSpec -> CacheManager`` splits.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

from repro.core.split_policy import (
    DEFAULT_NUM_CORES,
    KV_BLOCK,
    KV_DTYPES,
    MAX_SPLITS,
    DecodeWorkload,
)

# bytes per cache element, by calibration dtype name — the one registry
# (repro.core.split_policy.KV_DTYPES), re-exported under the historical
# tune-facing name.  Includes the quantized families ("int8", "fp8"):
# both 1 byte, keyed apart by NAME in the table.
DTYPE_BYTES = dict(KV_DTYPES)


@dataclass(frozen=True)
class TuneSpec:
    """One calibration run, declaratively.

    The default grid is the **reference grid**: the reduced-config
    serving shapes every test/CI engine actually plans (H_Q=4 MQA at
    head_dim 8/16, batch = the engine's ``batch_slots``) plus the
    paper's full-size low-head-count rows (Table 1's H_KV ∈ {1, 2, 4}
    at head_dim 128), each in bf16 AND int8 (quantized serving plans
    from its own cells, never a bf16 neighbor's).
    ``launch/tune.py --reference`` calibrates exactly this spec into the
    committed reference table.
    """
    # L_K grid: multiples of KV_BLOCK (the decision is lossless within a
    # block — same invariant the serving engine's buckets rely on)
    lk_buckets: Tuple[int, ...] = (128, 256, 384, 512, 640, 1024, 4096)
    batches: Tuple[int, ...] = (1, 2, 4, 8)
    # (num_heads_q, num_heads_kv, head_dim)
    head_shapes: Tuple[Tuple[int, int, int], ...] = (
        (4, 1, 8), (4, 1, 16), (4, 1, 32),   # reduced-config engine shapes
        (64, 1, 128), (16, 2, 128), (32, 4, 128),   # paper Table 1 rows
    )
    impls: Tuple[str, ...] = ("xla",)
    dtypes: Tuple[str, ...] = ("bfloat16", "int8")
    # explicit candidate split counts; None = every feasible split for
    # the workload (1..min(nblk, num_cores), skipping counts that do not
    # refine the partitioning — the efficiency loop's own skip rule)
    candidates: Optional[Tuple[int, ...]] = None
    num_cores: int = DEFAULT_NUM_CORES
    # timing budget: per-candidate repeats with warmup discard, plus an
    # optional global wall-clock cap — once exceeded, remaining cells
    # degrade to the analytic cost model (recorded per entry)
    repeats: int = 5
    warmup: int = 2
    budget_s: Optional[float] = None

    def __post_init__(self):
        for lk in self.lk_buckets:
            if lk % KV_BLOCK:
                raise ValueError(
                    f"lk_buckets must be multiples of KV_BLOCK "
                    f"({KV_BLOCK}); got {lk}")
        for d in self.dtypes:
            if d not in DTYPE_BYTES:
                raise ValueError(f"unknown dtype {d!r}; "
                                 f"known: {sorted(DTYPE_BYTES)}")
        if self.repeats < 1 or self.warmup < 0:
            raise ValueError("repeats must be >= 1 and warmup >= 0")

    # --- grid enumeration ---------------------------------------------------

    def workloads(self) -> Iterator[Tuple[DecodeWorkload, str]]:
        """Every (workload, impl) cell of the grid, in deterministic
        order (the calibrator's per-cell seeds index into this order)."""
        for impl in self.impls:
            for dtype in self.dtypes:
                for hq, hkv, hd in self.head_shapes:
                    for b in self.batches:
                        for lk in self.lk_buckets:
                            yield DecodeWorkload(
                                b, 1, lk, hq, hkv, hd,
                                dtype_bytes=DTYPE_BYTES[dtype],
                                kv_dtype=dtype), impl

    def candidate_splits(self, w: DecodeWorkload) -> Tuple[int, ...]:
        """The feasible candidate set for one workload (always
        includes 1, deduped, clamped to the block count)."""
        cap = min(w.num_n_blocks, self.num_cores, MAX_SPLITS)
        if self.candidates is not None:
            cands = sorted({max(1, min(s, w.num_n_blocks))
                            for s in self.candidates})
            return tuple(cands) if 1 in cands else (1, *cands)
        out = [1]
        for s in range(2, cap + 1):
            # identical per-split block count to s-1 = same partitioning,
            # pure combine overhead — never a distinct candidate
            if math.ceil(w.num_n_blocks / s) == \
                    math.ceil(w.num_n_blocks / (s - 1)):
                continue
            out.append(s)
        return tuple(out)

    def grid_size(self) -> int:
        return sum(1 for _ in self.workloads())

    def describe(self) -> dict:
        """JSON-safe summary persisted into the table artifact."""
        return {
            "lk_buckets": list(self.lk_buckets),
            "batches": list(self.batches),
            "head_shapes": [list(h) for h in self.head_shapes],
            "impls": list(self.impls),
            "dtypes": list(self.dtypes),
            "candidates": (None if self.candidates is None
                           else list(self.candidates)),
            "num_cores": self.num_cores,
            "repeats": self.repeats,
            "warmup": self.warmup,
            "budget_s": self.budget_s,
        }

    def replace(self, **kw) -> "TuneSpec":
        return dataclasses.replace(self, **kw)


# The spec the committed reference table is calibrated from (modeled
# mode — deterministic, CI-reproducible; see launch/tune.py --reference).
REFERENCE_SPEC = TuneSpec()
