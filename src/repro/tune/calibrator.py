"""Calibrator: resolves a TuneSpec into a SplitTable by measuring.

Per grid cell and candidate split count, the calibrator times a jitted
``ops.decode_attention`` launch with the split frozen via
``Planner(num_splits_override=s)`` — the exact code path a measured
plan later serves — takes the **median of repeats after a warmup
discard**, and records the whole latency curve plus its argmin.

Timing modes
------------
``wallclock``  real timing of the jitted launch (``block_until_ready``
               around a ``perf_counter`` window).  The production mode
               on real accelerators.
``modeled``    the analytic occupancy cost model
               (:func:`repro.core.occupancy.modeled_latency_us`) stands
               in for the clock.  Deterministic — this is what CI and
               the committed reference table use.
``auto``       ``modeled`` on CPU hosts (interpret-mode timings say
               nothing about TPU occupancy), ``wallclock`` elsewhere.

A ``TuneSpec.budget_s`` wall-clock cap degrades gracefully: once the
budget is spent, the remaining cells fall back to the model, and every
entry records its ``source`` so a mixed table stays auditable.

Determinism: under a fixed seed the grid order, candidate sets, input
tensors and (in modeled mode) every latency are bit-reproducible —
``calibrate()`` twice, get the same ``SplitTable.version``.
"""
from __future__ import annotations

import statistics
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.occupancy import modeled_latency_us
from repro.core.split_policy import DecodeWorkload
from repro.plan import AttentionSpec, Planner
from repro.tune.spec import TuneSpec
from repro.tune.table import SplitTable

MODES = ("auto", "wallclock", "modeled")


class Calibrator:
    """Resolve ``spec`` into a :class:`SplitTable` (measure -> decide)."""

    def __init__(self, spec: TuneSpec, *, mode: str = "auto",
                 seed: int = 0, interpret: bool = True):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; known: {MODES}")
        if mode == "auto":
            mode = "modeled" if jax.default_backend() == "cpu" \
                else "wallclock"
        self.spec = spec
        self.mode = mode
        self.seed = seed
        self.interpret = interpret

    # --- timing -------------------------------------------------------------

    def _inputs(self, w: DecodeWorkload, cell: int):
        """Seeded decode-shaped inputs (deterministic per cell index).

        Quantized workloads get a quantized cache: bf16-scale normals
        quantized through the family's :class:`~repro.quant.Quantizer`
        (returning the extra scale leaves), so the timed launch streams
        exactly the bytes a quantized serving step streams.
        """
        from repro.quant import QUANT_DTYPES, Quantizer
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), cell)
        kq, kk, kv = jax.random.split(key, 3)
        name = w.kv_dtype_name
        quant = name in QUANT_DTYPES
        dt = jnp.bfloat16 if quant else \
            {2: jnp.bfloat16, 4: jnp.float32}[w.dtype_bytes]
        q = jax.random.normal(kq, (w.batch, w.num_heads_q, w.head_dim), dt)
        k = jax.random.normal(
            kk, (w.batch, w.seqlen_k, w.num_heads_kv, w.head_dim), dt)
        v = jax.random.normal(
            kv, (w.batch, w.seqlen_k, w.num_heads_kv, w.head_dim), dt)
        kv_len = jnp.full((w.batch,), w.seqlen_k, jnp.int32)
        if quant:
            qkv = Quantizer.from_kv_dtype(name).quantized_kv(k, v)
            return q, qkv.k, qkv.v, qkv.k_scale, qkv.v_scale, kv_len
        return q, k, v, kv_len

    def _time_wallclock(self, w: DecodeWorkload, impl: str, s: int,
                        cell: int) -> float:
        """Median-of-repeats latency (us) of the jitted frozen launch.

        Quantized families ride the fused harness: the same
        ``ops.decode_attention`` dispatch, with the cell's scale leaves
        passed through — ``impl="pallas"`` times the fused in-register-
        dequant kernel, ``impl="xla"`` times the dequant-then-attend
        reference (each under its own table family).
        """
        from repro.kernels import ops   # local: keep import cost off the
        #                                 modeled-only (CI) path
        plan = Planner(num_splits_override=s, impl=impl).plan(
            AttentionSpec.from_workload(w))
        interpret = self.interpret
        args = self._inputs(w, cell)

        if len(args) == 6:              # quantized cell (fused harness)
            @jax.jit
            def step(q, k, v, k_s, v_s, kv_len):
                return ops.decode_attention(
                    q, k, v, kv_len, k_scale=k_s, v_scale=v_s,
                    plan=plan, impl=impl, interpret=interpret)
        else:
            @jax.jit
            def step(q, k, v, kv_len):
                return ops.decode_attention(q, k, v, kv_len, plan=plan,
                                            impl=impl, interpret=interpret)

        for _ in range(max(1, self.spec.warmup)):   # compile + warmup
            step(*args).block_until_ready()
        times = []
        for _ in range(self.spec.repeats):
            t0 = time.perf_counter()
            step(*args).block_until_ready()
            times.append(time.perf_counter() - t0)
        return statistics.median(times) * 1e6

    def _time_modeled(self, w: DecodeWorkload, s: int) -> float:
        return modeled_latency_us(w, s, num_cores=self.spec.num_cores)

    # --- resolution ---------------------------------------------------------

    def calibrate(self) -> SplitTable:
        spec = self.spec
        entries: List[Dict[str, Any]] = []
        t_start = time.perf_counter()
        budget_spent = False
        for cell, (w, impl) in enumerate(spec.workloads()):
            if (spec.budget_s is not None and not budget_spent
                    and time.perf_counter() - t_start > spec.budget_s):
                budget_spent = True
            # quantized cells time through the fused harness (see
            # _time_wallclock) and are labeled "wallclock" — the historic
            # refusal ("no fused-quant harness, model only") is lifted
            quant = w.dtype_bytes == 1
            wallclock = self.mode == "wallclock" and not budget_spent
            lat: Dict[str, float] = {}
            for s in spec.candidate_splits(w):
                t = (self._time_wallclock(w, impl, s, cell) if wallclock
                     else self._time_modeled(w, s))
                # rounded so the JSON round-trips (and hashes) stably
                lat[str(s)] = round(float(t), 4)
            # argmin, ties toward the smallest split (the paper's
            # "smallest split entering the low-latency regime")
            best = min(sorted(lat, key=int), key=lambda k: lat[k])
            entries.append({
                "batch": w.batch, "num_heads_q": w.num_heads_q,
                "num_heads_kv": w.num_heads_kv, "head_dim": w.head_dim,
                "impl": impl, "dtype_bytes": w.dtype_bytes,
                "kv_dtype": w.kv_dtype_name,
                "lk_bucket": w.seqlen_k,
                "best_split": int(best),
                "source": ("wallclock" if wallclock and quant
                           else "measured" if wallclock else "modeled"),
                "latencies_us": lat,
            })
        table = SplitTable(entries, self._fingerprint(entries),
                           spec=spec.describe())
        table.validate()
        return table

    def _fingerprint(self, entries: List[Dict[str, Any]]) -> Dict[str, Any]:
        from repro.tune.table import MEASURED_SOURCES
        n_measured = sum(e["source"] in MEASURED_SOURCES for e in entries)
        if self.mode == "modeled":
            sources = "modeled"
        elif n_measured == len(entries):
            sources = "measured"
        else:             # wallclock degraded mid-run (budget cap)
            sources = "mixed"
        return {
            "mode": self.mode,
            "sources": sources,
            "backend": jax.default_backend(),
            "device": jax.devices()[0].device_kind,
            "jax": jax.__version__,
            "num_cores": self.spec.num_cores,
            "seed": self.seed,
            "fallback": "paper",
        }
