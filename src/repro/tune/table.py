"""SplitTable: the frozen, persisted output of a calibration run.

A table is a versioned JSON artifact (schema + backend fingerprint +
per-cell ``argmin`` split and the full candidate latency curve) that the
:class:`~repro.plan.Planner` consumes as the ``measured`` policy
backend.  Persisted under ``experiments/tune/`` — the committed
``reference_reduced.json`` is regenerated deterministically by
``python -m repro.launch.tune --reference`` so CI replays it bit-exact
(``make tune-golden``).

Lookup semantics
----------------
A decode workload resolves in two stages:

1. **family** — exact match on (batch, H_Q, H_KV, head_dim, impl,
   dtype_bytes, kv_dtype).  The split decision's tile math depends on
   all of these — and the dtype NAME keeps same-width families apart
   (an fp8 workload never resolves to an int8 cell) — so interpolating
   across them would be a guess, not a measurement: an uncovered family
   **falls back to the analytic ``paper`` policy explicitly**, and the
   fallback is counted
   (:meth:`SplitTable.attach_stats` / the table's own counters).
2. **nearest L_K bucket** within the covered family — L_K only shifts
   the knee of the U-curve, so the nearest measured bucket's argmin
   (clamped to the live workload's block count, so it is always
   feasible) beats re-deriving from the analytic model.
"""
from __future__ import annotations

import hashlib
import json
import warnings
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from repro.core.split_policy import (
    DEFAULT_NUM_CORES,
    KV_BLOCK,
    DecodeWorkload,
    choose_num_splits,
)

# Schema 2 (PR 8): entries carry the KV dtype NAME ("kv_dtype") next to
# its byte width — int8 and fp8 are both 1 byte but run different
# kernels, so the family key must separate them.  Schema-1 tables have
# no name column and cannot be disambiguated; loading one raises with
# the regeneration command.
SCHEMA_VERSION = 2

# repo-root experiments/tune/ — the artifact home (mirrors
# benchmarks/common.OUT_DIR's repo-root anchoring)
TABLE_DIR = Path(__file__).resolve().parents[3] / "experiments" / "tune"
REFERENCE_TABLE_PATH = TABLE_DIR / "reference_reduced.json"

# (batch, num_heads_q, num_heads_kv, head_dim, impl, dtype_bytes, kv_dtype)
FamilyKey = Tuple[int, int, int, int, str, int, str]

_ENTRY_FIELDS = ("batch", "num_heads_q", "num_heads_kv", "head_dim",
                 "impl", "dtype_bytes", "kv_dtype", "lk_bucket",
                 "best_split", "source", "latencies_us")

# sources that came from actual timing (the fused-quant harness labels
# its cells "wallclock"; the bf16 harness's historical label is
# "measured" — both are hardware numbers, as opposed to "modeled")
MEASURED_SOURCES = ("measured", "wallclock")


def _norm_impl(impl: Optional[str]) -> str:
    """None means "the caller's default impl", which is xla everywhere
    a measured plan is consumed (the engines' planners pin impl=None)."""
    return impl or "xla"


def family_key(w: DecodeWorkload, impl: Optional[str] = None) -> FamilyKey:
    return (w.batch, w.num_heads_q, w.num_heads_kv, w.head_dim,
            _norm_impl(impl), w.dtype_bytes, w.kv_dtype_name)


def _entry_family(e: Dict[str, Any]) -> FamilyKey:
    return (e["batch"], e["num_heads_q"], e["num_heads_kv"],
            e["head_dim"], e["impl"], e["dtype_bytes"], e["kv_dtype"])


class SplitTable:
    """Calibrated per-shape split decisions, with load/save/merge/validate.

    ``entries`` is a list of per-cell dicts (see ``_ENTRY_FIELDS``);
    ``fingerprint`` records where the numbers came from (backend, jax
    version, timing mode, num_cores).  ``version`` is content-derived —
    ``{schema}.{sha256(entries)[:12]}`` — so two tables agree on version
    iff they agree on every decision and latency.
    """

    def __init__(self, entries: List[Dict[str, Any]],
                 fingerprint: Dict[str, Any],
                 spec: Optional[Dict[str, Any]] = None,
                 schema: int = SCHEMA_VERSION):
        if schema != SCHEMA_VERSION:
            raise ValueError(
                f"SplitTable schema mismatch: file has {schema}, this "
                f"code reads {SCHEMA_VERSION} — regenerate the table "
                "with `python -m repro.launch.tune`")
        self.entries = entries
        self.fingerprint = dict(fingerprint)
        self.spec = dict(spec) if spec else None
        self.schema = schema
        # observability: standalone counters, plus an optional attached
        # PlanCacheStats (the serving engine attaches its plan cache's)
        self.lookups = 0
        self.fallbacks = 0
        self.fallback_trace: List[tuple] = []
        self._stats = None
        self._version: Optional[str] = None      # lazy content hash
        self._families: Dict[FamilyKey, Dict[int, Dict[str, Any]]] = {}
        for e in entries:
            self._families.setdefault(
                _entry_family(e), {})[e["lk_bucket"]] = e

    # --- identity -----------------------------------------------------------

    @property
    def version(self) -> str:
        # computed once: entries are frozen after construction by
        # convention (merge returns a NEW table, to_json deep-copies),
        # and the Planner reads this on every measured plan freeze
        if self._version is None:
            canon = json.dumps(self.entries, sort_keys=True,
                               separators=(",", ":"))
            digest = hashlib.sha256(canon.encode()).hexdigest()[:12]
            self._version = f"{self.schema}.{digest}"
        return self._version

    def __len__(self) -> int:
        return len(self.entries)

    # --- lookup (the measured policy's decision path) -----------------------

    def covers(self, w: DecodeWorkload, impl: Optional[str] = None) -> bool:
        return family_key(w, impl) in self._families

    def choose(self, w: DecodeWorkload, impl: Optional[str] = None,
               num_cores: Optional[int] = None) -> Tuple[int, bool]:
        """(num_splits, tuned) for one workload.

        ``tuned=True``: the decision came from a measured cell (nearest
        L_K bucket in the exact family, clamped feasible).  ``tuned=
        False``: family uncovered — the analytic fallback policy
        decided, and the fallback was counted.
        """
        fam = family_key(w, impl)
        buckets = self._families.get(fam)
        self.lookups += 1
        if self._stats is not None:
            self._stats.record_measured(fam + (w.seqlen_k,),
                                        fallback=buckets is None)
        if buckets is None:
            self.fallbacks += 1
            self.fallback_trace.append(fam + (w.seqlen_k,))
            if len(self.fallback_trace) > 8192:
                del self.fallback_trace[:-4096]
            cores = num_cores if num_cores is not None else \
                self.fingerprint.get("num_cores", DEFAULT_NUM_CORES)
            return choose_num_splits(w, policy=self.fallback_policy,
                                     num_cores=cores), False
        # nearest measured L_K bucket (ties toward the smaller bucket:
        # under-splitting is the conservative error)
        lk = max(1, w.seqlen_k)
        nearest = min(buckets, key=lambda b: (abs(b - lk), b))
        s = buckets[nearest]["best_split"]
        return max(1, min(int(s), w.num_n_blocks)), True

    @property
    def fallback_policy(self) -> str:
        return self.fingerprint.get("fallback", "paper")

    def attach_stats(self, stats) -> None:
        """Route lookup/fallback counts into a PlanCacheStats (the
        serving engine attaches its plan cache's stats object)."""
        self._stats = stats

    # --- persistence --------------------------------------------------------

    def to_json(self) -> Dict[str, Any]:
        import copy
        # deep-copied: callers may edit the snapshot (tests tamper with
        # it deliberately) without corrupting the live table
        d: Dict[str, Any] = {
            "schema": self.schema,
            "version": self.version,
            "fingerprint": copy.deepcopy(self.fingerprint),
            "entries": copy.deepcopy(self.entries),
        }
        if self.spec is not None:
            d["spec"] = copy.deepcopy(self.spec)
        return d

    def save(self, path: str | Path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=1,
                                   sort_keys=True) + "\n")
        return path

    @classmethod
    def load(cls, path: str | Path) -> "SplitTable":
        path = Path(path)
        d = json.loads(path.read_text())
        table = cls(d["entries"], d.get("fingerprint", {}),
                    spec=d.get("spec"),
                    schema=d.get("schema", -1))
        stored = d.get("version")
        if stored is not None and stored != table.version:
            raise ValueError(
                f"SplitTable version mismatch in {path}: header says "
                f"{stored}, entries hash to {table.version} — the file "
                "was hand-edited or truncated; recalibrate it")
        return table

    def merge(self, other: "SplitTable") -> "SplitTable":
        """New table = self's cells overridden/extended by ``other``'s
        (recalibrating a sub-grid refreshes only those cells).  Both
        sides must share the schema; fingerprints are recorded
        side-by-side so a mixed-provenance table stays auditable."""
        if other.schema != self.schema:
            raise ValueError(
                f"cannot merge SplitTables across schemas "
                f"({self.schema} vs {other.schema})")
        merged: Dict[tuple, Dict[str, Any]] = {}
        for e in self.entries + other.entries:   # later wins
            key = _entry_family(e) + (e["lk_bucket"],)
            merged[key] = e
        fp = dict(self.fingerprint)
        if other.fingerprint != self.fingerprint:
            fp["merged_from"] = [self.fingerprint, other.fingerprint]
        return SplitTable([merged[k] for k in sorted(merged)], fp,
                          spec=self.spec, schema=self.schema)

    # --- validation (the tune-golden gate's first half) ---------------------

    def validate(self) -> None:
        """Raise ValueError on a structurally broken table: missing
        fields, off-grid L_K, infeasible or un-measured best splits.

        Additionally WARNS (does not raise — a degraded table still
        serves) on ``sources="mixed"``: some cells timed, some modeled —
        historically the permanent state of quantized cells before the
        fused-quant harness existed, now just a sign of an interrupted
        or budget-truncated calibration.
        """
        if not self.entries:
            raise ValueError("empty SplitTable")
        seen = set()
        for e in self.entries:
            missing = [f for f in _ENTRY_FIELDS if f not in e]
            if missing:
                raise ValueError(f"entry missing fields {missing}: {e}")
            if e["lk_bucket"] % KV_BLOCK:
                raise ValueError(
                    f"lk_bucket {e['lk_bucket']} is not a multiple of "
                    f"KV_BLOCK ({KV_BLOCK})")
            nblk = -(-e["lk_bucket"] // KV_BLOCK)
            if not 1 <= e["best_split"] <= nblk:
                raise ValueError(
                    f"best_split {e['best_split']} infeasible for "
                    f"lk_bucket {e['lk_bucket']} ({nblk} blocks)")
            if str(e["best_split"]) not in e["latencies_us"]:
                raise ValueError(
                    f"best_split {e['best_split']} has no measured "
                    f"latency in {sorted(e['latencies_us'])}")
            best = e["latencies_us"][str(e["best_split"])]
            if any(t < best for t in e["latencies_us"].values()):
                raise ValueError(
                    f"best_split {e['best_split']} is not the argmin of "
                    f"its latency curve: {e['latencies_us']}")
            key = _entry_family(e) + (e["lk_bucket"],)
            if key in seen:
                raise ValueError(f"duplicate cell {key}")
            seen.add(key)
        if self.fingerprint.get("sources") == "mixed":
            modeled = sorted({
                (e["kv_dtype"], e["impl"])
                for e in self.entries
                if e["source"] not in MEASURED_SOURCES})
            n_mod = sum(1 for e in self.entries
                        if e["source"] not in MEASURED_SOURCES)
            warnings.warn(
                f"SplitTable has mixed sources: {n_mod}/{len(self.entries)} "
                f"cells are modeled (families by (kv_dtype, impl): "
                f"{modeled}) while the rest are timed.  Re-run "
                "`python -m repro.launch.tune --mode wallclock` to time "
                "the whole grid (the fused-quant harness covers int8/fp8 "
                "cells), or merge() a wallclock recalibration of just "
                "those families over this table.",
                UserWarning, stacklevel=2)

    def describe(self) -> Dict[str, Any]:
        return {
            "version": self.version,
            "cells": len(self.entries),
            "families": len(self._families),
            "fingerprint": self.fingerprint,
            "lookups": self.lookups,
            "fallbacks": self.fallbacks,
        }


def select_table(path: str | Path) -> Tuple["SplitTable", bool]:
    """Resolve ``tune_table_path`` into one table: file OR registry dir.

    A file loads as before.  A DIRECTORY is a table *registry* (ship one
    calibrated table per accelerator): every ``*.json`` inside is
    loaded, and the one whose fingerprint best matches the live backend
    wins — exact (``backend``, ``device``) match first, backend-only
    match next.  When nothing matches the live ``jax.default_backend()``
    the first table (sorted by filename, so the choice is deterministic)
    serves as a fallback with a warning; the returned flag is ``False``
    and the serving engine counts it
    (``PlanCacheStats.table_registry_fallbacks``) — a sharded TPU
    deployment and a CPU CI run pointed at the same registry stop
    silently sharing one hand-pointed table.

    Returns ``(table, matched)``.
    """
    import jax

    p = Path(path)
    if not p.is_dir():
        return SplitTable.load(p), True
    candidates = sorted(p.glob("*.json"))
    if not candidates:
        raise ValueError(f"tune-table registry {p} holds no *.json tables")
    tables = [(c, SplitTable.load(c)) for c in candidates]
    backend = jax.default_backend()
    kind = jax.devices()[0].device_kind

    def score(t: "SplitTable") -> int:
        fp = t.fingerprint
        s = 0
        if fp.get("backend") == backend:
            s += 2
            if fp.get("device") == kind:
                s += 1
        return s

    best_path, best = max(tables, key=lambda ct: score(ct[1]))
    matched = score(best) > 0
    if not matched:
        fps = {c.name: t.fingerprint.get("backend") for c, t in tables}
        warnings.warn(
            f"no table in registry {p} matches the live backend "
            f"(backend={backend!r}, device={kind!r}; registry backends: "
            f"{fps}); falling back to {best_path.name} — its measured "
            "decisions were taken on different hardware",
            RuntimeWarning, stacklevel=2)
    return best, matched
