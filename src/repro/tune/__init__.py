"""``repro.tune`` — the measured-autotuning subsystem (Spec -> Calibrator
-> Table).

The fourth first-class subsystem, closing the paper §4.1 loop the
analytic policies approximate: **measure** split candidates on the
actual backend, **decide** once (argmin per grid cell), **serve** the
frozen decisions through the Planner — the same spec -> resolver ->
artifact design as ``repro.plan`` and ``repro.cache``:

- :class:`TuneSpec`    — declarative workload grid (L_K buckets x head
  shapes x batch x impl x dtype), candidate split set, timing budget.
- :class:`Calibrator`  — resolves a spec by timing jitted
  ``ops.decode_attention`` launches per candidate split (median of
  repeats, warmup discard, seeded inputs), degrading gracefully to the
  analytic cost model where wall-clock timing is meaningless (CI/CPU).
- :class:`SplitTable`  — the versioned JSON artifact (schema + backend
  fingerprint + per-cell argmin splits and latency curves), persisted
  under ``experiments/tune/`` with load/save/merge/validate.

The table plugs into planning as the ``measured`` policy backend
(registered in ``repro.core.split_policy``): construct
``Planner(policy="measured", table=SplitTable.load(path))``, or serve
with ``ServeConfig(split_policy="measured", tune_table_path=...)`` /
``serve --tune-table``.  Uncovered shapes fall back to ``paper``
explicitly and are counted (``PlanCacheStats.measured_fallbacks``).
Calibrate with ``python -m repro.launch.tune``; the committed
``experiments/tune/reference_reduced.json`` covers the reduced-config
serving shapes so CI is deterministic (``make tune-golden``).
"""
from repro.tune.calibrator import Calibrator  # noqa: F401
from repro.tune.spec import DTYPE_BYTES, REFERENCE_SPEC, TuneSpec  # noqa: F401
from repro.tune.table import (  # noqa: F401
    REFERENCE_TABLE_PATH,
    SCHEMA_VERSION,
    SplitTable,
    TABLE_DIR,
    family_key,
    select_table,
)
