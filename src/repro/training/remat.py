"""Activation-checkpoint (remat) policies for the layer scan.

The scan body (one superblock) is wrapped with ``jax.checkpoint`` under a
named policy.  ``nothing_saveable`` (recompute everything from the layer
boundary) is the production default at these batch sizes — the §Roofline
``MODEL_FLOPS / HLO_FLOPs`` ratio surfaces its recompute cost explicitly,
and the §Perf iteration trades it against memory.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

POLICIES = {
    "none": None,                               # save everything
    "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
    "dots_saveable": jax.checkpoint_policies.dots_saveable,
    "dots_with_no_batch_dims":
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def block_wrapper(policy: str) -> Optional[Callable]:
    """-> wrapper for the scan body fn, or None for no remat."""
    if policy not in POLICIES:
        raise KeyError(f"unknown remat policy {policy!r}; "
                       f"known: {sorted(POLICIES)}")
    if policy == "none":
        return None
    pol = POLICIES[policy]

    def wrap(fn):
        return jax.checkpoint(fn, policy=pol)
    return wrap
