"""Train-step builder: loss, microbatch accumulation, AdamW, pjit wiring.

``build_train_step(model, tcfg, mesh)`` returns a bundle holding the
jitted step function plus the abstract inputs / shardings the dry-run
needs — lowering ``bundle.step`` with ``bundle.abstract_args()`` is
exactly what ``launch/dryrun.py`` does for every (arch x shape x mesh).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models.common import abstract_params
from repro.models.registry import Model
from repro.sharding.ctx import activation_mesh
from repro.sharding.rules import (
    activation_rules,
    param_rules,
    spec_for,
    tree_shardings,
)
from repro.training import remat as remat_mod
from repro.training.optimizer import (
    abstract_opt_state,
    adamw_init,
    adamw_update,
)

Pytree = Any


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(model: Model, params: Pytree, batch: Dict[str, jax.Array],
            *, block_wrapper=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy over the label positions.

    ``labels`` align with the LAST ``labels.shape[1]`` positions of the
    logits (vlm: the text tail after the patch prefix).  ``label < 0``
    masks a position out.
    """
    logits, aux = model.forward(params, batch, block_wrapper=block_wrapper)
    labels = batch["labels"]
    Lt = labels.shape[1]
    lg = logits[:, -Lt:]                                  # (B, Lt, V) f32
    # next-token shift: logits at i predict labels at i+1
    lg = lg[:, :-1]
    tgt = labels[:, 1:]
    mask = (tgt >= 0).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)       # (B, Lt-1)
    # label logit via one-hot contraction, NOT take_along_axis: a gather
    # over the vocab-sharded logits makes GSPMD all-gather the full
    # (B, L, V) tensor (~40 GiB/device at train_4k); the one-hot product
    # reduces shard-locally and cross-shard sums are a tiny (B, L) psum.
    onehot = jax.nn.one_hot(jnp.maximum(tgt, 0), lg.shape[-1],
                            dtype=lg.dtype)
    ll = jnp.sum(lg * onehot, axis=-1)
    nll = (logz - ll) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    aux_w = model.cfg.moe.aux_loss_weight if model.cfg.moe else 0.0
    total = loss + aux_w * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "tokens": mask.sum()}


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------


_BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "patches": ("batch", "seq", None),
    "frames": ("batch", "seq", None),
}


def make_batch_shapes(model: Model, shape: ShapeConfig
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract train/prefill inputs for an (arch, shape) cell."""
    B, L = shape.global_batch, shape.seq_len
    Lt = model.text_len(L)
    out = {
        "tokens": jax.ShapeDtypeStruct((B, Lt), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, Lt), jnp.int32),
    }
    for k, (shp, dt) in model.frontend_inputs(B, L).items():
        out[k] = jax.ShapeDtypeStruct(shp, jnp.dtype(dt))
    return out


def batch_shardings(mesh: Mesh, batch_shapes: Dict[str, jax.ShapeDtypeStruct]
                    ) -> Dict[str, NamedSharding]:
    rules = activation_rules()
    out = {}
    for k, s in batch_shapes.items():
        axes = _BATCH_AXES[k]
        # only the batch dim is sharded for inputs; seq stays whole
        axes = tuple(a if a == "batch" else None for a in axes)
        out[k] = NamedSharding(mesh, spec_for(tuple(s.shape), axes, rules,
                                              mesh))
    return out


@dataclass
class TrainStepBundle:
    model: Model
    tcfg: TrainConfig
    mesh: Mesh
    step: Callable                          # jitted
    param_shardings: Pytree
    opt_shardings: Pytree
    batch_shapes: Dict[str, jax.ShapeDtypeStruct]
    batch_shardings_: Dict[str, NamedSharding]

    def abstract_args(self):
        specs = self.model.param_specs()
        aparams = abstract_params(specs)
        aopt = abstract_opt_state(aparams)
        return aparams, aopt, self.batch_shapes

    def init(self, rng: jax.Array):
        params = self.model.init_params(rng)
        return params, adamw_init(params)


def build_train_step(model: Model, tcfg: TrainConfig, mesh: Mesh
                     ) -> TrainStepBundle:
    cfg = model.cfg
    rules = param_rules()
    specs = model.param_specs()
    aparams = abstract_params(specs)
    paxes = model.param_axes()
    pshard = tree_shardings(mesh, aparams, paxes, rules)
    oshard = {
        "m": pshard,
        "v": pshard,
        "count": NamedSharding(mesh, P()),
    }
    bshapes = make_batch_shapes(model, tcfg.shape)
    bshard = batch_shardings(mesh, bshapes)
    wrapper = remat_mod.block_wrapper(tcfg.remat_policy)
    micro = max(1, tcfg.microbatches)

    def loss_fn(params, batch):
        return lm_loss(model, params, batch, block_wrapper=wrapper)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if micro == 1:
            (total, metrics), grads = grad_fn(params, batch)
            return total, metrics, grads

        def split(x):
            B = x.shape[0]
            return x.reshape(micro, B // micro, *x.shape[1:])

        mb = jax.tree.map(split, batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          params)
        z = jnp.zeros((), jnp.float32)

        def body(carry, mbatch):
            gacc, tot, loss, aux, ntok = carry
            (t, m), g = grad_fn(params, mbatch)
            gacc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gacc, g)
            return (gacc, tot + t, loss + m["loss"], aux + m["aux_loss"],
                    ntok + m["tokens"]), None

        (gacc, tot, loss, aux, ntok), _ = jax.lax.scan(
            body, (g0, z, z, z, z), mb)
        inv = 1.0 / micro
        grads = jax.tree.map(lambda g, p: (g * inv).astype(p.dtype),
                             gacc, params)
        metrics = {"loss": loss * inv, "aux_loss": aux * inv,
                   "tokens": ntok}
        return tot * inv, metrics, grads

    # sequence-parallel attention when heads can't shard the model axis
    # (§Perf hillclimb A: head-replicated attention wastes axis-fold
    # compute; query-sharding recovers it)
    from repro.plan import LaunchPlan, plan_scope
    attn_plan = LaunchPlan(
        kind="prefill",
        seq_shard_mesh=(mesh if cfg.num_heads % mesh.shape["model"] != 0
                        else None))

    def step(params, opt_state, batch):
        with activation_mesh(mesh), plan_scope(attn_plan):
            total, metrics, grads = compute_grads(params, batch)
            params, opt_state, opt_metrics = adamw_update(
                grads, opt_state, params, tcfg.optimizer)
        metrics = dict(metrics, total_loss=total, **opt_metrics)
        return params, opt_state, metrics

    metrics_shard = {k: NamedSharding(mesh, P()) for k in
                     ("loss", "aux_loss", "tokens", "total_loss",
                      "grad_norm", "lr")}
    jitted = jax.jit(
        step,
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard, metrics_shard),
        donate_argnums=(0, 1),
    )
    return TrainStepBundle(model, tcfg, mesh, jitted, pshard, oshard,
                           bshapes, bshard)
