"""AdamW in pure JAX, spec-first like the models.

Optimizer state mirrors the param tree (same logical axes, so the same
sharding rules apply — fully-sharded optimizer state under FSDP).
Moments are float32 regardless of param dtype (bf16-safe).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig

Pytree = Any


def adamw_init(params: Pytree) -> Dict[str, Pytree]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params: Pytree) -> Dict[str, Pytree]:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, abstract_params),
        "v": jax.tree.map(f32, abstract_params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_state_axes(param_axes: Pytree) -> Dict[str, Pytree]:
    ident = lambda a: a
    copy = jax.tree.map(ident, param_axes,
                        is_leaf=lambda x: isinstance(x, tuple))
    return {"m": copy, "v": copy, "count": ()}


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Warmup then cosine/linear/constant decay; pure jnp (jit-safe)."""
    stepf = step.astype(jnp.float32)
    warm = jnp.maximum(1.0, float(cfg.warmup_steps))
    warmup = stepf / warm
    total = jnp.maximum(1.0, float(cfg.total_steps - cfg.warmup_steps))
    t = jnp.clip((stepf - warm) / total, 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - t
    else:
        decay = jnp.ones(())
    return cfg.lr * jnp.where(stepf < warm, warmup, decay)


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Pytree, max_norm: float
                        ) -> Tuple[Pytree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(
    grads: Pytree,
    state: Dict[str, Pytree],
    params: Pytree,
    cfg: OptimizerConfig,
) -> Tuple[Pytree, Dict[str, Pytree], Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if cfg.grad_clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    else:
        gnorm = global_norm(grads)
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_ = b1 * m + (1 - b1) * gf
        v_ = b2 * v + (1 - b2) * gf * gf
        mhat = m_ / bc1
        vhat = v_ / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m_, v_

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
