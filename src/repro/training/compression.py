"""Gradient compression: int8 quantization + error feedback (EF-SGD).

Used in the data-parallel gradient exchange: workers quantize gradients
to int8 against a SHARED scale (global max via a cheap pre-psum), sum
them in int32 (no overflow below 2^23 workers), and dequantize — 4x less
ICI traffic than f32 all-reduce, 2x less than bf16.  The quantization
residual is carried in an error-feedback buffer and added to the next
step's gradient, which restores convergence (EF-SGD, Karimireddy et al.).

``compressed_psum`` is the shard_map building block;
``build_compressed_dp_grads`` wraps a loss into a DP-only (replicated
params) gradient function with the compressed exchange.  With FSDP the
analogous hook is the reduce-scatter — recorded as future work in
DESIGN.md.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def quantize_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Symmetric int8 quantization against a given scale (f32)."""
    q = jnp.round(x.astype(jnp.float32) / jnp.maximum(scale, 1e-30))
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: Pytree, axis: str, *,
                    ef: Pytree) -> Tuple[Pytree, Pytree]:
    """Mean of ``grads`` across ``axis`` with int8-EF compression.

    Must run inside shard_map/pmap over ``axis``.  Returns
    (mean_grads f32, new error-feedback buffers).
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        # shared scale: global max magnitude so every worker's int8 grid
        # coincides and the int32 sum dequantizes exactly
        m = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis)
        scale = m / 127.0
        q = quantize_int8(gf, scale)
        e_new = gf - dequantize_int8(q, scale)        # residual stays local
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        return dequantize_int8(total, scale) / n, e_new

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_ef = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return mean, new_ef


def init_error_feedback(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def build_compressed_dp_grads(loss_fn: Callable, mesh, *,
                              data_axis: str = "data") -> Callable:
    """-> ``grad_fn(params, batch, ef) -> (loss, grads, new_ef)``.

    DP-only layout: params replicated, batch sharded on ``data_axis``;
    gradients cross the wire as int8.  Composable with the AdamW update.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def per_replica(params, batch, ef):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        loss = jax.lax.pmean(loss, data_axis)
        grads, ef = compressed_psum(grads, data_axis, ef=ef)
        return loss, grads, ef

    pspec = jax.tree.map(lambda _: P(), {"_": 0})["_"]

    def grad_fn(params, batch, ef):
        f = shard_map(
            per_replica, mesh=mesh,
            in_specs=(P(), jax.tree.map(lambda _: P(data_axis), batch),
                      P()),
            out_specs=(P(), P(), P()),
            check_rep=False)
        return f(params, batch, ef)

    return grad_fn
