"""Training substrate: optimizer, remat, microbatching, train step."""
from repro.training.optimizer import (  # noqa: F401
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    lr_schedule,
)
from repro.training.train_step import (  # noqa: F401
    TrainStepBundle,
    build_train_step,
    lm_loss,
    make_batch_shapes,
)
