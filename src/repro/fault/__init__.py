"""Fault tolerance: watchdog, straggler detection, elastic restart."""
from repro.fault.watchdog import (  # noqa: F401
    Heartbeat,
    StragglerDetector,
    Watchdog,
)
from repro.fault.elastic import elastic_restore, resumable_train_loop  # noqa: F401
