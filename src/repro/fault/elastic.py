"""Elastic restart: resume the same logical run on a different mesh.

The pieces that make this work, all exercised in the integration tests:

1. checkpoints are dense + mesh-agnostic (``checkpoint.restore`` takes
   the NEW mesh's shardings),
2. the data pipeline is stateless (``batch_at(step)``) so skip-ahead is
   exact — no replayed or dropped batches,
3. the train-step builder re-jits against the new mesh.

``resumable_train_loop`` is the crash-safe loop used by ``launch/train.py``
and the examples; inject ``fail_at_step`` to test mid-run crashes.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.checkpoint import checkpoint as ckpt
from repro.data.synthetic import SyntheticLM
from repro.fault.watchdog import StragglerDetector

Pytree = Any


def elastic_restore(path: str, bundle, rng: jax.Array
                    ) -> Tuple[int, Pytree, Pytree]:
    """(start_step, params, opt_state) — fresh init if no checkpoint."""
    step = ckpt.latest_step(path)
    if step is None:
        params, opt = bundle.init(rng)
        return 0, params, opt
    like_p, like_o, _ = bundle.abstract_args()
    _, state = ckpt.restore(
        path, {"params": like_p, "opt": like_o},
        shardings={"params": bundle.param_shardings,
                   "opt": bundle.opt_shardings})
    return step + 1, state["params"], state["opt"]


def resumable_train_loop(
    bundle,
    data: SyntheticLM,
    *,
    total_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 50,
    keep: int = 3,
    log_every: int = 10,
    async_ckpt: bool = True,
    fail_at_step: Optional[int] = None,
    log_fn: Callable[[str], None] = print,
) -> Dict[str, float]:
    """Run (or resume) training to ``total_steps``. Returns last metrics."""
    rng = jax.random.PRNGKey(bundle.tcfg.seed)
    start, params, opt = elastic_restore(ckpt_dir, bundle, rng)
    if start > 0:
        log_fn(f"[elastic] resumed at step {start} on mesh "
               f"{tuple(bundle.mesh.devices.shape)}")
    writer = ckpt.AsyncCheckpointer(ckpt_dir, keep=keep) if async_ckpt \
        else None
    straggler = StragglerDetector()
    metrics: Dict[str, float] = {}

    for step in range(start, total_steps):
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        t0 = time.monotonic()
        batch = {k: jax.numpy.asarray(v)
                 for k, v in data.batch_at(step).items()}
        params, opt, m = bundle.step(params, opt, batch)
        dt = time.monotonic() - t0
        straggler.record("worker_0", dt)
        if step % log_every == 0:
            metrics = {k: float(v) for k, v in m.items()}
            log_fn(f"step {step:5d} loss {metrics['loss']:.4f} "
                   f"gnorm {metrics['grad_norm']:.3f} {dt*1e3:.0f}ms")
        if ckpt_every and (step + 1) % ckpt_every == 0:
            state = {"params": params, "opt": opt}
            if writer:
                writer.save(step, state)
            else:
                ckpt.save(ckpt_dir, step, state, keep=keep)
    if writer:
        writer.wait()
    if start < total_steps:
        # a resume landing exactly at total_steps runs zero steps; the
        # last logged metrics (possibly empty) are all there is
        metrics = {k: float(v) for k, v in m.items()}
    return metrics
