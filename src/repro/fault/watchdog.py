"""Heartbeats, hang watchdog, and straggler detection.

On a real multi-host pod each worker runs a :class:`Heartbeat` (updated
every step) and the coordinator a :class:`Watchdog` thread; here the same
objects run in-process and the tests drive them with synthetic clocks.

:class:`StragglerDetector` implements the standard robust rule: a worker
is a straggler when its step time exceeds ``median x threshold`` over a
sliding window.  At pod scale the mitigation is eviction + elastic
restart (``fault/elastic.py``); the detector is deliberately decoupled
from the mitigation so either half can be swapped.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional


class Heartbeat:
    """Monotonic per-worker liveness signal."""

    def __init__(self, worker_id: str, clock: Callable[[], float] = time.monotonic):
        self.worker_id = worker_id
        self._clock = clock
        self._last = clock()
        self._lock = threading.Lock()

    def beat(self) -> None:
        with self._lock:
            self._last = self._clock()

    def age(self) -> float:
        with self._lock:
            return self._clock() - self._last


class Watchdog:
    """Fires ``on_dead(worker_id)`` when a heartbeat goes stale."""

    def __init__(self, timeout_s: float,
                 on_dead: Callable[[str], None],
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.on_dead = on_dead
        self._clock = clock
        self._beats: Dict[str, Heartbeat] = {}
        self._dead: set = set()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def register(self, hb: Heartbeat) -> None:
        self._beats[hb.worker_id] = hb

    def check_once(self) -> List[str]:
        """One scan; returns newly-dead worker ids (test-friendly)."""
        newly = []
        for wid, hb in self._beats.items():
            if wid in self._dead:
                continue
            if hb.age() > self.timeout_s:
                self._dead.add(wid)
                newly.append(wid)
                self.on_dead(wid)
        return newly

    def start(self, interval_s: float = 1.0) -> None:
        def loop():
            while not self._stop.wait(interval_s):
                self.check_once()
        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join()


@dataclass
class StragglerDetector:
    """Flag workers whose step time exceeds median x threshold."""
    window: int = 32
    threshold: float = 2.0
    min_samples: int = 8
    _times: Dict[str, Deque[float]] = field(
        default_factory=lambda: defaultdict(deque))

    def record(self, worker_id: str, step_time_s: float) -> None:
        q = self._times[worker_id]
        q.append(step_time_s)
        if len(q) > self.window:
            q.popleft()

    def _medians(self) -> Dict[str, float]:
        out = {}
        for wid, q in self._times.items():
            if len(q) >= self.min_samples:
                s = sorted(q)
                out[wid] = s[len(s) // 2]
        return out

    def stragglers(self) -> List[str]:
        med = self._medians()
        if len(med) < 2:
            return []
        global_median = sorted(med.values())[len(med) // 2]
        return [wid for wid, m in med.items()
                if m > self.threshold * global_median]
