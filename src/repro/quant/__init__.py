"""repro.quant — low-precision KV as a first-class planned subsystem.

Spec → resolver → artifact, like every other repro package:

    QuantSpec  ──resolve──▶  Quantizer  ──produce──▶  QuantizedKV
    (kv dtype, granularity,  (traced quantize /      (int8/fp8 K/V +
     scale dtype, amax mode)  dequantize transforms)  per-row scales)

The artifact feeds ``kernels.ops.decode_attention_quant`` (fused Pallas
in-register dequant, or the dequant-then-attend reference), plans carry
the dtype family through ``AttentionSpec.kv_dtype``, and ``repro.tune``
calibrates quantized cells through the fused harness.
"""
from repro.quant.quantizer import QuantizedKV, Quantizer
from repro.quant.spec import (AB_ATOL, AMAX_MODES, GRANULARITIES,
                              QUANT_DTYPES, QuantDtype, QuantSpec)

__all__ = [
    "AB_ATOL",
    "AMAX_MODES",
    "GRANULARITIES",
    "QUANT_DTYPES",
    "QuantDtype",
    "QuantSpec",
    "QuantizedKV",
    "Quantizer",
]
