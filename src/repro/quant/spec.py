"""QuantSpec: the declarative input to the KV-quantization resolver.

The sixth spec→resolver→artifact package (after repro.plan, repro.cache,
repro.tune, repro.spec, and the serving engine's request specs): a
:class:`QuantSpec` says WHAT low-precision scheme the KV cache uses —
storage dtype, scale granularity, scale dtype, amax calibration mode —
and nothing about HOW rows get quantized or attended; the
:class:`~repro.quant.Quantizer` resolves it into traced quantize /
dequantize transforms and a :class:`~repro.quant.QuantizedKV` artifact
the kernels consume directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax.numpy as jnp

from repro.core.split_policy import KV_DTYPES


@dataclass(frozen=True)
class QuantDtype:
    """Storage format of one quantized KV family."""
    name: str            # KV_DTYPES key ("int8" | "fp8")
    storage: str         # jnp dtype name of the cache leaves
    qmax: float          # largest representable magnitude
    rounds: bool         # True: round-to-nearest-int; False: dtype cast


# The quantized members of KV_DTYPES.  ``fp8`` is float8_e4m3fn — the
# decode-side FA3 choice (e5m2 trades mantissa for exponent range the
# scaled KV values never use).  Both are 1 byte/element, which is exactly
# why family keying is by NAME, not width.
QUANT_DTYPES: Dict[str, QuantDtype] = {
    "int8": QuantDtype("int8", "int8", 127.0, rounds=True),
    "fp8": QuantDtype("fp8", "float8_e4m3fn", 448.0, rounds=False),
}

GRANULARITIES = ("per_head", "per_page")
AMAX_MODES = ("abs_max", "static")

# Fused-vs-unfused A/B tolerance, per dtype (absolute, on attention
# outputs of O(1)-magnitude activations).  Both paths read the SAME
# quantized artifact and dequantize with the same scales, so the
# quantization error cancels exactly; what remains is kernel
# accumulation-order drift (blockwise online softmax vs split-XLA
# reference), which is dtype-independent float noise.  The headroom over
# the observed ~1e-5 keeps the oracle meaningful without flaking.
AB_ATOL: Dict[str, float] = {"int8": 2e-2, "fp8": 2e-2}


@dataclass(frozen=True)
class QuantSpec:
    """One KV-cache quantization scheme, declaratively.

    ``granularity``:
      - ``per_head``: one scale per (token, head) — amax over the feature
        dim.  The serving default; matches the cache's existing
        ``k_s``/``v_s`` scale-leaf layout exactly.
      - ``per_page``: one scale per (page, head) — amax pooled over each
        ``page_size``-row page, materialized per-row into the same scale
        leaves (rows of a page share the value).  Coarser ⇒ cheaper scale
        traffic, looser error bound; the kernels are granularity-blind
        (they always dequant against per-row scale blocks).

    ``amax_mode``:
      - ``abs_max``: dynamic — amax observed from the rows being written.
      - ``static``: fixed ``static_amax`` calibration constant (scale =
        static_amax / qmax everywhere); rows beyond it saturate-clip.
    """
    kv_dtype: str = "int8"              # QUANT_DTYPES key
    granularity: str = "per_head"       # per_head | per_page
    scale_dtype: str = "float32"
    amax_mode: str = "abs_max"          # abs_max | static
    static_amax: Optional[float] = None
    eps: float = 1e-8                   # amax floor (all-zero rows)

    def __post_init__(self) -> None:
        if self.kv_dtype not in QUANT_DTYPES:
            raise ValueError(
                f"unknown quantized kv_dtype {self.kv_dtype!r}; "
                f"known: {sorted(QUANT_DTYPES)} "
                f"(non-quantized KV_DTYPES: {sorted(KV_DTYPES)})")
        if self.granularity not in GRANULARITIES:
            raise ValueError(
                f"unknown scale granularity {self.granularity!r}; "
                f"known: {GRANULARITIES}")
        if self.amax_mode not in AMAX_MODES:
            raise ValueError(
                f"unknown amax mode {self.amax_mode!r}; "
                f"known: {AMAX_MODES}")
        if self.amax_mode == "static" and (
                self.static_amax is None or self.static_amax <= 0):
            raise ValueError(
                "amax_mode='static' needs a positive static_amax "
                "calibration constant")
        if self.eps <= 0:
            raise ValueError(
                "eps must be positive — it floors the amax so all-zero "
                "rows never divide by zero")
        jnp.dtype(self.scale_dtype)     # must be a real dtype name

    # --- resolved storage properties ---------------------------------------

    @property
    def qdtype(self) -> QuantDtype:
        return QUANT_DTYPES[self.kv_dtype]

    @property
    def storage_dtype(self) -> str:
        """jnp dtype NAME of the cache data leaves (ParamSpec-ready)."""
        return self.qdtype.storage

    @property
    def qmax(self) -> float:
        return self.qdtype.qmax

    @property
    def dtype_bytes(self) -> int:
        return int(jnp.dtype(self.storage_dtype).itemsize)

    def describe(self) -> Dict[str, object]:
        """JSON-safe summary (LaunchPlan provenance, logs)."""
        d: Dict[str, object] = {
            "kv_dtype": self.kv_dtype, "storage": self.storage_dtype,
            "granularity": self.granularity, "amax_mode": self.amax_mode,
        }
        if self.static_amax is not None:
            d["static_amax"] = self.static_amax
        return d
