"""Quantizer: resolves a QuantSpec into traced KV transforms.

The resolver of the ``repro.quant`` package: stateless, fully traced
(jit/vmap/scan-safe), and numerics-pinned — the int8 ``per_head`` /
``abs_max`` path is bit-identical to the pre-package
``models.attention.quantize_kv`` so existing engines, caches and golden
token streams are unchanged by construction.

Artifact: :class:`QuantizedKV`, a pytree of the four leaves every
quantized attention launch consumes (data + scales, each either a dense
array or a :class:`~repro.kernels.ops.PagedKV` view).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.quant.spec import QUANT_DTYPES, QuantSpec

Leaf = Union[jax.Array, object]         # array or kernels.ops.PagedKV view


class QuantizedKV(NamedTuple):
    """The quantized-cache artifact: what a fused decode launch reads.

    ``k``/``v``: (B, L, H_kv, D) in the spec's storage dtype.
    ``k_scale``/``v_scale``: (B, L, H_kv) scales (``scale_dtype``).
    Any leaf may be a ``PagedKV`` view — ``kernels.ops`` resolves views
    uniformly (the scale pools page exactly like the data pools, so one
    page table serves all four).
    """
    k: Leaf
    v: Leaf
    k_scale: Leaf
    v_scale: Leaf


class Quantizer:
    """Traced quantize/dequantize for one :class:`QuantSpec`."""

    def __init__(self, spec: QuantSpec = QuantSpec()):
        self.spec = spec

    # --- constructors -------------------------------------------------------

    @classmethod
    def from_kv_dtype(cls, kv_dtype: str, **kw) -> "Quantizer":
        """Resolver entry point from a KV_DTYPES name ("int8" | "fp8")."""
        return cls(QuantSpec(kv_dtype=kv_dtype, **kw))

    @classmethod
    def for_cache(cls, cache: Dict[str, jax.Array]) -> Optional["Quantizer"]:
        """Infer the quantizer a cache dict was built for, from its leaf
        dtype; ``None`` for unquantized caches (no scale leaves)."""
        if "k_s" not in cache:
            return None
        leaf = jnp.dtype(cache["k"].dtype)
        for name, qd in QUANT_DTYPES.items():
            if leaf == jnp.dtype(qd.storage):
                return cls(QuantSpec(kv_dtype=name))
        raise ValueError(
            f"cache has scale leaves but data dtype {leaf} matches no "
            f"registered quantized dtype ({sorted(QUANT_DTYPES)})")

    # --- amax / scale -------------------------------------------------------

    def _amax(self, xf: jax.Array, page_size: Optional[int]) -> jax.Array:
        """Per-(row, head) amax (..., L, H), pooled per page if asked."""
        amax = jnp.max(jnp.abs(xf), axis=-1)            # (..., L, H)
        if self.spec.amax_mode == "static":
            return jnp.full_like(amax, self.spec.static_amax)
        if self.spec.granularity == "per_page":
            if page_size is None:
                raise ValueError(
                    "granularity='per_page' needs page_size= at quantize "
                    "time (the cache layout's page width)")
            L = amax.shape[-2]
            n = -(-L // page_size)
            pad = n * page_size - L
            a = jnp.pad(amax,
                        [(0, 0)] * (amax.ndim - 2) + [(0, pad), (0, 0)])
            a = a.reshape(a.shape[:-2] + (n, page_size, a.shape[-1]))
            a = jnp.max(a, axis=-2)                      # (..., n, H)
            # materialize per-row so the scale-leaf layout (and the
            # kernels' per-row scale blocks) stay granularity-blind
            amax = jnp.repeat(a, page_size, axis=-2)[..., :L, :]
        return amax

    # --- the traced transforms ---------------------------------------------

    def quantize(self, x: jax.Array, *, page_size: Optional[int] = None
                 ) -> tuple:
        """x: (..., H, D) -> (q storage-dtype same shape, scale (..., H)).

        int8: symmetric round-to-nearest with saturate-clip at ±127 —
        bit-identical to the legacy ``quantize_kv``.  fp8 (e4m3fn):
        scale-to-±448 then dtype cast (the cast rounds to the nearest
        representable; no integer rounding step).
        """
        qd = self.spec.qdtype
        xf = x.astype(jnp.float32)
        amax = self._amax(xf, page_size)
        scale = jnp.maximum(amax, self.spec.eps) / qd.qmax
        y = xf / scale[..., None]
        if qd.rounds:
            y = jnp.round(y)
        y = jnp.clip(y, -qd.qmax, qd.qmax)
        return (y.astype(jnp.dtype(qd.storage)),
                scale.astype(jnp.dtype(self.spec.scale_dtype)))

    def dequantize(self, q: jax.Array, scale: jax.Array) -> jax.Array:
        """(q (..., H, D), scale (..., H)) -> f32 (..., H, D).

        The unfused reference transform; the fused Pallas kernel applies
        the same ``q.astype(f32) * scale`` in-register per KV block, so
        fused and unfused attend mathematically identical K/V.
        """
        return q.astype(jnp.float32) * scale[..., None]

    def quantized_kv(self, k: jax.Array, v: jax.Array, *,
                     page_size: Optional[int] = None) -> QuantizedKV:
        """Quantize a K/V pair into the artifact the kernels consume."""
        kq, ks = self.quantize(k, page_size=page_size)
        vq, vs = self.quantize(v, page_size=page_size)
        return QuantizedKV(kq, vq, ks, vs)

    # --- error bound --------------------------------------------------------

    def row_error_bound(self, scale: jax.Array) -> jax.Array:
        """Elementwise |x - dequant(quant(x))| bound per (row, head).

        int8 round-to-nearest: half a quantization step (scale / 2).
        fp8 e4m3 (3 mantissa bits): relative 2^-4 of the scaled value,
        i.e. ≤ qmax · 2^-4 · scale on the largest element.  Used by the
        roundtrip property tests — the fused-vs-unfused oracle needs no
        bound (the quant error cancels; see ``repro.quant.spec.AB_ATOL``).
        """
        if self.spec.qdtype.rounds:
            return 0.5 * scale
        return self.spec.qmax * (2.0 ** -4) * scale
