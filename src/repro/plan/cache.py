"""PlanCache: capacity-bounded plan residency with built-in stats.

One cache class serves both users that previously rolled their own:

- the serving engine's per-bucket (plan, specialized jitted step) map
  (formerly a private OrderedDict inside ``DecodeEngine``), and
- the process-wide metadata cache (formerly an unbounded
  ``functools.lru_cache`` in ``core.scheduler_metadata``).

Eviction is LRU-by-insertion-or-touch; a re-visited evicted key
re-builds (and, for the engine, re-specializes) and counts as a fresh
miss — the capacity knob trades steady-state recompiles for bounded
residency.
"""
from __future__ import annotations

from collections import OrderedDict, namedtuple
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Hashable, List, Optional, Set

CacheInfo = namedtuple("CacheInfo", ("hits", "misses", "maxsize",
                                     "currsize"))


@dataclass
class PlanCacheStats:
    """Observability for the metadata-enabled path.

    ``misses`` is also the recompile count for a cache holding jitted
    steps: every miss builds one new specialized entry, and nothing else
    does.  With an unbounded cache, misses == distinct keys; under a
    capacity bound, re-visiting an evicted key counts as a fresh miss.

    ``trace`` keeps only the most recent ``TRACE_CAP`` launches (a
    long-lived engine must not grow it unboundedly); ``seen_buckets`` is
    the PERSISTENT set of every key ever launched, so
    ``distinct_buckets`` stays exact forever — it must never be derived
    from the trimmed trace.

    ``fallback_*`` attributes the engine's internal-heuristic fallback
    path (``use_scheduler_metadata=False``): that ONE-step-for-all-
    lengths launch evaluates the split policy at trace time on the
    PADDED cache length, so per launch we record the resident-length
    summary it actually covered — ``(resident_max, traced_len)`` — and
    A/B benchmarks can attribute fallback plans to the residency they
    served instead of mistaking them for planned launches.

    ``measured_*`` attributes the ``measured`` (repro.tune) policy
    backend: every SplitTable lookup counts, and lookups whose shape
    family the table's grid does not cover — decided by the analytic
    fallback policy instead of a measurement — are counted and traced
    separately, so a serving A/B can tell "served from the table" from
    "served from the fallback" without re-deriving it.  The serving
    engine wires these up via ``SplitTable.attach_stats``.
    """
    TRACE_CAP = 4096

    hits: int = 0
    misses: int = 0
    launches: Dict[Hashable, int] = field(default_factory=dict)
    trace: List[Hashable] = field(default_factory=list)  # key per launch
    seen_buckets: Set[Hashable] = field(default_factory=set)
    fallback_launches: int = 0
    # (resident_max, traced_len) per fallback launch, trimmed like trace
    fallback_trace: List[tuple] = field(default_factory=list)
    # measured-policy (SplitTable) lookups; fallbacks = uncovered shapes
    measured_lookups: int = 0
    measured_fallbacks: int = 0
    # (batch, Hq, Hkv, head_dim, impl, dtype_bytes, L_K) per fallback
    measured_fallback_trace: List[tuple] = field(default_factory=list)
    # speculative decoding (repro.spec): one spec_step per verify launch;
    # proposed/accepted count draft tokens, emitted counts everything the
    # verify steps contributed (accepted drafts + correction/bonus rows).
    spec_steps: int = 0
    spec_proposed: int = 0
    spec_accepted: int = 0
    spec_emitted: int = 0
    spec_disabled: int = 0   # requests that hit SpecConfig.max_rejects
    # repro.tune registry (directory of tables): engines that found no
    # table matching the live backend fingerprint and fell back to the
    # registry's first table (counted once per engine construction)
    table_registry_fallbacks: int = 0

    @property
    def total_launches(self) -> int:
        return self.hits + self.misses

    @property
    def spec_acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens that verified (0.0 when no
        drafts were ever proposed)."""
        return (self.spec_accepted / self.spec_proposed
                if self.spec_proposed else 0.0)

    @property
    def spec_tokens_per_step(self) -> float:
        """Effective tokens per verify step (> 1.0 means speculation is
        beating one-token-per-launch decode; 0.0 when no verify steps
        ran)."""
        return (self.spec_emitted / self.spec_steps
                if self.spec_steps else 0.0)

    @property
    def distinct_buckets(self) -> int:
        return len(self.seen_buckets)

    def _trim(self, trace: List[Any]) -> None:
        """Bound a per-launch trace to the most recent ``TRACE_CAP``
        entries (amortized: trimmed only past 2x, so appends stay O(1)).
        EVERY trace must funnel through this — a long-lived engine leaks
        in any recording path that appends without trimming, and the
        aggregate counters (``launches`` / ``*_launches`` /
        ``*_fallbacks``) are what survive the trim."""
        if len(trace) > 2 * self.TRACE_CAP:
            del trace[:-self.TRACE_CAP]

    def record_launch(self, key: Hashable) -> None:
        self.launches[key] = self.launches.get(key, 0) + 1
        self.seen_buckets.add(key)
        self.trace.append(key)
        self._trim(self.trace)

    def record_fallback(self, resident_max: int, traced_len: int) -> None:
        """One internal-heuristic (no-plan) launch: the policy saw
        ``traced_len`` at trace time while only ``resident_max`` rows
        were actually resident."""
        self.fallback_launches += 1
        self.fallback_trace.append((int(resident_max), int(traced_len)))
        self._trim(self.fallback_trace)

    def record_measured(self, key: tuple, fallback: bool) -> None:
        """One measured-policy (SplitTable) lookup.  ``key`` is the
        workload family + L_K; ``fallback=True`` means the table's grid
        did not cover it and the analytic fallback policy decided."""
        self.measured_lookups += 1
        if fallback:
            self.measured_fallbacks += 1
            self.measured_fallback_trace.append(tuple(key))
            self._trim(self.measured_fallback_trace)

    def record_spec_step(self, proposed: int, accepted: int,
                         emitted: int) -> None:
        """One speculative verify launch: ``proposed`` draft tokens went
        in across all drafting slots, ``accepted`` survived the batched
        accept/reject, ``emitted`` tokens came out (accepted drafts plus
        one correction/bonus token per generating slot)."""
        self.spec_steps += 1
        self.spec_proposed += int(proposed)
        self.spec_accepted += int(accepted)
        self.spec_emitted += int(emitted)

    def record_spec_disabled(self) -> None:
        """One request gave up on speculation (max_rejects consecutive
        zero-accept verify steps)."""
        self.spec_disabled += 1

    def to_json(self) -> Dict[str, Any]:
        """JSON-safe snapshot of every counter (tuple keys flattened to
        ``"a/b"`` strings).  ``ServingEngine.drain`` dumps this when
        ``ServeConfig.stats_path`` is set, so serving A/Bs read the
        numbers instead of re-deriving them by hand."""
        def k2s(k: Hashable) -> str:
            return "/".join(map(str, k)) if isinstance(k, tuple) else str(k)

        return {
            "hits": self.hits,
            "misses": self.misses,
            "total_launches": self.total_launches,
            "distinct_buckets": self.distinct_buckets,
            "launches": {k2s(k): v for k, v in self.launches.items()},
            "seen_buckets": sorted(k2s(k) for k in self.seen_buckets),
            "fallback_launches": self.fallback_launches,
            "fallback_trace": [list(t) for t in self.fallback_trace],
            "measured_lookups": self.measured_lookups,
            "measured_fallbacks": self.measured_fallbacks,
            "measured_fallback_trace": [
                list(t) for t in self.measured_fallback_trace],
            "spec_steps": self.spec_steps,
            "spec_proposed": self.spec_proposed,
            "spec_accepted": self.spec_accepted,
            "spec_emitted": self.spec_emitted,
            "spec_disabled": self.spec_disabled,
            "spec_acceptance_rate": round(self.spec_acceptance_rate, 4),
            "spec_tokens_per_step": round(self.spec_tokens_per_step, 4),
            "table_registry_fallbacks": self.table_registry_fallbacks,
        }

    def reset(self) -> None:
        self.hits = 0
        self.misses = 0
        self.launches.clear()
        self.trace.clear()
        self.seen_buckets.clear()
        self.fallback_launches = 0
        self.fallback_trace.clear()
        self.measured_lookups = 0
        self.measured_fallbacks = 0
        self.measured_fallback_trace.clear()
        self.spec_steps = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        self.spec_disabled = 0
        self.table_registry_fallbacks = 0


class PlanCache:
    """LRU cache of plans (or plan-derived values, e.g. jitted steps).

    ``capacity`` of 0/None = unbounded.  ``track_launches=False`` keeps
    only the hit/miss counters (the process-wide metadata cache does not
    need per-key launch traces).
    """

    def __init__(self, capacity: Optional[int] = None, *,
                 track_launches: bool = True):
        self.capacity = capacity or None
        self.track_launches = track_launches
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.stats = PlanCacheStats()

    def get_or_build(self, key: Hashable, build: Callable[[], Any]) -> Any:
        """Return the cached value for ``key``, building (and possibly
        evicting the oldest entry) on a miss."""
        if key in self._entries:
            self._entries.move_to_end(key)
            self.stats.hits += 1
            value = self._entries[key]
        else:
            self.stats.misses += 1
            value = build()
            self._entries[key] = value
            if self.capacity and len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
        if self.track_launches:
            self.stats.record_launch(key)
        return value

    def peek(self, key: Hashable) -> Optional[Any]:
        """Lookup without touching LRU order or stats."""
        return self._entries.get(key)

    def cache_info(self) -> CacheInfo:
        """lru_cache-compatible counters (observability)."""
        return CacheInfo(self.stats.hits, self.stats.misses,
                         self.capacity, len(self._entries))

    def clear(self) -> None:
        self._entries.clear()
        self.stats.reset()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def keys(self):
        return self._entries.keys()

    def values(self):
        return self._entries.values()

    def items(self):
        return self._entries.items()


# keys of PlanCacheStats.to_json() that merge by summation (everything
# the per-shard engines count independently)
_MERGE_SUM_KEYS = (
    "hits", "misses", "total_launches", "fallback_launches",
    "measured_lookups", "measured_fallbacks", "spec_steps",
    "spec_proposed", "spec_accepted", "spec_emitted", "spec_disabled",
    "table_registry_fallbacks",
)


def merge_stats_snapshots(snaps: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Merge per-shard :meth:`PlanCacheStats.to_json` snapshots into one
    aggregate snapshot (the ``repro.shard`` engine's ``stats_path`` dump
    carries both the per-shard sections and this merge).

    Counters sum; per-key launch counts sum per key; ``seen_buckets``
    union (so ``distinct_buckets`` is the union's size, not a sum —
    every shard plans the same buckets for the same traffic); traces
    concatenate in shard order, trimmed to ``TRACE_CAP``; the derived
    speculation rates are recomputed from the summed counters.  Non-
    counter keys a caller added to a snapshot (``policy``, ``shard``,
    ...) are ignored.
    """
    out: Dict[str, Any] = {k: 0 for k in _MERGE_SUM_KEYS}
    launches: Dict[str, int] = {}
    seen: Set[str] = set()
    fallback_trace: List[list] = []
    measured_fallback_trace: List[list] = []
    for s in snaps:
        for k in _MERGE_SUM_KEYS:
            out[k] += int(s.get(k, 0))
        for k, v in s.get("launches", {}).items():
            launches[k] = launches.get(k, 0) + int(v)
        seen.update(s.get("seen_buckets", ()))
        fallback_trace.extend(s.get("fallback_trace", ()))
        measured_fallback_trace.extend(s.get("measured_fallback_trace", ()))
    cap = PlanCacheStats.TRACE_CAP
    out["launches"] = launches
    out["seen_buckets"] = sorted(seen)
    out["distinct_buckets"] = len(seen)
    out["fallback_trace"] = fallback_trace[-cap:]
    out["measured_fallback_trace"] = measured_fallback_trace[-cap:]
    out["spec_acceptance_rate"] = round(
        out["spec_accepted"] / out["spec_proposed"]
        if out["spec_proposed"] else 0.0, 4)
    out["spec_tokens_per_step"] = round(
        out["spec_emitted"] / out["spec_steps"]
        if out["spec_steps"] else 0.0, 4)
    out["shards"] = len(snaps)
    return out
