"""plan_scope: the ONE ambient plan stack.

Replaces the two thread-local context stacks that used to live in
``kernels.ops`` (``DecodeContext`` for decode launches, ``AttnContext``
for full-sequence attention).  A serve-step builder pushes one
:class:`~repro.plan.LaunchPlan`; every attention op traced under the
scope reads it back filtered by launch kind, so a decode plan never
leaks into a prefill launch and vice versa.

The stack is trace-time state (plans are static Python values), exactly
like the old contexts — nothing here is traced.
"""
from __future__ import annotations

import contextlib
from typing import List, Optional

from repro.plan.plan import LaunchPlan

_SCOPE: List[Optional[LaunchPlan]] = [None]


@contextlib.contextmanager
def plan_scope(plan: Optional[LaunchPlan]):
    """Make ``plan`` the ambient launch plan for ops traced inside.

    ``plan=None`` pushes an empty scope (shadowing any outer plan), which
    keeps nesting semantics uniform for callers that conditionally have
    a plan.
    """
    _SCOPE.append(plan)
    try:
        yield plan
    finally:
        _SCOPE.pop()


def current_plan(kind: Optional[str] = None) -> Optional[LaunchPlan]:
    """The innermost ambient plan, filtered by launch-kind family.

    ``kind="prefill"`` only returns prefill plans; any decode-family kind
    (``decode`` / ``decode_update`` / ``cross``) only returns
    decode-family plans.  ``kind=None`` returns whatever is on top.
    """
    plan = _SCOPE[-1]
    if plan is None or kind is None:
        return plan
    if (plan.kind == "prefill") != (kind == "prefill"):
        return None
    return plan
