"""``repro.plan`` — the launch-planning subsystem (Spec -> Plan -> Cache).

Single source of truth for "how do we launch attention", the way FA3 /
vLLM route scheduling through ``get_scheduler_metadata``:

- :class:`AttentionSpec`  — declarative description of one attention
  launch (kind, shapes, window, MLA v_width, quantization, mesh axis).
- :class:`Planner`        — compiles a spec into a frozen
  :class:`LaunchPlan` through a pluggable policy backend
  (``fa3_baseline`` / ``paper`` / ``tpu_adaptive`` / table-backed
  ``measured`` (``repro.tune``) / explicit ``num_splits_override``),
  including the mesh-level decision (:meth:`Planner.mesh_plan`).
- :class:`LaunchPlan`     — the frozen launch decision: split count,
  pack_gqa, impl, block_k, mesh min_splits / seq-shard, cache bucket.
- :class:`PlanCache`      — reusable capacity-bounded plan cache with
  built-in :class:`PlanCacheStats` (hits / misses / launches / trace /
  persistent seen-bucket set).
- :func:`plan_scope`      — the ONE ambient-context stack through which
  serve-step builders inject a plan into traced code (replaces the old
  ``DecodeContext`` / ``AttnContext`` dual stacks in ``kernels.ops``).

The kernels (``repro.kernels.ops``), the serving engine
(``repro.serving.engine``), the mesh serve-step builder
(``repro.serving.decode_step``) and the benchmarks all consume plans
through this package; ``repro.core.scheduler_metadata`` remains as a
thin legacy shim over it.
"""
from repro.plan.cache import (  # noqa: F401
    CacheInfo,
    PlanCache,
    PlanCacheStats,
    merge_stats_snapshots,
)
from repro.plan.plan import LaunchPlan  # noqa: F401
from repro.plan.planner import Planner  # noqa: F401
from repro.plan.scope import current_plan, plan_scope  # noqa: F401
from repro.plan.spec import AttentionSpec, bucket_seqlen  # noqa: F401
