"""AttentionSpec: the declarative input to the planner.

A spec answers "WHAT are we launching" — kind and shapes — and nothing
about HOW (splits, impl, sharding); the :class:`~repro.plan.Planner`
compiles the how into a :class:`~repro.plan.LaunchPlan`.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Optional

from repro.core.split_policy import KV_BLOCK, KV_DTYPES, DecodeWorkload

# The launch kinds the planner understands.  ``decode`` and
# ``decode_update`` share one decision surface (the paper's split-KV
# policy); ``cross`` is decode against a fixed encoder memory (same
# policy, different L_K); ``prefill`` never splits KV; ``verify`` is the
# speculative-decoding verify step — decode with a k-row query block
# (seqlen_q = draft length + 1), same split policy over a workload whose
# ``num_m_blocks`` scales with the query rows.
KINDS = ("decode", "decode_update", "prefill", "cross", "verify")


def bucket_seqlen(seqlen_k: int, bucket: int = KV_BLOCK) -> int:
    """Round a cache length up to its block bucket so plan lookups hit.

    The serving engine quantizes L_K to the KV block width: the policy's
    decision only depends on ``num_n_blocks``, so this is lossless.
    """
    return ((max(1, seqlen_k) + bucket - 1) // bucket) * bucket


@dataclass(frozen=True)
class AttentionSpec:
    """One attention launch, declaratively.

    Mirrors the paper's shape tuple (Batch, L_Q, L_K, H_Q, H_KV, D) plus
    the launch kind and the launch-affecting extras: sliding ``window``
    (ring cache => L_K = window), MLA ``v_width`` (v = k[..., :v_width]),
    the KV-cache ``kv_dtype`` (a :data:`repro.core.split_policy.KV_DTYPES`
    name — quantized dtypes get their own split decisions AND their own
    tune-table families), and the mesh axis the launch may shard over.

    ``layout`` is the cache-side summary the serving engine plans from:
    under the ``repro.cache`` paged layout ``seqlen_k`` is the
    RESIDENT-length bucket (what the launch actually attends over), not
    the engine's padded slot capacity.  (The per-step true resident max
    is a runtime quantity — observe it via ``CacheManager.describe()``
    / ``PlanCacheStats.fallback_trace``, not the static spec.)
    """
    kind: str                           # one of KINDS
    batch: int
    seqlen_q: int
    seqlen_k: int
    num_heads_q: int
    num_heads_kv: int
    head_dim: int = 128
    window: Optional[int] = None
    v_width: Optional[int] = None       # MLA latent: v ⊂ k
    # DEPRECATED: the boolean cannot distinguish int8 from fp8 (both
    # 1 byte, different kernels/tolerances/tune families).  Pass
    # ``kv_dtype="int8"`` / ``"fp8"`` instead.  ``quantized=True`` still
    # works via a compat shim (DeprecationWarning, implies int8) and the
    # field is normalized in ``__post_init__`` to ``kv_dtype``'s
    # quantized-ness so equality/hash stay consistent.
    quantized: Optional[bool] = None
    kv_dtype: str = "bfloat16"          # a KV_DTYPES name
    mesh_axis: Optional[str] = None     # sharding axis name (mesh plans)
    mesh_axis_size: int = 1
    layout: str = "dense"               # repro.cache layout ("dense"|"paged")

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown attention kind {self.kind!r}; known: {KINDS}")
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"unknown kv_dtype {self.kv_dtype!r}; "
                f"known: {sorted(KV_DTYPES)}")
        if self.quantized and self.kv_dtype == "bfloat16":
            # legacy call site: quantized=True meant "int8 KV cache".
            # (A replayed spec with kv_dtype already quantized skips this
            # branch, so dataclasses.replace / bucketed() never re-warn.)
            warnings.warn(
                "AttentionSpec.quantized is deprecated; pass "
                "kv_dtype='int8' (or 'fp8') instead — the boolean cannot "
                "distinguish same-width quantized dtypes",
                DeprecationWarning, stacklevel=3)
            object.__setattr__(self, "kv_dtype", "int8")
        object.__setattr__(self, "quantized",
                           KV_DTYPES[self.kv_dtype] == 1)

    def workload(self) -> DecodeWorkload:
        """The policy-facing shape tuple (what the split heuristic reads).

        ``dtype_bytes`` follows the cache dtype (quantized KV moves half
        the bytes of bf16): the occupancy cost model reads the bytes, and
        the ``measured`` table's family key additionally reads the dtype
        NAME, so an fp8 launch never plans from (or looks up) int8 cells.
        """
        lk = self.seqlen_k if self.window is None \
            else min(self.window, self.seqlen_k)
        return DecodeWorkload(self.batch, self.seqlen_q, lk,
                              self.num_heads_q, self.num_heads_kv,
                              self.head_dim,
                              dtype_bytes=KV_DTYPES[self.kv_dtype],
                              kv_dtype=self.kv_dtype)

    def bucketed(self, bucket: int = KV_BLOCK) -> "AttentionSpec":
        """Spec with L_K rounded up to its cache-length bucket."""
        return dataclasses.replace(
            self, seqlen_k=bucket_seqlen(self.seqlen_k, bucket))

    # --- convenience constructors ------------------------------------------

    @classmethod
    def decode(cls, batch: int, seqlen_k: int, num_heads_q: int,
               num_heads_kv: int, head_dim: int = 128,
               **kw) -> "AttentionSpec":
        """Pure decode: one new query token against a KV cache."""
        return cls("decode", batch, 1, seqlen_k, num_heads_q, num_heads_kv,
                   head_dim, **kw)

    @classmethod
    def prefill(cls, batch: int, seqlen: int, num_heads_q: int,
                num_heads_kv: int, head_dim: int = 128,
                **kw) -> "AttentionSpec":
        """Fused prompt prefill: causal self-attention with
        L_Q = L_K = the bucket-padded prompt length (the serving
        engine's admission launch).  Prefill never splits KV, but the
        spec still flows through the Planner so the launch is planned,
        cached and counted like any other."""
        return cls("prefill", batch, seqlen, seqlen, num_heads_q,
                   num_heads_kv, head_dim, **kw)

    @classmethod
    def verify(cls, batch: int, seqlen_q: int, seqlen_k: int,
               num_heads_q: int, num_heads_kv: int, head_dim: int = 128,
               **kw) -> "AttentionSpec":
        """Speculative-decoding verify step: a ``seqlen_q``-row query
        block (the committed current token + k drafts) against the KV
        cache, causal *within* the block at the slot's absolute offset.
        Splits are planned by the same sequence-aware policy as decode —
        the k-row block shifts ``num_m_blocks`` and hence the occupancy
        picture, which is the planning-side point of speculation."""
        return cls("verify", batch, seqlen_q, seqlen_k, num_heads_q,
                   num_heads_kv, head_dim, **kw)

    @classmethod
    def from_workload(cls, w: DecodeWorkload, kind: str = "decode",
                      **kw) -> "AttentionSpec":
        kw.setdefault("kv_dtype", w.kv_dtype_name)
        return cls(kind, w.batch, w.seqlen_q, w.seqlen_k, w.num_heads_q,
                   w.num_heads_kv, w.head_dim, **kw)
