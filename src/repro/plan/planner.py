"""Planner: compiles an AttentionSpec into a frozen LaunchPlan.

The policy backend is pluggable by name (``fa3_baseline`` / ``paper`` /
``tpu_adaptive`` / ``measured`` — the registry in
``repro.core.split_policy``) or bypassed entirely with
``num_splits_override`` (FA3's explicit ``num_splits`` argument;
benchmarks use it for forced-split sweeps).  The ``measured`` backend
decides from an injected ``repro.tune`` :class:`SplitTable`
(``Planner(policy="measured", table=...)``); plans record their
``tuned`` / ``table_version`` provenance.

Two planning levels share one entry point:

- :meth:`Planner.plan`       — the kernel-level decision (the paper's
  split count) for one launch shape.
- :meth:`Planner.mesh_plan`  — the same decision lifted to a mesh axis:
  how many ways the KV cache sequence-shards across chips
  (``mesh_splits``), including the storage-forced case where H_KV does
  not divide the axis.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from repro.core.split_policy import (
    DEFAULT_NUM_CORES,
    available_policies,
    choose_mesh_splits,
    choose_num_splits,
    get_policy,
)
from repro.plan.plan import LaunchPlan
from repro.plan.spec import AttentionSpec


@dataclass(frozen=True)
class Planner:
    """Pluggable policy backend -> frozen launch plans.

    ``num_cores = None`` means "the policy's default machine model"
    (:data:`DEFAULT_NUM_CORES`); mesh planning substitutes the axis size.
    ``table`` is the calibrated ``repro.tune`` SplitTable the
    ``measured`` backend decides from (required for it, ignored by the
    analytic backends).
    """
    policy: str = "paper"
    num_cores: Optional[int] = None
    num_splits_override: Optional[int] = None
    pack_gqa: Optional[bool] = None       # None = pack iff H_Q > H_KV
    impl: Optional[str] = None            # xla | pallas | naive
    block_k: Optional[int] = None         # Pallas KV block width
    table: Optional[object] = None        # repro.tune.SplitTable

    def __post_init__(self):
        fn = get_policy(self.policy)      # fail fast on unknown backends
        if getattr(fn, "needs_table", False) and self.table is None:
            raise ValueError(
                f"split policy {self.policy!r} decides from a calibrated "
                "repro.tune SplitTable: pass Planner(table="
                "SplitTable.load(path)) (serving: ServeConfig."
                "tune_table_path / serve --tune-table); analytic "
                f"backends needing no table: "
                f"{[p for p in available_policies() if p != self.policy]}")

    # --- kernel-level planning ---------------------------------------------

    def plan(self, spec: AttentionSpec, *,
             bucket: Optional[int] = None) -> LaunchPlan:
        """Freeze the launch decision for one attention shape."""
        w = spec.workload()
        cores = self.num_cores if self.num_cores is not None \
            else DEFAULT_NUM_CORES
        tuned, table_version = False, None
        if spec.kind == "prefill":
            s = 1                         # prefill never splits KV
        elif self.num_splits_override is not None:
            s = max(1, min(int(self.num_splits_override), w.num_n_blocks))
        elif self.table is not None and \
                getattr(get_policy(self.policy), "needs_table", False):
            s, tuned = self.table.choose(w, impl=self.impl,
                                         num_cores=cores)
            table_version = self.table.version
        else:
            s = choose_num_splits(w, policy=self.policy, num_cores=cores,
                                  table=self.table)
        if self.pack_gqa is not None:
            pack = self.pack_gqa
        elif spec.kind == "prefill":
            pack = False                  # full L_Q rows already fill tiles
        else:
            pack = spec.num_heads_q > spec.num_heads_kv
        return LaunchPlan(kind=spec.kind, spec=spec, num_splits=s,
                          pack_gqa=pack, policy=self.policy,
                          num_cores=cores, impl=self.impl,
                          block_k=self.block_k, bucket=bucket,
                          tuned=tuned, table_version=table_version)

    def context(self, kind: str = "decode", **overrides) -> LaunchPlan:
        """A context-only plan: nothing frozen, policy runs at trace time
        with this planner's backend (the internal-heuristic A/B path)."""
        return LaunchPlan(kind=kind, policy=self.policy,
                          num_cores=self.num_cores, impl=self.impl,
                          block_k=self.block_k, **overrides)

    # --- mesh-level planning -----------------------------------------------

    def mesh_plan(self, spec: AttentionSpec, *, axis_size: int,
                  axis: str = "model",
                  bucket: Optional[int] = None) -> LaunchPlan:
        """Kernel plan + the mesh-level sequence-shard decision.

        Two reasons to shard the cache over ``axis`` (``mesh_splits`` =
        axis size): (a) the occupancy policy says the axis is starved —
        the paper's grid starvation with chips in place of SMs; or (b)
        *storage*: H_KV doesn't divide the axis, so head-sharding
        degenerates to full replication and sequence-sharding is
        strictly better regardless of the compute policy.  The split is
        binary on a fixed mesh (any split -> whole-axis shard; fractional
        axis splits need sub-axes, recorded as future work).

        ``bucket`` passes through to :meth:`plan` — the mesh-native
        serving engine freezes bucket-keyed plans through this path, so
        ``mesh_splits`` provenance lands on every scheduler plan.
        """
        w = spec.workload()
        mesh_spec = dataclasses.replace(spec, mesh_axis=axis,
                                        mesh_axis_size=axis_size)
        planner = dataclasses.replace(self, num_cores=axis_size)
        if spec.num_heads_kv % axis_size != 0:      # storage-driven (b)
            planner = dataclasses.replace(planner,
                                          num_splits_override=axis_size)
            p = planner.plan(mesh_spec, bucket=bucket)
            return dataclasses.replace(p, mesh_splits=axis_size,
                                       seq_shard_axis=axis)
        p = planner.plan(mesh_spec, bucket=bucket)
        s_mesh = choose_mesh_splits(w, axis_size, policy=self.policy,
                                    table=self.table, impl=self.impl)
        return dataclasses.replace(
            p, mesh_splits=axis_size if s_mesh > 1 else 1,
            seq_shard_axis=axis)
