"""LaunchPlan: the frozen output of the planner.

A plan is a static Python value — jitted steps close over it, so XLA
specializes the whole program (kernel grid included) on the frozen
``num_splits``.  It is a superset of the old ``SchedulerMetadata``:
besides the split decision it carries the impl choice, the Pallas
``block_k``, GQA packing, the cache-length bucket it covers, and the
mesh-level realization (``mesh_splits`` / ``min_splits`` / seq-shard
fields the serve-step builder pins into the ambient scope).

``num_splits is None`` marks a *context-only* plan: nothing frozen, the
split policy runs at trace time with this plan's ``policy`` /
``num_cores`` (the paper's weaker "internal heuristic" path, kept for
A/B).  ``plan.frozen`` distinguishes the two.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

from repro.core.split_policy import DecodeWorkload
from repro.plan.spec import AttentionSpec


@dataclass(frozen=True)
class LaunchPlan:
    """Frozen launch decision for one attention shape (or a context-only
    override when ``num_splits`` is None)."""
    kind: str = "decode"                  # decode | decode_update | prefill | cross
    spec: Optional[AttentionSpec] = None
    num_splits: Optional[int] = None      # None = not frozen (heuristic path)
    pack_gqa: bool = False
    policy: str = "paper"
    num_cores: Optional[int] = None       # None = policy default
    impl: Optional[str] = None            # xla | pallas | naive; None = caller's
    block_k: Optional[int] = None         # Pallas KV block; None = kernel default
    bucket: Optional[int] = None          # cache-length bucket this plan covers
    # --- measured-policy provenance (repro.tune) ---------------------------
    # tuned=True: num_splits came from a calibrated SplitTable cell;
    # tuned=False under policy="measured": the table's grid did not
    # cover this shape and the analytic fallback decided (counted in
    # PlanCacheStats.measured_fallbacks).
    tuned: bool = False
    table_version: Optional[str] = None   # SplitTable.version that decided
    # --- mesh-level realization (serve-step builder) -----------------------
    mesh_splits: int = 1                  # ways the model axis seq-shards KV
    min_splits: int = 1                   # kernel split rounded up to this
    # applied to the (S, B, C, H, D) split-KV tensors and (S, ...) partials
    split_constraint: Optional[Callable] = None
    # fused shard_map sequence-sharded decode (optimized path)
    seq_shard_mesh: Optional[object] = None
    seq_shard_axis: str = "model"

    # --- predicates --------------------------------------------------------

    @property
    def frozen(self) -> bool:
        """True when the split decision is precomputed (metadata path)."""
        return self.num_splits is not None

    @property
    def uses_split(self) -> bool:
        return self.num_splits is not None and self.num_splits > 1

    # --- legacy SchedulerMetadata surface ----------------------------------

    @property
    def workload(self) -> Optional[DecodeWorkload]:
        """The policy-facing shape tuple (old ``SchedulerMetadata.workload``)."""
        return None if self.spec is None else self.spec.workload()

    # --- derivations -------------------------------------------------------

    def context_only(self) -> "LaunchPlan":
        """Drop the frozen decision, keep the overrides.

        Used where a frozen plan must NOT transfer — e.g. cross-attention
        decodes against the encoder length, window layers against the
        ring cache: different shapes than the plan was frozen for — while
        the policy / num_cores / mesh context still apply.
        """
        return dataclasses.replace(self, spec=None, num_splits=None,
                                   bucket=None, tuned=False,
                                   table_version=None)

    def describe(self) -> Dict[str, Any]:
        """JSON-safe summary (dry-run records, logs)."""
        d: Dict[str, Any] = {
            "kind": self.kind, "policy": self.policy,
            "num_splits": self.num_splits, "pack_gqa": self.pack_gqa,
            "mesh_splits": self.mesh_splits,
        }
        if self.num_cores is not None:
            d["num_cores"] = self.num_cores
        if self.bucket is not None:
            d["bucket"] = self.bucket
        if self.table_version is not None:
            d["tuned"] = self.tuned
            d["table_version"] = self.table_version
        if self.impl is not None:
            d["impl"] = self.impl
        if self.block_k is not None:
            d["block_k"] = self.block_k
        if self.spec is not None:
            w = self.spec.workload()
            d["shape"] = (f"B{w.batch} Lq{w.seqlen_q} Lk{w.seqlen_k} "
                          f"Hq{w.num_heads_q} Hkv{w.num_heads_kv} "
                          f"D{w.head_dim}")
            if w.dtype_bytes != 2:
                # quantized (or widened) KV provenance: the split decision
                # above was made for THIS byte width / dtype family.
                d["kv_dtype"] = w.kv_dtype_name
                d["dtype_bytes"] = w.dtype_bytes
        return d
