"""Shared model machinery: param specs, norms, RoPE, MLPs, embeddings.

Everything is *spec-first*: a model family declares its parameters as a
pytree of :class:`ParamSpec` (shape + logical axes + init), and three
derived views fall out mechanically:

- ``init_params``      — materialize real arrays (smoke tests / examples),
- ``abstract_params``  — ``ShapeDtypeStruct`` stand-ins (the dry-run path:
  full-size configs are *never* allocated),
- ``logical_axes``     — pytree of logical-axis tuples that
  ``sharding/rules.py`` maps onto the mesh.

Logical axis vocabulary (mapped to mesh axes in one place):

====================  =======================================================
``layers``            stacked-scan leading dim (never sharded)
``embed``             d_model / residual stream (FSDP-sharded on data axes)
``vocab``             vocabulary (TP-sharded)
``heads``             attention query heads (TP-sharded)
``kv_heads``          attention KV heads (TP-sharded; replicated if < axis)
``head_dim``          per-head feature dim (never sharded)
``ff``                MLP hidden (TP-sharded)
``experts``           MoE expert dim (expert-parallel on the model axis)
``state``             SSM/LRU recurrent width (TP-sharded)
``seq``               sequence dim of activations / caches
``batch``             batch dim of activations / caches
====================  =======================================================
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    """Declaration of one parameter tensor."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis per dim
    dtype: str = "bfloat16"
    init: str = "normal"                     # normal | zeros | ones
    fan_in: Optional[int] = None             # stddev = 1/sqrt(fan_in)
    # cache leaves only: whether repro.cache may page this tensor over
    # its "seq" axis.  None = infer (a full-capacity "seq" axis pages);
    # False pins position-complete tensors like encdec's cross K/V,
    # which are read to their FULL length every step and must never be
    # gathered through a per-slot page table.
    paged: Optional[bool] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _tree_map_specs(fn, specs: Pytree) -> Pytree:
    return jax.tree_util.tree_map(fn, specs, is_leaf=is_spec)


def abstract_params(specs: Pytree) -> Pytree:
    """ShapeDtypeStructs for the dry-run: zero bytes allocated."""
    return _tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.jdtype), specs)


def logical_axes(specs: Pytree) -> Pytree:
    return _tree_map_specs(lambda s: s.axes, specs)


def param_count(specs: Pytree) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def init_params(specs: Pytree, rng: jax.Array) -> Pytree:
    """Materialize parameters (small/smoke configs only)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    rngs = jax.random.split(rng, max(1, len(leaves)))

    def one(spec: ParamSpec, key):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.jdtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.jdtype)
        fan_in = spec.fan_in if spec.fan_in else (
            spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1])
        std = 1.0 / math.sqrt(max(1, fan_in))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std
                ).astype(spec.jdtype)

    arrays = [one(s, k) for s, k in zip(leaves, rngs)]
    return jax.tree_util.tree_unflatten(treedef, arrays)


# ---------------------------------------------------------------------------
# Small pure modules (params are dicts of arrays keyed like their specs)
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(x.dtype)


def norm_specs(d: int, kind: str = "rms") -> Dict[str, ParamSpec]:
    if kind == "rms":
        return {"scale": ParamSpec((d,), ("embed",), init="ones")}
    return {"scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros")}


def apply_norm(params: Dict[str, jax.Array], x: jax.Array,
               eps: float) -> jax.Array:
    if "bias" in params:
        return layer_norm(x, params["scale"], params["bias"], eps)
    return rms_norm(x, params["scale"], eps)


# --- RoPE -------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embedding (half of head_dim)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, interleaved-free (rotate-half / GPT-NeoX style).

    x: (..., L, H, D); positions: broadcastable to (..., L).
    """
    if theta <= 0:                      # e.g. whisper: no rope
        return x
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., L, d/2)
    # insert head axis: (..., L, 1, d/2)
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (length, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(1, half - 1))
    ang = jnp.arange(length)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --- MLP --------------------------------------------------------------------


def mlp_specs(d_model: int, d_ff: int, kind: str) -> Dict[str, ParamSpec]:
    if kind in ("swiglu", "geglu"):
        return {
            "wi_gate": ParamSpec((d_model, d_ff), ("embed", "ff")),
            "wi_up": ParamSpec((d_model, d_ff), ("embed", "ff")),
            "wo": ParamSpec((d_ff, d_model), ("ff", "embed")),
        }
    return {  # plain gelu (whisper)
        "wi": ParamSpec((d_model, d_ff), ("embed", "ff")),
        "bi": ParamSpec((d_ff,), ("ff",), init="zeros"),
        "wo": ParamSpec((d_ff, d_model), ("ff", "embed")),
        "bo": ParamSpec((d_model,), ("embed",), init="zeros"),
    }


def apply_mlp(params: Dict[str, jax.Array], x: jax.Array,
              kind: str) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        g = act(x @ params["wi_gate"])
        u = x @ params["wi_up"]
        return (g * u) @ params["wo"]
    h = jax.nn.gelu(x @ params["wi"] + params["bi"].astype(x.dtype))
    return h @ params["wo"] + params["bo"].astype(x.dtype)


# --- Embedding / unembedding -------------------------------------------------


def embed_specs(vocab: int, d_model: int, tie: bool) -> Dict[str, ParamSpec]:
    specs = {"tok": ParamSpec((vocab, d_model), ("vocab", "embed"),
                              fan_in=d_model)}
    if not tie:
        specs["unembed"] = ParamSpec((d_model, vocab), ("embed", "vocab"))
    return specs


def embed_tokens(params: Dict[str, jax.Array], tokens: jax.Array) -> jax.Array:
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """Project to vocab logits in f32 (loss-stable)."""
    w = params.get("unembed")
    if w is None:
        w = params["tok"].T
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(w, jnp.float32)


# ---------------------------------------------------------------------------
# Stacking helpers (scan-over-layers)
# ---------------------------------------------------------------------------


def stack_specs(specs: Pytree, n: int) -> Pytree:
    """Prefix every leaf with a ``layers`` dim of size n (for lax.scan)."""
    return _tree_map_specs(
        lambda s: dataclasses.replace(
            s, shape=(n,) + s.shape, axes=("layers",) + s.axes), specs)
