"""Standard multi-head attention block (GQA/MQA/MHA) with KV cache.

Three entry points, all operating on a single layer's params:

- :func:`attention_train`   — full-sequence causal (optionally windowed)
  attention for training / prefill.
- :func:`attention_decode`  — one-token decode against a padded KV cache,
  routed through the paper's split policy via ``kernels.ops``.
- :func:`cache_update`      — functional KV-cache write at position ``t``.

Cache layout is ``(B, L_max, H_kv, D)`` — sequence-major so the mesh-level
sequence split (serving/decode_step.py) can shard ``L_max`` directly.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.common import ParamSpec, apply_rope
from repro.plan import LaunchPlan
from repro.quant import QUANT_DTYPES, Quantizer

Params = Dict[str, jax.Array]


def attention_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    specs = {
        "wq": ParamSpec((d, hq, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((hq, hd, d), ("heads", "head_dim", "embed"),
                        fan_in=hq * hd),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((hq, hd), ("heads", "head_dim"), init="zeros")
        specs["bk"] = ParamSpec((hkv, hd), ("kv_heads", "head_dim"),
                                init="zeros")
        specs["bv"] = ParamSpec((hkv, hd), ("kv_heads", "head_dim"),
                                init="zeros")
    return specs


def _project_qkv(params: Params, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array, rope: bool = True
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, L, d) -> q (B,L,Hq,D), k/v (B,L,Hkv,D), rope applied."""
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, params["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    if rope and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_train(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,                       # (B, L, d)
    positions: jax.Array,               # (B, L) int32
    *,
    window: Optional[int] = None,
    impl: Optional[str] = None,
) -> jax.Array:
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = ops.attention(q, k, v, causal=True, window=window,
                        impl=impl or cfg.attention_impl)
    return jnp.einsum("blhk,hkd->bld", out, params["wo"])


def attention_prefill(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,                       # (B, L, d)
    positions: jax.Array,               # (B, L)
    cache_len: int,
    *,
    window: Optional[int] = None,
    impl: Optional[str] = None,
    kv_dtype: str = "bfloat16",
    plan: Optional[LaunchPlan] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence attention that also emits the decode cache.

    The cache is laid out exactly as the decode step expects: linear
    [0..L) for full attention, ring order (position % window) holding the
    last ``window`` positions for local attention.  A prefill-kind
    ``plan`` (the serving engine's fused-admission path) selects the
    attention impl; prefill never splits KV, so there is no frozen
    split to consume.
    """
    B, L, _ = x.shape
    if impl is None and plan is not None:
        impl = plan.impl
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = ops.attention(q, k, v, causal=True, window=window,
                        impl=impl or cfg.attention_impl)
    y = jnp.einsum("blhk,hkd->bld", out, params["wo"])

    if window is None:
        pad = cache_len - L
        assert pad >= 0, f"prompt ({L}) exceeds cache ({cache_len})"
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        W = cache_len                   # ring cache sized min(window, max)
        if L >= W:
            # slot s holds the unique position p in [L-W, L), p % W == s
            s_idx = jnp.arange(W)
            base = L - W
            src = base + jnp.mod(s_idx - base, W)
            kc, vc = k[:, src], v[:, src]
        else:
            pad = W - L
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if kv_dtype in QUANT_DTYPES:
        qz = Quantizer.from_kv_dtype(kv_dtype)
        kq, ks = qz.quantize(kc)
        vq, vs = qz.quantize(vc)
        return y, {"k": kq, "v": vq, "k_s": ks, "v_s": vs}
    return y, {"k": kc.astype(cfg.dtype), "v": vc.astype(cfg.dtype)}


def _place_rows(old: jax.Array, new: jax.Array,
                start: jax.Array) -> jax.Array:
    """Write ``new`` (B, M, ...) into ``old`` (B, V, ...) at row offset
    ``start`` (traced scalar).  ``dynamic_update_slice`` is wrong here:
    it CLAMPS the start index so a suffix landing near the view's end
    would silently shift — masked take/where places rows exactly and
    out-of-range rows keep their old values."""
    V, M = old.shape[1], new.shape[1]
    idx = jnp.arange(V)
    src = jnp.clip(idx - start, 0, M - 1)
    mask = (idx >= start) & (idx < start + M)
    moved = jnp.take(new, src, axis=1)
    mask = mask.reshape((1, V) + (1,) * (old.ndim - 2))
    return jnp.where(mask, moved.astype(old.dtype), old)


def attention_suffix_prefill(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,                       # (1, M, d) — the unshared suffix
    cache: Dict[str, jax.Array],        # slot view, prefix rows resident
    start: jax.Array,                   # scalar int32: first suffix row
    *,
    impl: Optional[str] = None,
    kv_dtype: str = "bfloat16",
    plan: Optional[LaunchPlan] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill only rows [start, start + M) against an already-resident
    prefix (prefix sharing): the view's rows [0, start) hold the adopted
    pages' K/V, the suffix queries attend over prefix + themselves via
    the causal ``q_offset`` mask, and the fresh K/V is placed into the
    view in cache layout.  Rows past ``start + M`` are garbage from the
    slot's unwritten tail — their key positions exceed every query
    position, so the same mask discards them.

    ``start`` is traced (one compiled step serves every split of a
    bucket pair), which the pallas/seqpar paths cannot consume — they
    specialize on a static ``q_offset`` — so those impls drop to the XLA
    flash reference here.
    """
    B, M, _ = x.shape
    assert B == 1, "suffix prefill is a batch-1 admission step"
    if impl is None and plan is not None:
        impl = plan.impl
    impl = impl or cfg.attention_impl
    if impl in ("pallas", "seqpar"):
        impl = "xla"
    positions = start + jnp.arange(M)[None, :]
    q, k, v = _project_qkv(params, cfg, x, positions)

    if kv_dtype in QUANT_DTYPES:
        qz = Quantizer.from_kv_dtype(kv_dtype)
        kq, ks = qz.quantize(k)
        vq, vs = qz.quantize(v)
        cache = {"k": _place_rows(cache["k"], kq, start),
                 "v": _place_rows(cache["v"], vq, start),
                 "k_s": _place_rows(cache["k_s"], ks, start),
                 "v_s": _place_rows(cache["v_s"], vs, start)}
        kf = qz.dequantize(cache["k"], cache["k_s"])
        vf = qz.dequantize(cache["v"], cache["v_s"])
    else:
        cache = {"k": _place_rows(cache["k"], k, start),
                 "v": _place_rows(cache["v"], v, start)}
        kf, vf = cache["k"], cache["v"]
    out = ops.attention(q, kf.astype(q.dtype), vf.astype(q.dtype),
                        causal=True, q_offset=start, impl=impl)
    y = jnp.einsum("blhk,hkd->bld", out, params["wo"])
    return y, cache


def cross_attention_train(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,                       # (B, Lq, d) decoder stream
    memory: jax.Array,                  # (B, Lk, d) encoder output
    *,
    impl: Optional[str] = None,
) -> jax.Array:
    """Encoder-decoder cross attention (no mask, no rope)."""
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"])
    k = jnp.einsum("bld,dhk->blhk", memory, params["wk"])
    v = jnp.einsum("bld,dhk->blhk", memory, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    out = ops.attention(q, k, v, causal=False,
                        impl=impl or cfg.attention_impl)
    return jnp.einsum("blhk,hkd->bld", out, params["wo"])


def precompute_cross_kv(params: Params, cfg: ModelConfig,
                        memory: jax.Array) -> Dict[str, jax.Array]:
    """Project encoder output to K/V once per request (decode fast path)."""
    k = jnp.einsum("bld,dhk->blhk", memory, params["wk"])
    v = jnp.einsum("bld,dhk->blhk", memory, params["wv"])
    if cfg.qkv_bias:
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    return {"k": k, "v": v}


def cross_attention_decode(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,                       # (B, 1, d)
    cross_cache: Dict[str, jax.Array],  # precomputed k/v (B, Lk, Hkv, D)
    *,
    plan: Optional[LaunchPlan] = None,
    impl: Optional[str] = None,
) -> jax.Array:
    """Decode-time cross attention against a FIXED-length memory.

    L_K is the encoder length (Whisper: 1500 frames -> nblk = 12) — decode
    against it is exactly the paper's shape family, so it routes through
    the same split policy.
    """
    B = x.shape[0]
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"])
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
    Lk = cross_cache["k"].shape[1]
    kv_len = jnp.full((B,), Lk, jnp.int32)
    # encoder length != decoder cache length: any plan frozen for the
    # SELF-attention shape (explicit or ambient) must not apply — keep
    # only the policy/num_cores overrides
    if plan is not None and plan.frozen:
        plan = plan.context_only()
    out = ops.decode_attention(
        q[:, 0], cross_cache["k"], cross_cache["v"], kv_len,
        plan=plan, use_ctx_metadata=False,
        impl=impl or cfg.attention_impl)
    return jnp.einsum("bhk,hkd->bd", out, params["wo"])[:, None]


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Deprecated: materialize via ``repro.cache`` (``Model.init_cache``
    for the dense arrays, or a ``CacheManager`` for layout choice)."""
    import warnings
    warnings.warn(
        "attention.init_kv_cache is deprecated; go through repro.cache "
        "(Model.init_cache / Model.cache_manager)",
        DeprecationWarning, stacklevel=2)
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.num_kv_heads, hd)
    if dtype in ("int8", jnp.int8):
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(shape[:3], jnp.float32),
                "v_s": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                   dtype: str = "bfloat16") -> Dict[str, ParamSpec]:
    """KV cache layout.  A quantized ``dtype`` ("int8" | "fp8") stores
    the data leaves in the :class:`~repro.quant.QuantSpec` storage dtype
    plus per-(token, head) symmetric scales — halving (or better) the
    decode step's dominant memory term (§Perf C.4).

    Leaves are marked ``paged=True``: self-attention K/V (and its
    quantization scales) is position-linear, so the ``repro.cache``
    paged layout may store it as pages when the seq axis spans the full
    slot capacity — one page table serves data and scale pools alike.
    """
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.num_kv_heads, hd)
    axes = ("batch", "seq", "kv_heads", "head_dim")
    if dtype in QUANT_DTYPES:
        storage = QUANT_DTYPES[dtype].storage
        sspec = ParamSpec(shape[:3], axes[:3], dtype="float32",
                          init="zeros", paged=True)
        return {"k": ParamSpec(shape, axes, dtype=storage, init="zeros",
                               paged=True),
                "v": ParamSpec(shape, axes, dtype=storage, init="zeros",
                               paged=True),
                "k_s": sspec, "v_s": sspec}
    return {"k": ParamSpec(shape, axes, dtype=dtype, init="zeros",
                           paged=True),
            "v": ParamSpec(shape, axes, dtype=dtype, init="zeros",
                           paged=True)}


# int8 per-(token, head) transforms, kept as module-level functions for
# the many existing call sites; they delegate to the repro.quant default
# resolver (numerics pinned bit-identical by tests/test_quant.py).
_INT8 = Quantizer()


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-(..., head) int8 over the feature dim.
    x: (..., H, D) -> (q int8 same shape, scale f32 (..., H))."""
    return _INT8.quantize(x)


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return _INT8.dequantize(q, scale)


def cache_update(cache: Dict[str, jax.Array], k_new: jax.Array,
                 v_new: jax.Array, t: jax.Array) -> Dict[str, jax.Array]:
    """Write one token's K/V at position t.

    ``t``: scalar (lockstep decode) or (B,) (continuous batching — each
    slot at its own position).
    """
    B = k_new.shape[0]
    tv = jnp.broadcast_to(t.astype(jnp.int32), (B,))

    def upd(c, new, ti):
        return jax.lax.dynamic_update_slice(
            c, new[None].astype(c.dtype),
            (ti, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)))

    return {
        "k": jax.vmap(upd)(cache["k"], k_new, tv),
        "v": jax.vmap(upd)(cache["v"], v_new, tv),
    }


def attention_decode(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,                       # (B, 1, d) — the new token
    cache: Dict[str, jax.Array],
    t: jax.Array,                       # scalar int32: current position
    *,
    plan: Optional[LaunchPlan] = None,
    window: Optional[int] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step. Returns (output (B,1,d), updated cache).

    ``t``: scalar or (B,) — position of each sequence's new token.
    """
    B = x.shape[0]
    tv = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    positions = tv[:, None]
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    cache_len = cache["k"].shape[1]
    # windowed layers attend over the ring cache — a different L_K than
    # the full-cache shape any frozen plan (explicit or ambient scope)
    # describes, so the frozen decision is dropped here (policy overrides
    # survive) rather than trusting every call site to know that
    use_ctx_md = window is None
    if window is not None and plan is not None and plan.frozen:
        plan = plan.context_only()
    if window is not None:
        # local attention: ring-buffer cache sized to the window.  RoPE is
        # applied at absolute positions before the write, so slot order is
        # irrelevant — every resident entry is attendable (all are past).
        write_t = jnp.mod(tv, jnp.int32(cache_len))
        kv_len = jnp.minimum(tv + 1, jnp.int32(cache_len))
    else:
        write_t = tv
        kv_len = tv + 1
    if "k_s" in cache:                      # quantized KV cache (§Perf C.4)
        # checked BEFORE the pallas branch: a raw cache_update would cast
        # bf16 rows straight into the storage dtype (garbage without the
        # scales).  impl="pallas" here means the fused in-register-
        # dequant kernel, not the bf16 one.
        qz = Quantizer.for_cache(cache)
        kq, kns = qz.quantize(k_new[:, 0])
        vq, vns = qz.quantize(v_new[:, 0])
        out, ck, cv, ks, vs = ops.decode_attention_update(
            q[:, 0], cache["k"], cache["v"], kq, vq, write_t, kv_len,
            plan=plan, use_ctx_metadata=use_ctx_md,
            impl=impl or cfg.attention_impl,
            quant={"k_s": cache["k_s"], "v_s": cache["v_s"],
                   "k_ns": kns, "v_ns": vns})
        cache = {"k": ck, "v": cv, "k_s": ks, "v_s": vs}
    elif (impl or cfg.attention_impl) == "pallas":
        cache = cache_update(cache, k_new[:, 0], v_new[:, 0], write_t)
        out = ops.decode_attention(
            q[:, 0], cache["k"], cache["v"], kv_len,
            plan=plan, use_ctx_metadata=use_ctx_md, impl="pallas")
    else:
        out, ck, cv = ops.decode_attention_update(
            q[:, 0], cache["k"], cache["v"], k_new[:, 0], v_new[:, 0],
            write_t, kv_len, plan=plan, use_ctx_metadata=use_ctx_md)
        cache = {"k": ck, "v": cv}
    y = jnp.einsum("bhk,hkd->bd", out, params["wo"])
    return y[:, None], cache


def _place_rows_at(old: jax.Array, new: jax.Array,
                   start: jax.Array) -> jax.Array:
    """Per-slot variant of :func:`_place_rows`: write ``new`` (B, M, ...)
    into ``old`` (B, V, ...) at PER-SLOT row offsets ``start`` (B,).
    Lockstep verify batches place each slot's k+1 fresh K/V rows at that
    slot's own position, so the offset is a vector, not a scalar."""
    B, V = old.shape[:2]
    M = new.shape[1]
    idx = jnp.arange(V)[None, :]                         # (1, V)
    st = start.astype(jnp.int32)[:, None]                # (B, 1)
    src = jnp.clip(idx - st, 0, M - 1)                   # (B, V)
    mask = (idx >= st) & (idx < st + M)
    src = src.reshape((B, V) + (1,) * (old.ndim - 2))
    moved = jnp.take_along_axis(new, src, axis=1)
    mask = mask.reshape((B, V) + (1,) * (old.ndim - 2))
    return jnp.where(mask, moved.astype(old.dtype), old)


def attention_verify(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,                       # (B, M, d) — current token + drafts
    cache: Dict[str, jax.Array],
    t: jax.Array,                       # (B,) int32: each slot's position
    *,
    plan: Optional[LaunchPlan] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Speculative-verify attention step: score an ``M = k + 1``-row
    query block per slot in one planned launch.

    Rows land in the cache at [t, t + M) via masked per-slot placement
    (the k-row analogue of the suffix-prefill write); queries attend
    causal-within-block at the slot's absolute offset through
    :func:`ops.verify_attention`, which consumes the frozen
    ``("verify", k, bucket)`` plan.  The caller commits only accepted
    rows (paged write-back masks pages past the accept point; dense
    rollback is the host-side ``kv_len`` truncate) — rejected rows stay
    as garbage above ``kv_len``, the repo-wide masking invariant.
    """
    B, M, _ = x.shape
    tv = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    positions = tv[:, None] + jnp.arange(M, dtype=jnp.int32)[None, :]
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)

    if "k_s" in cache:                      # quantized KV cache
        qz = Quantizer.for_cache(cache)
        kq, ks = qz.quantize(k_new)
        vq, vs = qz.quantize(v_new)
        cache = {"k": _place_rows_at(cache["k"], kq, tv),
                 "v": _place_rows_at(cache["v"], vq, tv),
                 "k_s": _place_rows_at(cache["k_s"], ks, tv),
                 "v_s": _place_rows_at(cache["v_s"], vs, tv)}
        kf = qz.dequantize(cache["k"], cache["k_s"])
        vf = qz.dequantize(cache["v"], cache["v_s"])
    else:
        cache = {"k": _place_rows_at(cache["k"], k_new, tv),
                 "v": _place_rows_at(cache["v"], v_new, tv)}
        kf, vf = cache["k"], cache["v"]
    out = ops.verify_attention(q, kf.astype(q.dtype), vf.astype(q.dtype),
                               tv, plan=plan,
                               impl=impl or cfg.attention_impl)
    y = jnp.einsum("bmhk,hkd->bmd", out, params["wo"])
    return y, cache
