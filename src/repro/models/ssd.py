"""Mamba-2 block: State-Space Duality (SSD), chunked matmul form.

Training/prefill run the chunked SSD algorithm (arXiv:2405.21060 §6):
within a chunk the recurrence is expanded into attention-like matmuls
(MXU-friendly); across chunks a short ``lax.scan`` carries the (H, P, N)
state.  Decode is the pure recurrence — one state update per token, no
attention, no KV cache.

The paper's split technique is **inapplicable** here (attention-free;
DESIGN.md §5): decode parallelism comes from sharding the (B, H) state
grid over the mesh instead.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, rms_norm

Params = Dict[str, jax.Array]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.ngroups * s.state_dim
    return d_inner, nheads, conv_dim


def ssd_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, conv_dim = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * s.ngroups * s.state_dim + nheads
    return {
        "in_proj": ParamSpec((d, d_in_proj), ("embed", "state")),
        "conv_w": ParamSpec((s.conv_width, conv_dim), (None, "state"),
                            fan_in=s.conv_width),
        "conv_b": ParamSpec((conv_dim,), ("state",), init="zeros"),
        "A_log": ParamSpec((nheads,), ("heads",), init="zeros"),
        "D": ParamSpec((nheads,), ("heads",), init="ones"),
        "dt_bias": ParamSpec((nheads,), ("heads",), init="zeros"),
        "norm": ParamSpec((d_inner,), ("state",), init="ones"),
        "out_proj": ParamSpec((d_inner, d), ("state", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    d_inner, nheads, _ = _dims(cfg)
    gn = s.ngroups * s.state_dim
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * gn]
    dt = zxbcdt[..., -nheads:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along L. xbc: (B, L, C), w: (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(W):                        # static small loop (W = 4)
        out = out + pad[:, i:i + xbc.shape[1]].astype(jnp.float32) \
            * w[W - 1 - i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise sums: out[..., i, j] = sum a[j+1..i]."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jax.Array,        # (B, L, H, P) — already dt-weighted NOT; raw
    dt: jax.Array,       # (B, L, H) — post-softplus
    A: jax.Array,        # (H,) negative
    B_: jax.Array,       # (B, L, G, N)
    C_: jax.Array,       # (B, L, G, N)
    *,
    chunk: int,
    init_state: jax.Array | None = None,   # (B, H, P, N)
    unroll_chunks: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    Bb, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    assert L % chunk == 0, f"pad L={L} to chunk={chunk}"
    nc = L // chunk
    rep = H // G

    xf = x.astype(jnp.float32).reshape(Bb, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bb, nc, chunk, H)
    Bf = B_.astype(jnp.float32).reshape(Bb, nc, chunk, G, N)
    Cf = C_.astype(jnp.float32).reshape(Bb, nc, chunk, G, N)
    # broadcast groups to heads
    Bh = jnp.repeat(Bf, rep, axis=3)                   # (B,nc,q,H,N)
    Ch = jnp.repeat(Cf, rep, axis=3)

    a = dtf * A[None, None, None, :]                   # (B,nc,q,H) log-decay
    a = a.transpose(0, 3, 1, 2)                        # (B,H,nc,q)
    a_cs = jnp.cumsum(a, axis=-1)

    xdt = xf * dtf[..., None]                          # (B,nc,q,H,P)

    # 1. intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(a))                         # (B,H,nc,q,q)
    scores = jnp.einsum("bcqhn,bcshn->bhcqs", Ch, Bh)
    y_diag = jnp.einsum("bhcqs,bhcqs,bcshp->bcqhp",
                        scores, Lmat, xdt)

    # 2. chunk states: decay each position to the end of its chunk
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)      # (B,H,nc,q)
    states = jnp.einsum("bcshn,bhcs,bcshp->bchpn", Bh, decay_states, xdt)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(a_cs[..., -1])               # (B,H,nc)
    s0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st_c, dec_c = inp                              # (B,H,P,N), (B,H)
        new = carry * dec_c[..., None, None] + st_c
        return new, carry                              # emit state *before*

    (final_state, prev_states) = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4),              # (nc,B,H,P,N)
         chunk_decay.transpose(2, 0, 1)),              # (nc,B,H)
        unroll=unroll_chunks)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # 4. state -> output within each chunk
    state_decay_out = jnp.exp(a_cs)                    # (B,H,nc,q)
    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp",
                       Ch, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(Bb, L, H, P)
    return y.astype(x.dtype), final_state


def _tail_rows(x: jax.Array, n: int) -> jax.Array:
    """Last n rows along axis 1, zero-padded at the FRONT if L < n."""
    L = x.shape[1]
    if L >= n:
        return x[:, L - n:]
    return jnp.pad(x, ((0, 0), (n - L, 0), (0, 0)))


def apply_ssd_train(params: Params, cfg: ModelConfig, x: jax.Array,
                    *, init_state: jax.Array | None = None,
                    return_state: bool = False,
                    return_cache: bool = False):
    """Full Mamba-2 block over (B, L, d). Returns y (or (y, state/cache))."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    gn = s.ngroups * s.state_dim

    zxbcdt = x @ params["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc_raw = xbc
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs = xbc[..., :d_inner]
    B_ = xbc[..., d_inner:d_inner + gn].reshape(*x.shape[:2], s.ngroups,
                                                s.state_dim)
    C_ = xbc[..., d_inner + gn:].reshape(*x.shape[:2], s.ngroups,
                                         s.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    L = x.shape[1]
    chunk = min(s.chunk_size, L)
    pad = (-L) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xh = xs.reshape(*xs.shape[:2], nheads, s.head_dim)
    y, state = ssd_chunked(xh, dt, A, B_, C_, chunk=chunk,
                           init_state=init_state,
                           unroll_chunks=cfg.probe_unroll)
    y = y[:, :L].reshape(x.shape[0], L, d_inner)
    y = y + xs[:, :L] * params["D"].astype(jnp.float32).repeat(s.head_dim)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm"], cfg.norm_eps)
    out = y.astype(x.dtype) @ params["out_proj"]
    if return_cache:
        conv_cache = _tail_rows(xbc_raw, s.conv_width - 1)
        return out, {"state": state,
                     "conv": conv_cache.astype(cfg.dtype)}
    if return_state:
        return out, state
    return out


# --- decode ------------------------------------------------------------------


def init_ssd_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32
                   ) -> Dict[str, jax.Array]:
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    return {
        "state": jnp.zeros((batch, nheads, s.head_dim, s.state_dim),
                           jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
    }


def ssd_cache_specs(cfg: ModelConfig, batch: int,
                    dtype: str = "bfloat16") -> Dict[str, ParamSpec]:
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    return {
        "state": ParamSpec((batch, nheads, s.head_dim, s.state_dim),
                           ("batch", "heads", "head_dim", None),
                           dtype="float32", init="zeros"),
        "conv": ParamSpec((batch, s.conv_width - 1, conv_dim),
                          ("batch", None, "state"), dtype=dtype,
                          init="zeros"),
    }


def apply_ssd_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                     cache: Dict[str, jax.Array]
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token Mamba-2 step. x: (B, 1, d) -> (y (B,1,d), cache)."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    gn = s.ngroups * s.state_dim

    zxbcdt = x[:, 0] @ params["in_proj"]               # (B, ·)
    z, xbc, dt = _split_proj(cfg, zxbcdt)

    # rolling conv buffer: (B, W-1, conv_dim) holds the previous inputs.
    # conv_in is time-ordered oldest..newest; _causal_conv pairs w[0] with
    # the CURRENT input, so flip the taps here to match.
    conv_in = jnp.concatenate(
        [cache["conv"], xbc[:, None].astype(cache["conv"].dtype)], axis=1)
    w = params["conv_w"].astype(jnp.float32)[::-1]     # (W, C)
    conv_out = jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32), w)
    xbc_c = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    new_conv = conv_in[:, 1:]

    xs = xbc_c[..., :d_inner]
    B_ = xbc_c[..., d_inner:d_inner + gn].reshape(-1, s.ngroups, s.state_dim)
    C_ = xbc_c[..., d_inner + gn:].reshape(-1, s.ngroups, s.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    rep = nheads // s.ngroups
    Bh = jnp.repeat(B_, rep, axis=1)                   # (B,H,N)
    Ch = jnp.repeat(C_, rep, axis=1)
    xh = xs.reshape(-1, nheads, s.head_dim)            # (B,H,P)

    decay = jnp.exp(dt * A[None, :])                   # (B,H)
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + xh * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(-1, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)),
                 params["norm"], cfg.norm_eps)
    out = (y @ params["out_proj"].astype(y.dtype)).astype(x.dtype)
    return out[:, None], {"state": state, "conv": new_conv}
