"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 family).

MLA compresses K/V into a single shared latent stream per layer:

- train/prefill: queries come from a low-rank down+up projection
  (``q_lora``); keys/values are reconstructed from the compressed latent
  ``c_kv`` (rank ``kv_lora_rank``) plus a *shared* RoPE key of dim
  ``qk_rope_head_dim``.
- decode: the cache stores only ``c_kv`` and ``k_rope`` — effectively
  **H_KV = 1**.  This is the most extreme low-head-count regime the paper
  targets: every decode step is one work tile per sequence, so the split
  policy (and the mesh-level sequence split) is load-bearing here.

Decode uses the *absorbed* formulation: ``W_uk`` is folded into the query
and ``W_uv`` into the output projection, so attention runs directly in
latent space against the (B, L, kv_lora+rope) cache with Hkv=1 — the
shape the split policy sees.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops, ref
from repro.plan import LaunchPlan
from repro.models.common import ParamSpec, apply_rope, rms_norm
from repro.sharding.ctx import shard_activation

Params = Dict[str, jax.Array]


def mla_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    """MLA params.

    LAYOUT NOTE (§Perf hillclimb A): the latent ranks are deliberately
    NOT TP-sharded.  Sharding them makes every up-projection a partial
    sum, and when the head count doesn't divide the model axis (MiniCPM3:
    40 heads on 16) GSPMD resolves those partials *inside* attention —
    all-reducing score-sized tensors (measured 860 s/step of modeled
    collective time at prefill_32k).  Replicating the tiny latent ranks
    (~3M params) moves the resolution to one (B, L, r) all-reduce.
    """
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dqk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "q_down": ParamSpec((d, m.q_lora_rank), ("embed", None)),
        "q_norm": ParamSpec((m.q_lora_rank,), (None,), init="ones"),
        "q_up": ParamSpec((m.q_lora_rank, h, dqk),
                          (None, "heads", "head_dim"),
                          fan_in=m.q_lora_rank),
        # kv down-projection: latent + shared rope key, one matmul
        "kv_down": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                             ("embed", None)),
        "kv_norm": ParamSpec((m.kv_lora_rank,), (None,), init="ones"),
        # up-projections from the latent: k_nope and v per head
        "k_up": ParamSpec((m.kv_lora_rank, h, m.qk_nope_head_dim),
                          (None, "heads", "head_dim"),
                          fan_in=m.kv_lora_rank),
        "v_up": ParamSpec((m.kv_lora_rank, h, m.v_head_dim),
                          (None, "heads", "head_dim"),
                          fan_in=m.kv_lora_rank),
        "wo": ParamSpec((h, m.v_head_dim, d), ("heads", "head_dim", "embed"),
                        fan_in=h * m.v_head_dim),
    }


def _latents(params: Params, cfg: ModelConfig, x: jax.Array,
             positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (B,L,d) -> (c_kv (B,L,r) normalized, k_rope (B,L,dr) rotated)."""
    m = cfg.mla
    kv = x @ params["kv_down"]                                   # (B,L,r+dr)
    # resolve the FSDP partial sum HERE, on the narrow latent (see
    # mla_specs layout note) — not inside attention
    kv = shard_activation(kv, ("batch", None, None))
    c_kv = rms_norm(kv[..., :m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]                  # shared head
    return c_kv, k_rope


def _queries(params: Params, cfg: ModelConfig, x: jax.Array,
             positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (q_nope (B,L,H,dn), q_rope (B,L,H,dr))."""
    m = cfg.mla
    ql = shard_activation(x @ params["q_down"], ("batch", None, None))
    ql = rms_norm(ql, params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("blr,rhk->blhk", ql, params["q_up"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_train(params: Params, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array, *, impl: Optional[str] = None
              ) -> jax.Array:
    """Full-sequence MLA (training/prefill): reconstruct K/V, run flash."""
    m = cfg.mla
    q_nope, q_rope = _queries(params, cfg, x, positions)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    k_nope = jnp.einsum("blr,rhk->blhk", c_kv, params["k_up"])
    v = jnp.einsum("blr,rhk->blhk", c_kv, params["v_up"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)               # (B,L,H,dqk)
    B, L, H, _ = q.shape
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (B, L, H, m.qk_rope_head_dim))], axis=-1)
    out = ops.attention(q, k, v, causal=True,
                        impl=impl or cfg.attention_impl)         # (B,L,H,dv)
    return jnp.einsum("blhk,hkd->bld", out, params["wo"])


def mla_prefill(params: Params, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array, cache_len: int,
                *, impl: Optional[str] = None,
                plan: Optional[LaunchPlan] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence MLA that also emits the latent decode cache.

    A prefill-kind ``plan`` selects the impl (fused-admission path);
    the latent cache layout is identical either way."""
    m = cfg.mla
    if impl is None and plan is not None:
        impl = plan.impl
    y = mla_train(params, cfg, x, positions, impl=impl)
    c_kv, k_rope = _latents(params, cfg, x, positions)
    entries = jnp.concatenate([c_kv, k_rope], axis=-1)   # (B, L, w)
    B, L, w = entries.shape
    pad = cache_len - L
    assert pad >= 0, f"prompt ({L}) exceeds cache ({cache_len})"
    lat = jnp.pad(entries, ((0, 0), (0, pad), (0, 0)))[:, :, None]
    return y, {"latent": lat.astype(cfg.dtype)}


# --- decode: absorbed latent-space attention (Hkv = 1) ----------------------


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Deprecated: materialize via ``repro.cache`` (``Model.init_cache``
    for the dense arrays, or a ``CacheManager`` for layout choice)."""
    import warnings
    warnings.warn(
        "mla.init_mla_cache is deprecated; go through repro.cache "
        "(Model.init_cache / Model.cache_manager)",
        DeprecationWarning, stacklevel=2)
    m = cfg.mla
    width = m.kv_lora_rank + m.qk_rope_head_dim
    return {"latent": jnp.zeros((batch, max_len, 1, width), dtype)}


def mla_cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                    dtype: str = "bfloat16") -> Dict[str, ParamSpec]:
    """Latent cache layout (position-linear -> pageable, like any
    self-attention K/V — one shared H_KV=1 stream)."""
    m = cfg.mla
    width = m.kv_lora_rank + m.qk_rope_head_dim
    return {"latent": ParamSpec((batch, max_len, 1, width),
                                ("batch", "seq", "kv_heads", "head_dim"),
                                dtype=dtype, init="zeros", paged=True)}


def mla_decode(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,                       # (B, 1, d)
    cache: Dict[str, jax.Array],
    t: jax.Array,
    *,
    plan: Optional[LaunchPlan] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    m = cfg.mla
    B = x.shape[0]
    tv = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    positions = tv[:, None]
    q_nope, q_rope = _queries(params, cfg, x, positions)         # (B,1,H,·)
    c_kv, k_rope = _latents(params, cfg, x, positions)           # (B,1,·)

    new_entry = jnp.concatenate([c_kv, k_rope], axis=-1)         # (B,1,w)

    # absorb W_uk into q: score = q_nope·(c W_uk) = (q_nope W_uk^T)·c
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], params["k_up"])
    q_cat = jnp.concatenate([q_lat, q_rope[:, 0]], axis=-1)      # (B,H,w)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    kv_len = tv + 1
    # latent attention: k = full latent entries, v = c_kv part only.
    # Hkv = 1 (shared latent stream) — the paper's most extreme case.
    out_lat, lat, _ = ops.decode_attention_update(
        q_cat * scale, cache["latent"], None,
        new_entry[:, 0, None, :], None, tv, kv_len,
        v_width=m.kv_lora_rank, scale=1.0, plan=plan)            # (B,H,r)
    cache = {"latent": lat}
    out = jnp.einsum("bhr,rhk->bhk", out_lat, params["v_up"])    # absorb W_uv
    y = jnp.einsum("bhk,hkd->bd", out, params["wo"])
    return y[:, None], cache
