"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch.

Two dispatch implementations, A/B-able (the MoE hillclimb cell in §Perf
swaps them and measures the HLO-FLOP delta):

- ``einsum``  — classic Switch/Mesh-TF one-hot dispatch+combine einsums.
  Simple, robustly shardable, but spends O(S·E·C·d) FLOPs moving tokens.
- ``gather``  — sort-free gather/scatter dispatch: token→slot indices are
  computed with cumulative one-hot ranks, tokens move via ``take`` /
  ``segment-style`` scatter-add. Near-zero dispatch FLOPs; this is the
  beyond-paper optimized path.

Routing is per *group* (a contiguous slab of tokens, default one batch
row) so dispatch never crosses the data-parallel shard boundary: groups
ride the batch axis, experts ride the model axis (expert parallelism
folded into TP, per DESIGN.md).

Load-balance aux loss (Switch-style) is returned alongside the output.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec

Params = Dict[str, jax.Array]


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    assert cfg.moe is not None
    d, e, f = cfg.d_model, cfg.moe.num_experts, cfg.moe.d_expert
    return {
        "router": ParamSpec((d, e), ("embed", "experts"), dtype="float32"),
        "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "ff"),
                             fan_in=d),
        "wi_up": ParamSpec((e, d, f), ("experts", "embed", "ff"), fan_in=d),
        "wo": ParamSpec((e, f, d), ("experts", "ff", "embed"), fan_in=f),
    }


def _capacity(group_size: int, num_experts: int, top_k: int,
              capacity_factor: float) -> int:
    c = math.ceil(capacity_factor * group_size * top_k / num_experts)
    return max(1, c)


def _route(params: Params, cfg: ModelConfig, x: jax.Array
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (G, S, d) -> (gates (G,S,k), experts (G,S,k) int32, aux loss)."""
    moe = cfg.moe
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                        params["router"])                        # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, moe.top_k)             # (G,S,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch load-balance loss: E * mean_e(frac_tokens_e * mean_prob_e)
    sel = jax.nn.one_hot(experts[..., 0], moe.num_experts)       # top-1 frac
    frac = sel.mean(axis=(0, 1))
    mean_p = probs.mean(axis=(0, 1))
    aux = moe.num_experts * jnp.sum(frac * mean_p)
    return gates, experts, aux


def _expert_ffn(params: Params, xin: jax.Array) -> jax.Array:
    """xin: (..., E, C, d) -> (..., E, C, d) through per-expert SwiGLU."""
    g = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", xin, params["wi_gate"]))
    u = jnp.einsum("...ecd,edf->...ecf", xin, params["wi_up"])
    return jnp.einsum("...ecf,efd->...ecd", g * u, params["wo"])


# ---------------------------------------------------------------------------
# einsum dispatch (baseline)
# ---------------------------------------------------------------------------


def _positions_in_expert(experts: jax.Array, num_experts: int
                         ) -> Tuple[jax.Array, jax.Array]:
    """Queue position of each (token, k) routing decision within its expert.

    k-major priority: all top-1 choices get queue slots before any top-2
    choice, so capacity overflow drops the least-confident assignments.
    Returns (onehot (G,S,k,E) int32, pos (G,S,k) int32).
    """
    G, S, K = experts.shape
    onehot = jax.nn.one_hot(experts, num_experts, dtype=jnp.int32)
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, K * S, num_experts)
    pos_flat = jnp.cumsum(flat, axis=1) - 1                      # (G,kS,E)
    pos = pos_flat.reshape(G, K, S, num_experts).transpose(0, 2, 1, 3)
    pos = (pos * onehot).sum(-1)                                 # (G,S,k)
    return onehot, pos


def _moe_einsum(params: Params, cfg: ModelConfig, x: jax.Array,
                capacity: int) -> Tuple[jax.Array, jax.Array]:
    """x: (G, S, d). Returns (out (G,S,d), aux).

    The k axis is a *static Python loop* (k <= 8): materializing the
    (G,S,k,E,C) product would be ~TBs at full scale; per-k (G,S,E,C)
    dispatch tensors are transient and fuse into their einsums.
    """
    moe = cfg.moe
    G, S, d = x.shape
    E, C = moe.num_experts, capacity
    gates, experts, aux = _route(params, cfg, x)
    onehot, pos = _positions_in_expert(experts, E)
    keep = pos < C

    xin = jnp.zeros((G, E, C, d), x.dtype)
    disp_ks = []
    for ki in range(moe.top_k):
        disp_k = (onehot[:, :, ki].astype(x.dtype)[..., None]
                  * jax.nn.one_hot(jnp.where(keep[:, :, ki], pos[:, :, ki], 0),
                                   C, dtype=x.dtype)[:, :, None, :]
                  * keep[:, :, ki, None, None].astype(x.dtype))  # (G,S,E,C)
        disp_ks.append(disp_k)
        xin = xin + jnp.einsum("gsec,gsd->gecd", disp_k, x)
    xout = _expert_ffn(params, xin)
    out = jnp.zeros_like(x)
    for ki in range(moe.top_k):
        comb_k = disp_ks[ki] * gates[:, :, ki, None, None].astype(x.dtype)
        out = out + jnp.einsum("gsec,gecd->gsd", comb_k, xout)
    return out, aux


# ---------------------------------------------------------------------------
# gather dispatch (optimized: no O(S·E·C·d) one-hot matmuls)
# ---------------------------------------------------------------------------


def _moe_gather(params: Params, cfg: ModelConfig, x: jax.Array,
                capacity: int) -> Tuple[jax.Array, jax.Array]:
    moe = cfg.moe
    G, S, d = x.shape
    E, C, K = moe.num_experts, capacity, moe.top_k
    gates, experts, aux = _route(params, cfg, x)
    _, pos = _positions_in_expert(experts, E)
    keep = pos < C
    slot = experts * C + jnp.where(keep, pos, C)                 # (G,S,k)
    slot = jnp.where(keep, slot, E * C)                          # overflow slot

    def per_group(xg, slotg, gateg):
        # xg (S,d), slotg/gateg (S,k)
        src = jnp.repeat(jnp.arange(S), K)                       # (S*k,)
        flat_slot = slotg.reshape(-1)                            # (S*k,)
        buf = jnp.zeros((E * C + 1, d), xg.dtype)
        buf = buf.at[flat_slot].set(xg[src], mode="drop")        # dispatch
        xin = buf[:E * C].reshape(E, C, d)
        xout = _expert_ffn(params, xin).reshape(E * C, d)
        xout = jnp.concatenate([xout, jnp.zeros((1, d), xout.dtype)])
        picked = xout[flat_slot].reshape(S, K, d)                # combine
        return (picked * gateg[..., None].astype(xg.dtype)).sum(1)

    out = jax.vmap(per_group)(x, slot, gates)
    return out, aux


# ---------------------------------------------------------------------------
# shard_map expert parallelism (optimized: explicit all_to_all routing)
# ---------------------------------------------------------------------------


def _moe_ep_shard_map(params: Params, cfg: ModelConfig, x: jax.Array,
                      mesh, axis: str = "model"
                      ) -> Tuple[jax.Array, jax.Array]:
    """GShard-style EP: tokens split over the model axis, experts live
    sharded, two all_to_alls move only the routed tokens.

    The §Perf hillclimb B path: the auto-SPMD gather dispatch replicates
    its scatter buffers over the mesh (176 s/step of modeled collective
    time on qwen3-moe train_4k); here the wire carries exactly
    2 x (E, C_local, d) per layer plus the output all-gather.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    moe = cfg.moe
    B, L, d = x.shape
    n = mesh.shape[axis]
    E, K = moe.num_experts, moe.top_k
    e_pad = -(-E // n) * n                 # pad experts to the axis (40->48)

    def padded(w):
        if e_pad == E:
            return w
        return jnp.pad(w, ((0, e_pad - E),) + ((0, 0),) * (w.ndim - 1))

    router = jnp.pad(params["router"], ((0, 0), (0, e_pad - E)),
                     constant_values=-1e9) if e_pad != E else params["router"]
    wi_g, wi_u, wo = (padded(params["wi_gate"]), padded(params["wi_up"]),
                      padded(params["wo"]))

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = data_axes if (data_axes and B % _prodi(
        mesh.shape[a] for a in data_axes) == 0) else None

    s_loc = (B if bspec is None else B // _prodi(
        mesh.shape[a] for a in data_axes)) * (L // n)
    cap = _capacity(s_loc, e_pad, K, moe.capacity_factor)

    def body(xb, rtr, wg, wu, wo_):
        # xb: (B_loc, L/n, d); experts for THIS device: e_pad/n.
        # Expert weights arrive in their stored FSDP layout (d sharded on
        # the data axes) and are gathered HERE — handing GSPMD a
        # replicated in_spec instead makes it rematerialize the FULL
        # expert stack per device (63.8 TB/step of all-gather, measured).
        if data_axes:
            wg = jax.lax.all_gather(wg, data_axes, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, data_axes, axis=1, tiled=True)
            wo_ = jax.lax.all_gather(wo_, data_axes, axis=2, tiled=True)
        Bl, Ll, _ = xb.shape
        S = Bl * Ll
        xt = xb.reshape(S, d)
        logits = (xt.astype(jnp.float32) @ rtr)         # (S, e_pad)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, experts = jax.lax.top_k(probs, K)        # (S, K)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        # aux loss (local estimate, pmean'd below)
        sel = jax.nn.one_hot(experts[..., 0], e_pad)
        aux = e_pad * jnp.sum(sel.mean(0) * probs.mean(0))
        aux = jax.lax.pmean(jax.lax.pmean(aux, axis),
                            data_axes) if data_axes else \
            jax.lax.pmean(aux, axis)

        # queue positions (k-major priority), slot = e * cap + pos
        onehot = jax.nn.one_hot(experts, e_pad, dtype=jnp.int32)  # (S,K,E)
        flat = onehot.transpose(1, 0, 2).reshape(K * S, e_pad)
        pos = (jnp.cumsum(flat, axis=0) - 1).reshape(K, S, e_pad)
        pos = (pos.transpose(1, 0, 2) * onehot).sum(-1)  # (S,K)
        keep = pos < cap
        slot = jnp.where(keep, experts * cap + pos, e_pad * cap)

        src = jnp.repeat(jnp.arange(S), K)
        buf = jnp.zeros((e_pad * cap + 1, d), xt.dtype)
        buf = buf.at[slot.reshape(-1)].set(xt[src], mode="drop")
        buf = buf[:-1].reshape(e_pad, cap, d)

        # ship token slabs to their expert owners
        recv = jax.lax.all_to_all(buf, axis, split_axis=0,
                                  concat_axis=1, tiled=True)
        # recv: (e_pad/n, n*cap, d) — this device's experts, all peers
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, wg))
        u = jnp.einsum("ecd,edf->ecf", recv, wu)
        yout = jnp.einsum("ecf,efd->ecd", g * u, wo_)
        back = jax.lax.all_to_all(yout, axis, split_axis=1,
                                  concat_axis=0, tiled=True)
        back = back.reshape(e_pad * cap, d)
        back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)])

        picked = back[slot.reshape(-1)].reshape(S, K, d)
        out = (picked * gates[..., None].astype(xt.dtype)).sum(1)
        return out.reshape(Bl, Ll, d), aux

    dspec = data_axes if data_axes else None
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, axis, None),
                  P(None, None),        # router: replicated (routing is global)
                  P(axis, dspec, None),  # stored FSDP layout (see body)
                  P(axis, dspec, None),
                  P(axis, None, dspec)),
        out_specs=(P(bspec, axis, None), P()),
        check_rep=False)
    return fn(x, router, wi_g, wi_u, wo)


def _prodi(it) -> int:
    r = 1
    for v in it:
        r *= v
    return r


def apply_moe(params: Params, cfg: ModelConfig, x: jax.Array,
              *, dispatch: str | None = None, group_size: int = 0
              ) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN over (B, L, d) activations. Returns (out, aux_loss).

    Groups are (B, L) rows by default (group = one sequence), keeping
    routing local to the data shard.  ``dispatch`` defaults to the config's
    choice (production default: ``ep_shard_map`` when a mesh context with
    a non-trivial model axis is active, else ``gather``).
    """
    B, L, d = x.shape
    moe = cfg.moe
    if dispatch is None:
        dispatch = getattr(moe, "dispatch", "gather")
    if dispatch == "ep_shard_map":
        from repro.sharding.ctx import current_mesh
        mesh = current_mesh()
        if (mesh is not None and "model" in mesh.axis_names
                and mesh.shape["model"] > 1 and L % mesh.shape["model"] == 0):
            return _moe_ep_shard_map(params, cfg, x, mesh)
        dispatch = "gather"                 # single-device fallback
    if group_size and group_size < L:
        ng = L // group_size
        xg = x.reshape(B * ng, group_size, d)
    else:
        group_size = L
        xg = x.reshape(B, L, d)
    cap = _capacity(group_size, moe.num_experts, moe.top_k,
                    moe.capacity_factor)
    fn = _moe_gather if dispatch == "gather" else _moe_einsum
    out, aux = fn(params, cfg, xg, cap)
    return out.reshape(B, L, d), aux
