"""Whisper-style encoder-decoder (audio backbone; conv frontend is a stub).

``input_specs()`` supplies precomputed frame embeddings (B, 1500, d_model)
— the mel-spectrogram conv stem is out of scope per the assignment.  The
encoder adds fixed sinusoidal positions and runs bidirectional attention;
the decoder runs causal self-attention + cross-attention to the encoder
output.  Both decode-time attentions (growing self cache, fixed 1500-frame
cross cache) route through the paper's split policy.

Norms are LayerNorm (scale+bias) and MLPs are plain GELU, per Whisper.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.sharding.ctx import shard_activation
from repro.models.common import (
    ParamSpec,
    apply_mlp,
    apply_norm,
    embed_specs,
    embed_tokens,
    mlp_specs,
    norm_specs,
    sinusoidal_positions,
    stack_specs,
    unembed,
)

Pytree = Any


def _enc_block_specs(cfg: ModelConfig) -> Dict[str, Pytree]:
    d = cfg.d_model
    return {
        "ln1": norm_specs(d, "layer"),
        "self": attn_mod.attention_specs(cfg),
        "ln2": norm_specs(d, "layer"),
        "ffn": mlp_specs(d, cfg.d_ff, cfg.mlp_kind),
    }


def _dec_block_specs(cfg: ModelConfig) -> Dict[str, Pytree]:
    d = cfg.d_model
    return {
        "ln1": norm_specs(d, "layer"),
        "self": attn_mod.attention_specs(cfg),
        "lnx": norm_specs(d, "layer"),
        "cross": attn_mod.attention_specs(cfg),
        "ln2": norm_specs(d, "layer"),
        "ffn": mlp_specs(d, cfg.d_ff, cfg.mlp_kind),
    }


def encdec_param_specs(cfg: ModelConfig) -> Dict[str, Pytree]:
    return {
        "embed": embed_specs(cfg.vocab_size, cfg.d_model,
                             cfg.tie_embeddings),
        "pos_dec": ParamSpec((_max_dec_positions(cfg), cfg.d_model),
                             ("seq", "embed")),
        "enc_layers": stack_specs(_enc_block_specs(cfg),
                                  cfg.num_encoder_layers),
        "enc_norm": norm_specs(cfg.d_model, "layer"),
        "dec_layers": stack_specs(_dec_block_specs(cfg), cfg.num_layers),
        "final_norm": norm_specs(cfg.d_model, "layer"),
    }


# The decoder's learned positions table is bounded; whisper uses 448, we
# size it to the largest assigned decode shape (decode_32k).
def _max_dec_positions(cfg: ModelConfig) -> int:
    return min(cfg.max_seq_len, 32_768)


def encode(params: Pytree, cfg: ModelConfig, frames: jax.Array
           ) -> jax.Array:
    """frames: (B, T, d_model) stub embeddings -> encoder output."""
    B, T, d = frames.shape
    pos = sinusoidal_positions(T, d).astype(frames.dtype)
    x = shard_activation(frames + pos[None], ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(xc, lp):
        xc = shard_activation(xc, ("batch", None, None))
        h = apply_norm(lp["ln1"], xc, cfg.norm_eps)
        q = jnp.einsum("bld,dhk->blhk", h, lp["self"]["wq"])
        k = jnp.einsum("bld,dhk->blhk", h, lp["self"]["wk"])
        v = jnp.einsum("bld,dhk->blhk", h, lp["self"]["wv"])
        from repro.kernels import ops
        o = ops.attention(q, k, v, causal=False, impl=cfg.attention_impl)
        xc = xc + jnp.einsum("blhk,hkd->bld", o, lp["self"]["wo"])
        h2 = apply_norm(lp["ln2"], xc, cfg.norm_eps)
        xc = xc + apply_mlp(lp["ffn"], h2, cfg.mlp_kind)
        return xc, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for r in range(cfg.num_encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[r],
                                        params["enc_layers"]))
    return apply_norm(params["enc_norm"], x, cfg.norm_eps)


def decoder_forward(params: Pytree, cfg: ModelConfig, tokens: jax.Array,
                    memory: jax.Array) -> jax.Array:
    """Teacher-forced decoder. -> logits (B, L, vocab) f32."""
    B, L = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["pos_dec"], 0, L, axis=0).astype(x.dtype)[None]
    x = shard_activation(x, ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    def body(xc, lp):
        xc = shard_activation(xc, ("batch", None, None))
        h = apply_norm(lp["ln1"], xc, cfg.norm_eps)
        xc = xc + attn_mod.attention_train(lp["self"], cfg, h, positions)
        hx = apply_norm(lp["lnx"], xc, cfg.norm_eps)
        xc = xc + attn_mod.cross_attention_train(lp["cross"], cfg, hx,
                                                 memory)
        h2 = apply_norm(lp["ln2"], xc, cfg.norm_eps)
        xc = xc + apply_mlp(lp["ffn"], h2, cfg.mlp_kind)
        return xc, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
    else:
        for r in range(cfg.num_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[r],
                                        params["dec_layers"]))
    x = apply_norm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x)


def encdec_forward(params: Pytree, cfg: ModelConfig, tokens: jax.Array,
                   frames: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Full teacher-forced pass. -> (logits, aux=0)."""
    memory = encode(params, cfg, frames)
    logits = decoder_forward(params, cfg, tokens, memory)
    return logits, jnp.zeros((), jnp.float32)


def encdec_prefill(params: Pytree, cfg: ModelConfig, tokens: jax.Array,
                   frames: jax.Array, max_len: int
                   ) -> Tuple[jax.Array, Pytree]:
    """Encode + teacher-forced decoder prefill emitting decode caches.

    -> (last-position logits (B, vocab), stacked {"self", "cross"} caches).
    """
    memory = encode(params, cfg, frames)
    B, L = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["pos_dec"], 0, L, axis=0).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    def body(xc, lp):
        xc = shard_activation(xc, ("batch", None, None))
        h = apply_norm(lp["ln1"], xc, cfg.norm_eps)
        mix, self_cache = attn_mod.attention_prefill(
            lp["self"], cfg, h, positions, max_len)
        xc = xc + mix
        hx = apply_norm(lp["lnx"], xc, cfg.norm_eps)
        xc = xc + attn_mod.cross_attention_train(lp["cross"], cfg, hx,
                                                 memory)
        h2 = apply_norm(lp["ln2"], xc, cfg.norm_eps)
        xc = xc + apply_mlp(lp["ffn"], h2, cfg.mlp_kind)
        cross_cache = attn_mod.precompute_cross_kv(lp["cross"], cfg, memory)
        return xc, {"self": self_cache, "cross": cross_cache}

    if cfg.scan_layers:
        x, caches = jax.lax.scan(body, x, params["dec_layers"])
    else:
        outs = []
        for r in range(cfg.num_layers):
            x, c = body(x, jax.tree.map(lambda a: a[r],
                                        params["dec_layers"]))
            outs.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    x = apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, -1:])[:, 0]
    return logits, caches


# --- fused single-slot prefill (serving admission) ---------------------------


def encdec_prefill_view(
    params: Pytree,
    cfg: ModelConfig,
    caches: Pytree,                     # stacked {"self": .., "cross": ..}
    tokens: jax.Array,                  # (Lb,) int32 — bucket-padded prompt
    slot: jax.Array,                    # scalar int32
    length: jax.Array,                  # scalar int32 — true prompt length
    view_len: int,                      # seq extent of the emitted self cache
    *,
    plan=None,
) -> Tuple[jax.Array, Pytree]:
    """Decoder prefill of one prompt, emitting a batch-1 cache VIEW.

    Cross-attention reads the slot's *resident* precomputed cross K/V
    (zeros on a fresh engine, real encoder output after
    :func:`build_cross_caches`) — the same memory the decode step
    consumes, so prefill-then-decode matches decode-all-the-way.
    Returns (last-prompt-position logits (vocab,), batch-1
    ``{"self", "cross"}`` view) — ``self`` is freshly computed at seq
    extent ``view_len``; ``cross`` is the slot's resident column, passed
    back so a layout write of the full view is a no-op on it.
    """
    from repro.kernels import ops

    L = tokens.shape[0]
    slot = jnp.asarray(slot, jnp.int32)
    x = embed_tokens(params["embed"], tokens[None])      # (1, Lb, d)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["pos_dec"], 0, L, axis=0).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (1, L))
    cross_sl = jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
        caches["cross"])
    impl = (plan.impl if plan is not None and plan.impl is not None
            else cfg.attention_impl)

    def body(xc, scanned):
        lp, cc = scanned                # cc: this layer's (1, enc, H, D) kv
        h = apply_norm(lp["ln1"], xc, cfg.norm_eps)
        mix, self_cache = attn_mod.attention_prefill(
            lp["self"], cfg, h, positions, view_len, plan=plan)
        xc = xc + mix
        hx = apply_norm(lp["lnx"], xc, cfg.norm_eps)
        q = jnp.einsum("bld,dhk->blhk", hx, lp["cross"]["wq"])
        if cfg.qkv_bias:
            q = q + lp["cross"]["bq"].astype(q.dtype)
        o = ops.attention(q, cc["k"], cc["v"], causal=False, impl=impl)
        xc = xc + jnp.einsum("blhk,hkd->bld", o, lp["cross"]["wo"])
        h2 = apply_norm(lp["ln2"], xc, cfg.norm_eps)
        xc = xc + apply_mlp(lp["ffn"], h2, cfg.mlp_kind)
        return xc, self_cache

    if cfg.scan_layers:
        x, self_caches = jax.lax.scan(body, x,
                                      (params["dec_layers"], cross_sl))
    else:
        outs = []
        for r in range(cfg.num_layers):
            x, c = body(x, jax.tree.map(lambda a: a[r],
                                        (params["dec_layers"], cross_sl)))
            outs.append(c)
        self_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    xl = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
    xl = apply_norm(params["final_norm"], xl, cfg.norm_eps)
    logits = unembed(params["embed"], xl)[0, 0]
    return logits, {"self": self_caches, "cross": cross_sl}


def encdec_prefill_slot(
    params: Pytree,
    cfg: ModelConfig,
    caches: Pytree,                     # stacked {"self": .., "cross": ..}
    tokens: jax.Array,                  # (Lb,) int32 — bucket-padded prompt
    slot: jax.Array,                    # scalar int32
    length: jax.Array,                  # scalar int32 — true prompt length
    max_len: int,
    *,
    plan=None,
) -> Tuple[jax.Array, Pytree]:
    """Decoder prefill of one prompt into slot ``slot``'s DENSE self
    cache (see :func:`encdec_prefill_view` for the layout-agnostic
    half).  Returns (last-prompt-position logits (vocab,), caches)."""
    from repro.models.lm import write_cache_slot

    slot = jnp.asarray(slot, jnp.int32)
    logits, view = encdec_prefill_view(params, cfg, caches, tokens, slot,
                                       length, max_len, plan=plan)
    return logits, {"self": write_cache_slot(caches["self"], view["self"],
                                             slot),
                    "cross": caches["cross"]}


# --- decode ------------------------------------------------------------------


def encdec_cache_specs(cfg: ModelConfig, batch: int, max_len: int
                       ) -> Dict[str, Pytree]:
    hd = cfg.resolved_head_dim
    self_specs = attn_mod.kv_cache_specs(cfg, batch, max_len)
    cross_shape = (batch, cfg.encoder_positions, cfg.num_kv_heads, hd)
    cross_axes = ("batch", "seq", "kv_heads", "head_dim")
    # cross K/V is position-COMPLETE (decode reads the full encoder
    # length every step), so repro.cache must never page it — pin
    # paged=False rather than rely on encoder_positions != max_len
    per_layer = {
        "self": self_specs,
        "cross": {"k": ParamSpec(cross_shape, cross_axes, init="zeros",
                                 paged=False),
                  "v": ParamSpec(cross_shape, cross_axes, init="zeros",
                                 paged=False)},
    }
    return stack_specs(per_layer, cfg.num_layers)


def build_cross_caches(params: Pytree, cfg: ModelConfig,
                       memory: jax.Array) -> Pytree:
    """Precompute per-layer cross K/V from the encoder output (stacked)."""
    def one(lp):
        return attn_mod.precompute_cross_kv(lp["cross"], cfg, memory)
    return jax.vmap(one)(params["dec_layers"])


def encdec_decode_step(
    params: Pytree,
    cfg: ModelConfig,
    caches: Pytree,                     # stacked {"self": .., "cross": ..}
    token: jax.Array,                   # (B,)
    t: jax.Array,
    *,
    plan=None,                          # frozen plan for SELF-attention
) -> Tuple[jax.Array, Pytree]:
    B = token.shape[0]
    tv = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    x = embed_tokens(params["embed"], token[:, None])
    pos_row = jnp.take(params["pos_dec"], tv, axis=0)    # (B, d)
    x = x + pos_row.astype(x.dtype)[:, None]

    def body(xc, scanned):
        lp, lc = scanned
        xc = shard_activation(xc, ("batch", None, None))
        h = apply_norm(lp["ln1"], xc, cfg.norm_eps)
        mix, new_self = attn_mod.attention_decode(
            lp["self"], cfg, h, lc["self"], t, plan=plan)
        xc = xc + mix
        hx = apply_norm(lp["lnx"], xc, cfg.norm_eps)
        # cross-attention decodes against a FIXED encoder length — a
        # different workload shape, so the self-attn plan does not apply
        # (cross_attention_decode keeps only the policy overrides)
        xc = xc + attn_mod.cross_attention_decode(
            lp["cross"], cfg, hx, lc["cross"], plan=plan)
        h2 = apply_norm(lp["ln2"], xc, cfg.norm_eps)
        xc = xc + apply_mlp(lp["ffn"], h2, cfg.mlp_kind)
        return xc, {"self": new_self, "cross": lc["cross"]}

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x,
                                     (params["dec_layers"], caches))
    else:
        outs = []
        for r in range(cfg.num_layers):
            x, c = body(x, jax.tree.map(lambda a: a[r],
                                        (params["dec_layers"], caches)))
            outs.append(c)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    x = apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]
    return logits, new_caches
