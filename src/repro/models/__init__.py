"""Model zoo: all assigned architecture families on one spec-first API."""
from repro.models.registry import Model, build_model  # noqa: F401
