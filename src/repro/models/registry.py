"""Unified model facade: one object per architecture family.

``build_model(cfg)`` returns a :class:`Model` exposing the same API for
every family (dense / mla / moe / ssm / hybrid / vlm / encdec):

- ``param_specs()`` / ``abstract_params()`` / ``init_params(rng)``
- ``cache_specs(batch, max_len)`` / ``init_cache(batch, max_len)``
- ``forward(params, batch)``        -> (logits, aux_loss)
- ``decode_step(params, caches, token, t)`` -> (logits, new_caches)

``batch`` is a dict: ``tokens`` always; ``patches`` (vlm) or ``frames``
(audio) when the frontend stub applies.  The dry-run, train step, serve
step, tests and benchmarks all go through this facade.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_arch
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.models.common import abstract_params, init_params, logical_axes

Pytree = Any


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # --- params -------------------------------------------------------------

    def param_specs(self) -> Pytree:
        if self.cfg.family == "encdec":
            return encdec_mod.encdec_param_specs(self.cfg)
        return lm_mod.lm_param_specs(self.cfg)

    def abstract_params(self) -> Pytree:
        return abstract_params(self.param_specs())

    def init_params(self, rng: jax.Array) -> Pytree:
        return init_params(self.param_specs(), rng)

    def param_axes(self) -> Pytree:
        return logical_axes(self.param_specs())

    # --- caches ---------------------------------------------------------------

    def cache_specs(self, batch: int, max_len: int,
                    kv_dtype: str = "bfloat16") -> Pytree:
        if self.cfg.family == "encdec":
            return encdec_mod.encdec_cache_specs(self.cfg, batch, max_len)
        return lm_mod.lm_cache_specs(self.cfg, batch, max_len, kv_dtype)

    def abstract_cache(self, batch: int, max_len: int,
                       kv_dtype: str = "bfloat16") -> Pytree:
        return abstract_params(self.cache_specs(batch, max_len, kv_dtype))

    def cache_axes(self, batch: int, max_len: int,
                   kv_dtype: str = "bfloat16") -> Pytree:
        return logical_axes(self.cache_specs(batch, max_len, kv_dtype))

    @property
    def supports_paged_cache(self) -> bool:
        """Whether the ``repro.cache`` paged layout may hold this
        family's caches.  Requires position-linear cache semantics
        (row ``t`` holds position ``t``): recurrent families (ssm /
        hybrid) carry per-token state / ring-ordered window caches whose
        meaning depends on the STORAGE length, so they stay dense."""
        return self.cfg.family in ("dense", "moe", "mla", "vlm", "encdec")

    @property
    def supports_prefix_sharing(self) -> bool:
        """Whether ``share_prefix`` may index this family's pages.
        Needs paged (position-linear) caches, the fused admission path
        (suffix prefill is its restartable form), AND a uniform
        full-attention stack whose per-layer cache is the standard
        k/v dict the suffix placement path writes — which excludes
        mla's split latent/rope caches (paged-compatible, but not yet
        covered by :func:`repro.models.lm.block_suffix_prefill`) and
        encdec's cross-attention column; recurrent families fail the
        paged gate outright."""
        return (self.supports_paged_cache and self.supports_fused_prefill
                and self.cfg.family in ("dense", "moe"))

    def cache_spec(self, batch: int, max_len: int,
                   kv_dtype: str = "bfloat16", *, layout: str = "dense",
                   page_size: int = 64,
                   page_budget: Optional[int] = None,
                   share_prefix: bool = False,
                   prefix_capacity: Optional[int] = None):
        """The declarative :class:`~repro.cache.CacheSpec` for this
        model's caches — the input the :class:`~repro.cache.CacheManager`
        resolves into a layout."""
        from repro.cache import CacheSpec
        if layout == "paged" and not self.supports_paged_cache:
            raise ValueError(
                f"{self.cfg.family} caches are not position-linear "
                "(recurrent state / ring buffers); use layout='dense'")
        if share_prefix and not self.supports_prefix_sharing:
            raise ValueError(
                f"{self.cfg.family} models cannot share prefix pages "
                "(needs paged caches, fused prefill, and a uniform "
                "full-attention stack)")
        return CacheSpec(self.cfg.family, batch, max_len,
                         kv_dtype=kv_dtype, layout=layout,
                         page_size=page_size, page_budget=page_budget,
                         share_prefix=share_prefix,
                         prefix_capacity=prefix_capacity)

    def cache_manager(self, batch: int, max_len: int,
                      kv_dtype: str = "bfloat16", label: str = "",
                      **layout_kw):
        """Resolve a cache spec into a :class:`~repro.cache.CacheManager`
        (the storage-owning entry point; models no longer hand out raw
        arrays — see the README migration map).  ``label`` tags the
        manager for observability — the mesh-native engine passes
        ``shard{d}`` so conservation failures name the owning shard."""
        from repro.cache import CacheManager
        return CacheManager(self, self.cache_spec(batch, max_len,
                                                  kv_dtype, **layout_kw),
                            label=label)

    def init_cache(self, batch: int, max_len: int,
                   kv_dtype: str = "bfloat16") -> Pytree:
        """Dense-layout cache arrays (legacy surface, kept bit-identical:
        delegates to ``repro.cache.DenseLayout``; new code should hold a
        :meth:`cache_manager` instead)."""
        return self.cache_manager(batch, max_len, kv_dtype).init_storage()

    # --- compute --------------------------------------------------------------

    def forward(self, params: Pytree, batch: Dict[str, jax.Array],
                *, block_wrapper=None) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec_mod.encdec_forward(params, cfg, batch["tokens"],
                                             batch["frames"])
        return lm_mod.lm_forward(params, cfg, batch["tokens"],
                                 patches=batch.get("patches"),
                                 block_wrapper=block_wrapper)

    def prefill(self, params: Pytree, batch: Dict[str, jax.Array],
                max_len: int, kv_dtype: str = "bfloat16"
                ) -> Tuple[jax.Array, Pytree]:
        """Forward + decode-cache construction in one pass.

        -> (last-position logits (B, vocab), caches ready for
        ``decode_step`` at t = L_total).
        """
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec_mod.encdec_prefill(params, cfg, batch["tokens"],
                                             batch["frames"], max_len)
        return lm_mod.lm_prefill(params, cfg, batch["tokens"], max_len,
                                 patches=batch.get("patches"),
                                 kv_dtype=kv_dtype)

    @property
    def supports_fused_prefill(self) -> bool:
        """Whether :meth:`prefill_slot` can consume a bucket-padded
        prompt.  Recurrent families (ssm / hybrid) would fold the pad
        garbage into their carried state, and a vision frontend prepends
        non-token positions; both keep the teacher-forcing admission
        path instead."""
        cfg = self.cfg
        if cfg.family == "encdec":
            return True                # decoder-side prefill, stub frames
        return (cfg.family in ("dense", "moe", "mla")
                and cfg.frontend.kind == "none")

    @property
    def prefill_writes_full_slot(self) -> bool:
        """Whether :meth:`prefill_slot` overwrites EVERY cache leaf row
        of the target slot (lm families emit full ``max_len``-length
        caches), letting the serving engine skip its slot-reset launch
        at fused admission.  encdec leaves the cross-cache leaves
        untouched, so its slots still need the reset."""
        return self.cfg.family != "encdec"

    def prefill_slot(self, params: Pytree, caches: Pytree,
                     tokens: jax.Array, slot: jax.Array,
                     length: jax.Array, max_len: int, *, plan=None,
                     kv_dtype: str = "bfloat16"
                     ) -> Tuple[jax.Array, Pytree]:
        """Fused single-slot prompt prefill into an existing cache.

        ``tokens``: (Lb,) bucket-padded prompt; ``slot`` / ``length``:
        traced scalars.  One launch writes the whole prompt's cache rows
        for slot ``slot`` and returns the logits at position
        ``length - 1`` (ready to sample the first generated token) —
        the serving engine's O(1)-launches admission path.
        """
        cfg = self.cfg
        if not self.supports_fused_prefill:
            raise NotImplementedError(
                f"{cfg.family} models cannot fused-prefill a padded "
                "prompt; use the loop (teacher-forcing) admission path")
        if cfg.family == "encdec":
            return encdec_mod.encdec_prefill_slot(
                params, cfg, caches, tokens, slot, length, max_len,
                plan=plan)
        return lm_mod.lm_prefill_slot(params, cfg, caches, tokens, slot,
                                      length, max_len, plan=plan,
                                      kv_dtype=kv_dtype)

    def prefill_slot_view(self, params: Pytree, caches: Pytree,
                          tokens: jax.Array, slot: jax.Array,
                          length: jax.Array, view_len: int, *, plan=None,
                          kv_dtype: str = "bfloat16"
                          ) -> Tuple[jax.Array, Pytree]:
        """Layout-agnostic half of :meth:`prefill_slot`: compute one
        prompt's batch-1 cache VIEW (seq extent ``view_len``) without
        writing it anywhere — the cache layout decides where it lands
        (dense ``write_cache_slot`` vs the paged layout's page-table
        scatter).  ``caches`` is only read where a family's prefill
        consumes resident state (encdec: the slot's cross K/V column).
        """
        cfg = self.cfg
        if not self.supports_fused_prefill:
            raise NotImplementedError(
                f"{cfg.family} models cannot fused-prefill a padded "
                "prompt; use the loop (teacher-forcing) admission path")
        if cfg.family == "encdec":
            return encdec_mod.encdec_prefill_view(
                params, cfg, caches, tokens, slot, length, view_len,
                plan=plan)
        return lm_mod.lm_prefill_view(params, cfg, tokens, length,
                                      view_len, plan=plan,
                                      kv_dtype=kv_dtype)

    def prefill_suffix_view(self, params: Pytree, caches: Pytree,
                            tokens: jax.Array, start: jax.Array,
                            length: jax.Array, *, plan=None,
                            kv_dtype: str = "bfloat16"
                            ) -> Tuple[jax.Array, Pytree]:
        """Suffix-only admission prefill over a batch-1 cache view whose
        rows [0, start) already hold a shared prefix's K/V (prefix
        sharing).  ``tokens``: (Mb,) bucket-padded UNSHARED suffix;
        ``start`` / ``length``: traced scalars (first suffix row /
        total prompt length).  Returns (logits at prompt row
        ``length - 1``, the updated views) — the paged layout scatters
        them back through the slot's page table exactly like
        :meth:`prefill_slot_view` output."""
        if not self.supports_prefix_sharing:
            raise NotImplementedError(
                f"{self.cfg.family} models cannot suffix-prefill a "
                "shared prefix; admit with the full prefill path")
        return lm_mod.lm_prefill_suffix_view(
            params, self.cfg, caches, tokens, start, length, plan=plan,
            kv_dtype=kv_dtype)

    def decode_step(self, params: Pytree, caches: Pytree, token: jax.Array,
                    t: jax.Array, *, plan=None, metadata=None,
                    policy: str = "paper",
                    num_cores: Optional[int] = None
                    ) -> Tuple[jax.Array, Pytree]:
        """One decode step.

        ``plan``: a :class:`~repro.plan.LaunchPlan` (static Python value,
        NOT a traced array).  When frozen, every attention layer launches
        from it and the split policy is never evaluated inside this
        function — callers jitting this must specialize on the plan
        (close over it / static argnum).  A context-only plan (or the
        legacy ``metadata`` / ``policy`` / ``num_cores`` kwargs, kept as
        a migration shim) selects the internal-heuristic path with those
        overrides.
        """
        cfg = self.cfg
        if plan is None:
            if metadata is not None:
                plan = metadata
            elif policy != "paper" or num_cores is not None:
                from repro.plan import LaunchPlan
                plan = LaunchPlan(kind="decode", policy=policy,
                                  num_cores=num_cores)
        if cfg.family == "encdec":
            return encdec_mod.encdec_decode_step(
                params, cfg, caches, token, t, plan=plan)
        return lm_mod.lm_decode_step(params, cfg, caches, token, t,
                                     plan=plan)

    @property
    def supports_speculation(self) -> bool:
        """Whether the engine may run speculative verify steps
        (``SamplingParams.speculation``) against this family.  Like the
        prefix-sharing gate: needs a uniform full-attention stack whose
        per-layer cache is the standard k/v dict the multi-row verify
        placement writes, and whose rejected rows roll back by
        truncating ``kv_len`` — recurrent families (ssm / hybrid) carry
        per-token state a rollback cannot rewind, windowed ring caches
        lose overwritten rows, encdec adds the cross column, and mla's
        split latent caches aren't covered by
        :func:`repro.models.lm.block_verify` yet."""
        return (self.cfg.family in ("dense", "moe")
                and self.cfg.frontend.kind == "none")

    def verify_step(self, params: Pytree, caches: Pytree,
                    tokens: jax.Array, t: jax.Array, *, plan=None
                    ) -> Tuple[jax.Array, Pytree]:
        """Speculative verify: score an (B, M = k + 1)-token block per
        slot — each slot's committed current token plus its k draft
        tokens at positions [t, t + M) — in ONE planned launch.

        Returns (logits (B, M, vocab) f32, updated caches): logits row
        ``j`` is the next-token distribution after feeding rows
        [0, j], the teacher-forced scores that batched accept/reject
        (``Sampler.verify``) consumes.  ``plan`` is the frozen
        ``("verify", k, bucket)`` :class:`~repro.plan.LaunchPlan`.
        """
        if not self.supports_speculation:
            raise NotImplementedError(
                f"{self.cfg.family} models cannot run speculative verify "
                "steps (needs a uniform full-attention stack with "
                "truncation-rollbackable caches)")
        return lm_mod.lm_verify_step(params, self.cfg, caches, tokens, t,
                                     plan=plan)

    # --- frontend stubs ---------------------------------------------------------

    def frontend_inputs(self, batch: int, seq_len: int
                        ) -> Dict[str, Tuple[Tuple[int, ...], str]]:
        """Extra (non-token) inputs: name -> (shape, dtype)."""
        cfg = self.cfg
        if cfg.frontend.kind == "vision":
            return {"patches": ((batch, cfg.frontend.num_positions,
                                 cfg.frontend.embed_dim), cfg.dtype)}
        if cfg.family == "encdec":
            return {"frames": ((batch, cfg.encoder_positions, cfg.d_model),
                               cfg.dtype)}
        return {}

    def text_len(self, seq_len: int) -> int:
        """Token count for a total sequence budget (vlm reserves patches)."""
        if self.cfg.frontend.kind == "vision":
            return max(1, seq_len - self.cfg.frontend.num_positions)
        return seq_len


def build_model(cfg_or_name: ModelConfig | str) -> Model:
    cfg = (get_arch(cfg_or_name) if isinstance(cfg_or_name, str)
           else cfg_or_name)
    return Model(cfg)
