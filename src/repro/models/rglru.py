"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

Temporal-mixing half of a Griffin block:

    branch_a = conv1d(W_in_a @ x)  -> RG-LRU linear recurrence
    branch_b = gelu(W_in_b @ x)
    out      = W_out @ (branch_a * branch_b)

RG-LRU: ``h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)`` with
``a_t = exp(-c softplus(Lambda) r_t)`` — a *linear* recurrence in h, so
training uses ``jax.lax.associative_scan`` (log-depth, MXU-free but
parallel) and decode is a single fused elementwise update.

Attention layers of the hybrid use ``models.attention`` with a local
window — those are where the paper's split policy applies (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec

Params = Dict[str, jax.Array]
_C = 8.0                               # RG-LRU decay sharpness constant


def _width(cfg: ModelConfig) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


def rglru_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, w = cfg.d_model, _width(cfg)
    cw = cfg.hybrid.conv_width
    return {
        "w_in_a": ParamSpec((d, w), ("embed", "state")),
        "w_in_b": ParamSpec((d, w), ("embed", "state")),
        "conv_w": ParamSpec((cw, w), (None, "state"), fan_in=cw),
        "conv_b": ParamSpec((w,), ("state",), init="zeros"),
        "lam": ParamSpec((w,), ("state",), init="ones"),     # Lambda
        "w_gate_i": ParamSpec((w, w), ("state", None)),      # input gate
        "b_gate_i": ParamSpec((w,), ("state",), init="zeros"),
        "w_gate_r": ParamSpec((w, w), ("state", None)),      # recurrence gate
        "b_gate_r": ParamSpec((w,), ("state",), init="zeros"),
        "w_out": ParamSpec((w, d), ("state", "embed")),
    }


def _gates(params: Params, xa: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (log_a, gated_in), both f32, shapes of xa."""
    xf = xa.astype(jnp.float32)
    i_t = jax.nn.sigmoid(xf @ params["w_gate_i"].astype(jnp.float32)
                         + params["b_gate_i"].astype(jnp.float32))
    r_t = jax.nn.sigmoid(xf @ params["w_gate_r"].astype(jnp.float32)
                         + params["b_gate_r"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r_t
    return log_a, i_t * xf


def _conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Causal depthwise conv along L. x: (B, L, W)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, jnp.float32)
    for i in range(W):
        out = out + pad[:, i:i + x.shape[1]].astype(jnp.float32) \
            * w[W - 1 - i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def apply_rglru_train(params: Params, cfg: ModelConfig, x: jax.Array,
                      *, init_state: jax.Array | None = None,
                      return_state: bool = False,
                      return_cache: bool = False):
    """x: (B, L, d) -> (B, L, d) through the full recurrent block."""
    xa_lin = x @ params["w_in_a"]
    xa = _conv(xa_lin, params["conv_w"], params["conv_b"])
    xb = jax.nn.gelu((x @ params["w_in_b"]).astype(jnp.float32))

    log_a, bt = _gates(params, xa)                     # (B,L,w) f32
    a = jnp.exp(log_a)
    bt = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * bt

    if init_state is not None:
        # fold h_0 into the first step: b_1 += a_1 * h_0
        bt = bt.at[:, 0].add(a[:, 0] * init_state.astype(jnp.float32))

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, bt), axis=1)

    y = (h * xb).astype(x.dtype)
    out = y @ params["w_out"]
    if return_cache:
        W = cfg.hybrid.conv_width
        L = x.shape[1]
        if L >= W - 1:
            conv_cache = xa_lin[:, L - (W - 1):]
        else:
            conv_cache = jnp.pad(xa_lin, ((0, 0), (W - 1 - L, 0), (0, 0)))
        return out, {"state": h[:, -1],
                     "conv": conv_cache.astype(cfg.dtype)}
    if return_state:
        return out, h[:, -1]                           # (B, w) f32
    return out


# --- decode ------------------------------------------------------------------


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16
                     ) -> Dict[str, jax.Array]:
    w = _width(cfg)
    cw = cfg.hybrid.conv_width
    return {"state": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, w), dtype)}


def rglru_cache_specs(cfg: ModelConfig, batch: int,
                      dtype: str = "bfloat16") -> Dict[str, ParamSpec]:
    w = _width(cfg)
    cw = cfg.hybrid.conv_width
    return {"state": ParamSpec((batch, w), ("batch", "state"),
                               dtype="float32", init="zeros"),
            "conv": ParamSpec((batch, cw - 1, w), ("batch", None, "state"),
                              dtype=dtype, init="zeros")}


def apply_rglru_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                       cache: Dict[str, jax.Array]
                       ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token step. x: (B, 1, d)."""
    xa_lin = x[:, 0] @ params["w_in_a"]                # (B, w)
    # time-ordered buffer oldest..newest; flip taps to match _conv, which
    # pairs w[0] with the current input.
    conv_in = jnp.concatenate(
        [cache["conv"], xa_lin[:, None].astype(cache["conv"].dtype)], axis=1)
    wconv = params["conv_w"].astype(jnp.float32)[::-1]
    xa = jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32), wconv) \
        + params["conv_b"].astype(jnp.float32)
    xb = jax.nn.gelu((x[:, 0] @ params["w_in_b"]).astype(jnp.float32))

    log_a, bt = _gates(params, xa)
    a = jnp.exp(log_a)
    bt = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * bt
    h = a * cache["state"] + bt

    y = (h * xb).astype(x.dtype)
    out = y @ params["w_out"]
    return out[:, None], {"state": h, "conv": conv_in[:, 1:]}
