"""Decoder-only LM assembly: scan-over-layers, all decoder families.

Families covered here: ``dense`` (GQA/MQA/MHA), ``mla`` (MiniCPM3),
``moe`` (attention + MoE FFN), ``ssm`` (Mamba-2), ``hybrid``
(RecurrentGemma RG-LRU/local-attn pattern), ``vlm`` (PaliGemma: projected
patch prefix + gemma backbone).  ``encdec`` (Whisper) lives in
``encdec.py`` and reuses the same blocks.

Layers are grouped into **scan groups**: a (pattern, repeats) pair whose
parameters are stacked along a leading ``layers`` dim and executed with
``jax.lax.scan`` — one HLO block body regardless of depth (94-layer MoE
compiles as fast as a 2-layer one; remat applies to the body).  Uniform
families have one group ``((kind,), L)``; RecurrentGemma has
``((rglru, rglru, attn), 12)`` plus a remainder group.

Decode steps carry a cache pytree with the *same group structure* as the
params, so a single scan walks (layer_params, layer_cache) together.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssd as ssd_mod
from repro.models.common import (
    ParamSpec,
    apply_mlp,
    apply_norm,
    embed_specs,
    embed_tokens,
    mlp_specs,
    norm_specs,
    stack_specs,
    unembed,
)
from repro.sharding.ctx import shard_activation

_ACT = ("batch", None, None)             # (B, L, d) layout anchor

Pytree = Any


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------


def layer_kinds(cfg: ModelConfig) -> List[str]:
    if cfg.family == "hybrid":
        p = cfg.hybrid.pattern
        return [("attn_window" if p[i % len(p)] == "attn" else p[i % len(p)])
                for i in range(cfg.num_layers)]
    if cfg.family == "ssm":
        return ["ssd"] * cfg.num_layers
    if cfg.family == "mla":
        return ["mla"] * cfg.num_layers
    return ["attn"] * cfg.num_layers      # dense / moe / vlm


def layer_groups(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    """-> [(pattern, repeats), ...] covering all layers in order."""
    kinds = layer_kinds(cfg)
    if cfg.family == "hybrid":
        p = tuple("attn_window" if k == "attn" else k
                  for k in cfg.hybrid.pattern)
        n_full, rem = divmod(cfg.num_layers, len(p))
        groups: List[Tuple[Tuple[str, ...], int]] = []
        if n_full:
            groups.append((p, n_full))
        if rem:
            groups.append((p[:rem], 1))
        return groups
    return [((kinds[0],), cfg.num_layers)]


# ---------------------------------------------------------------------------
# Per-block specs / apply
# ---------------------------------------------------------------------------


def _has_mlp(cfg: ModelConfig, kind: str) -> bool:
    return kind != "ssd"


def block_specs(cfg: ModelConfig, kind: str) -> Dict[str, Pytree]:
    d = cfg.d_model
    norm_kind = "layer" if cfg.family == "encdec" else "rms"
    specs: Dict[str, Pytree] = {"ln1": norm_specs(d, norm_kind)}
    if kind in ("attn", "attn_window", "xattn"):
        specs["mix"] = attn_mod.attention_specs(cfg)
    elif kind == "mla":
        specs["mix"] = mla_mod.mla_specs(cfg)
    elif kind == "rglru":
        specs["mix"] = rglru_mod.rglru_specs(cfg)
    elif kind == "ssd":
        specs["mix"] = ssd_mod.ssd_specs(cfg)
    else:
        raise ValueError(kind)
    if _has_mlp(cfg, kind):
        specs["ln2"] = norm_specs(d, norm_kind)
        if cfg.moe is not None:
            specs["ffn"] = moe_mod.moe_specs(cfg)
        else:
            specs["ffn"] = mlp_specs(d, cfg.d_ff, cfg.mlp_kind)
    return specs


def _apply_ffn(params, cfg: ModelConfig, x: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    if cfg.moe is not None:
        return moe_mod.apply_moe(params, cfg, x)
    return apply_mlp(params, x, cfg.mlp_kind), jnp.zeros((), jnp.float32)


def block_train(params, cfg: ModelConfig, kind: str, x: jax.Array,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One block, full sequence. Returns (x, aux_loss).

    The ``shard_activation`` anchors keep every residual/norm tensor
    pinned to the (batch, *, *) layout through the scan.  NOTE (§Perf
    train iterations 1-2, both refuted): they do NOT move the backward
    TP all-reduces off the norm-vjp's f32 internals — XLA's partial-sum
    placement there is upstream of sharding constraints; forcing bf16
    backward collectives needs a custom_vjp boundary (recorded as future
    work in EXPERIMENTS.md).
    """
    h = shard_activation(apply_norm(params["ln1"], x, cfg.norm_eps), _ACT)
    if kind == "attn":
        mix = attn_mod.attention_train(params["mix"], cfg, h, positions)
    elif kind == "attn_window":
        mix = attn_mod.attention_train(params["mix"], cfg, h, positions,
                                       window=cfg.hybrid.window)
    elif kind == "mla":
        mix = mla_mod.mla_train(params["mix"], cfg, h, positions)
    elif kind == "rglru":
        mix = rglru_mod.apply_rglru_train(params["mix"], cfg, h)
    elif kind == "ssd":
        mix = ssd_mod.apply_ssd_train(params["mix"], cfg, h)
    else:
        raise ValueError(kind)
    x = shard_activation(x + mix, _ACT)
    aux = jnp.zeros((), jnp.float32)
    if _has_mlp(cfg, kind):
        h2 = shard_activation(apply_norm(params["ln2"], x, cfg.norm_eps),
                              _ACT)
        y, aux = _apply_ffn(params["ffn"], cfg, h2)
        x = shard_activation(x + y, _ACT)
    return x, aux


def block_prefill(params, cfg: ModelConfig, kind: str, x: jax.Array,
                  positions: jax.Array, max_len: int,
                  kv_dtype: str = "bfloat16", plan=None
                  ) -> Tuple[jax.Array, jax.Array, Pytree]:
    """One block, full sequence, also emitting its decode cache.

    Returns (x, aux_loss, cache).  ``plan`` is a prefill-kind
    :class:`~repro.plan.LaunchPlan` (fused-admission path).
    """
    h = apply_norm(params["ln1"], x, cfg.norm_eps)
    if kind == "attn":
        mix, cache = attn_mod.attention_prefill(params["mix"], cfg, h,
                                                positions, max_len,
                                                kv_dtype=kv_dtype,
                                                plan=plan)
    elif kind == "attn_window":
        mix, cache = attn_mod.attention_prefill(
            params["mix"], cfg, h, positions,
            min(cfg.hybrid.window, max_len), window=cfg.hybrid.window,
            kv_dtype=kv_dtype, plan=plan)
    elif kind == "mla":
        mix, cache = mla_mod.mla_prefill(params["mix"], cfg, h, positions,
                                         max_len, plan=plan)
    elif kind == "rglru":
        mix, cache = rglru_mod.apply_rglru_train(params["mix"], cfg, h,
                                                 return_cache=True)
    elif kind == "ssd":
        mix, cache = ssd_mod.apply_ssd_train(params["mix"], cfg, h,
                                             return_cache=True)
    else:
        raise ValueError(kind)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if _has_mlp(cfg, kind):
        h2 = apply_norm(params["ln2"], x, cfg.norm_eps)
        y, aux = _apply_ffn(params["ffn"], cfg, h2)
        x = x + y
    return x, aux, cache


def block_cache_specs(cfg: ModelConfig, kind: str, batch: int,
                      max_len: int, kv_dtype: str = "bfloat16") -> Pytree:
    if kind == "attn":
        return attn_mod.kv_cache_specs(cfg, batch, max_len, dtype=kv_dtype)
    if kind == "attn_window":
        return attn_mod.kv_cache_specs(
            cfg, batch, min(cfg.hybrid.window, max_len), dtype=kv_dtype)
    if kind == "mla":
        return mla_mod.mla_cache_specs(cfg, batch, max_len)
    if kind == "rglru":
        return rglru_mod.rglru_cache_specs(cfg, batch)
    if kind == "ssd":
        return ssd_mod.ssd_cache_specs(cfg, batch)
    raise ValueError(kind)


def block_decode(params, cfg: ModelConfig, kind: str, x: jax.Array,
                 cache: Pytree, t: jax.Array, *,
                 plan=None) -> Tuple[jax.Array, Pytree]:
    """One block, one token. x: (B, 1, d).

    ``plan`` is the frozen :class:`~repro.plan.LaunchPlan` (static); it
    applies to full-attention layers, which all see the same decode
    shape.  Window layers attend over the ring cache (L_K = window, a
    DIFFERENT shape), so they fall back to an in-line policy evaluation
    on their own static length instead of consuming a plan frozen for
    the full cache (``attention_decode`` drops the frozen decision,
    keeping the policy overrides).
    """
    h = apply_norm(params["ln1"], x, cfg.norm_eps)
    if kind == "attn":
        mix, cache = attn_mod.attention_decode(
            params["mix"], cfg, h, cache, t, plan=plan)
    elif kind == "attn_window":
        mix, cache = attn_mod.attention_decode(
            params["mix"], cfg, h, cache, t, plan=plan,
            window=cfg.hybrid.window)
    elif kind == "mla":
        mix, cache = mla_mod.mla_decode(
            params["mix"], cfg, h, cache, t, plan=plan)
    elif kind == "rglru":
        mix, cache = rglru_mod.apply_rglru_decode(params["mix"], cfg, h,
                                                  cache)
    elif kind == "ssd":
        mix, cache = ssd_mod.apply_ssd_decode(params["mix"], cfg, h, cache)
    else:
        raise ValueError(kind)
    x = x + mix
    if _has_mlp(cfg, kind):
        h2 = apply_norm(params["ln2"], x, cfg.norm_eps)
        y, _ = _apply_ffn(params["ffn"], cfg, h2)
        x = x + y
    return x, cache


# ---------------------------------------------------------------------------
# Whole-model specs
# ---------------------------------------------------------------------------


def lm_param_specs(cfg: ModelConfig) -> Dict[str, Pytree]:
    groups = []
    for pattern, reps in layer_groups(cfg):
        groups.append(tuple(stack_specs(block_specs(cfg, k), reps)
                            for k in pattern))
    specs: Dict[str, Pytree] = {
        "embed": embed_specs(cfg.vocab_size, cfg.d_model,
                             cfg.tie_embeddings),
        "final_norm": norm_specs(
            cfg.d_model, "layer" if cfg.family == "encdec" else "rms"),
        "groups": tuple(groups),
    }
    if cfg.frontend.kind == "vision":
        specs["patch_proj"] = ParamSpec(
            (cfg.frontend.embed_dim, cfg.d_model), (None, "embed"))
    return specs


def lm_cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                   kv_dtype: str = "bfloat16") -> Tuple[Pytree, ...]:
    groups = []
    for pattern, reps in layer_groups(cfg):
        groups.append(tuple(
            stack_specs(block_cache_specs(cfg, k, batch, max_len,
                                          kv_dtype), reps)
            for k in pattern))
    return tuple(groups)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def lm_forward(
    params: Pytree,
    cfg: ModelConfig,
    tokens: jax.Array,                  # (B, L_text)
    *,
    patches: Optional[jax.Array] = None,  # (B, P, embed_dim) for vlm
    block_wrapper: Optional[Callable] = None,  # e.g. jax.checkpoint
) -> Tuple[jax.Array, jax.Array]:
    """-> (logits (B, L_total, vocab) f32, aux_loss scalar)."""
    x = embed_tokens(params["embed"], tokens)
    if cfg.frontend.kind == "vision":
        assert patches is not None, "vlm forward needs patch embeddings"
        pp = patches.astype(x.dtype) @ params["patch_proj"].astype(x.dtype)
        x = jnp.concatenate([pp, x], axis=1)
    x = shard_activation(x, _ACT)
    B, L, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    aux = jnp.zeros((), jnp.float32)
    for gi, (pattern, reps) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]

        def body(carry, layer_params, pattern=pattern):
            xc, auxc = carry
            xc = shard_activation(xc, _ACT)
            for ki, kind in enumerate(pattern):
                xc, a = block_train(layer_params[ki], cfg, kind, xc,
                                    positions)
                auxc = auxc + a
            return (shard_activation(xc, _ACT), auxc), None

        if block_wrapper is not None:
            body = block_wrapper(body)
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(body, (x, aux), gp)
        else:                      # roofline probe: unrolled layers
            for r in range(reps):
                (x, aux), _ = body((x, aux),
                                   jax.tree.map(lambda a: a[r], gp))

    x = apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)
    logits = shard_activation(logits, ("batch", None, "vocab"))
    return logits, aux


# ---------------------------------------------------------------------------
# Prefill: forward + decode caches in one pass
# ---------------------------------------------------------------------------


def lm_prefill(
    params: Pytree,
    cfg: ModelConfig,
    tokens: jax.Array,                  # (B, L_text)
    max_len: int,
    *,
    patches: Optional[jax.Array] = None,
    kv_dtype: str = "bfloat16",
) -> Tuple[jax.Array, Tuple[Pytree, ...]]:
    """-> (last-position logits (B, vocab) f32, decode caches).

    The caches are laid out exactly as ``lm_decode_step`` consumes them;
    decoding continues at position t = L_total.
    """
    x = embed_tokens(params["embed"], tokens)
    if cfg.frontend.kind == "vision":
        assert patches is not None
        pp = patches.astype(x.dtype) @ params["patch_proj"].astype(x.dtype)
        x = jnp.concatenate([pp, x], axis=1)
    x = shard_activation(x, _ACT)
    B, L, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    caches = []
    for gi, (pattern, reps) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]

        def body(xc, layer_params, pattern=pattern):
            xc = shard_activation(xc, _ACT)
            new_lc = []
            for ki, kind in enumerate(pattern):
                xc, _, c = block_prefill(layer_params[ki], cfg, kind, xc,
                                         positions, max_len, kv_dtype)
                new_lc.append(c)
            return shard_activation(xc, _ACT), tuple(new_lc)

        if cfg.scan_layers:
            x, gc = jax.lax.scan(body, x, gp)
        else:
            outs = []
            for r in range(reps):
                x, c = body(x, jax.tree.map(lambda a: a[r], gp))
                outs.append(c)
            gc = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        caches.append(gc)

    x = apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, -1:])[:, 0]
    return logits, tuple(caches)


# ---------------------------------------------------------------------------
# Fused single-slot prefill (serving admission)
# ---------------------------------------------------------------------------


def write_cache_slot(caches: Pytree, new: Pytree, slot: jax.Array) -> Pytree:
    """Write a batch-1 cache pytree into slot ``slot`` of a multi-slot one.

    Every layer-stacked cache leaf carries batch at axis 1 —
    ``(layers, B, ...)`` — for all families (``stack_specs`` prepends
    the layers dim to per-block ``(B, ...)`` leaves), so one
    ``dynamic_update_slice`` per leaf covers the whole pytree.
    """
    slot = jnp.asarray(slot, jnp.int32)

    def w(c, n):
        start = (0, slot) + (0,) * (c.ndim - 2)
        return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), start)

    return jax.tree.map(w, caches, new)


def lm_prefill_view(
    params: Pytree,
    cfg: ModelConfig,
    tokens: jax.Array,                  # (Lb,) int32 — bucket-padded prompt
    length: jax.Array,                  # scalar int32 — true prompt length
    view_len: int,                      # seq extent of the emitted cache
    *,
    plan=None,
    kv_dtype: str = "bfloat16",
) -> Tuple[jax.Array, Tuple[Pytree, ...]]:
    """Fused single-prompt prefill emitting a batch-1 cache VIEW.

    The storage-agnostic half of the admission prefill: one launch
    computes the whole prompt and returns (last-real-position logits
    (vocab,) f32, batch-1 caches of seq extent ``view_len``).  Where the
    view lands is the cache layout's business — :func:`lm_prefill_slot`
    writes it dense via :func:`write_cache_slot`; the paged layout
    scatters it through the slot's page table
    (:meth:`repro.cache.PagedKVCache.write_slot`).

    Padding correctness: positions >= ``length`` hold garbage K/V, but
    causal attention keeps them out of every real position's output, the
    decode step masks them via ``kv_len = t + 1``, and decoding
    overwrites row ``length`` onward before it ever becomes attendable.
    Families with recurrent per-token state (ssd / rglru) would fold the
    pad garbage into their carried state, so they are gated out at the
    :class:`~repro.models.registry.Model` facade.
    """
    x = embed_tokens(params["embed"], tokens[None])      # (1, Lb, d)
    _, L, _ = x.shape
    positions = jnp.arange(L, dtype=jnp.int32)[None]

    new_groups = []
    for gi, (pattern, reps) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]

        def body(xc, layer_params, pattern=pattern):
            new_lc = []
            for ki, kind in enumerate(pattern):
                xc, _, c = block_prefill(layer_params[ki], cfg, kind, xc,
                                         positions, view_len, kv_dtype,
                                         plan=plan)
                new_lc.append(c)
            return xc, tuple(new_lc)

        if cfg.scan_layers:
            x, gc = jax.lax.scan(body, x, gp)
        else:
            outs = []
            for r in range(reps):
                x, c = body(x, jax.tree.map(lambda a: a[r], gp))
                outs.append(c)
            gc = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_groups.append(gc)

    xl = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
    xl = apply_norm(params["final_norm"], xl, cfg.norm_eps)
    logits = unembed(params["embed"], xl)[0, 0]          # (vocab,)
    return logits, tuple(new_groups)


def lm_prefill_slot(
    params: Pytree,
    cfg: ModelConfig,
    caches: Tuple[Pytree, ...],
    tokens: jax.Array,                  # (Lb,) int32 — bucket-padded prompt
    slot: jax.Array,                    # scalar int32 — target decode slot
    length: jax.Array,                  # scalar int32 — true prompt length
    max_len: int,
    *,
    plan=None,
    kv_dtype: str = "bfloat16",
) -> Tuple[jax.Array, Tuple[Pytree, ...]]:
    """Prefill one prompt into slot ``slot`` of an existing DENSE cache.

    One launch writes the whole prompt's KV rows (O(1) launches per
    admission vs O(prompt_len) teacher-forced decode steps) and returns
    the logits at the last real prompt position, ready to sample the
    first generated token.  Returns (logits (vocab,) f32, caches).
    """
    logits, new = lm_prefill_view(params, cfg, tokens, length, max_len,
                                  plan=plan, kv_dtype=kv_dtype)
    return logits, write_cache_slot(caches, new, slot)


def block_suffix_prefill(params, cfg: ModelConfig, x: jax.Array,
                         cache: Pytree, start: jax.Array, *,
                         kv_dtype: str = "bfloat16", plan=None
                         ) -> Tuple[jax.Array, Pytree]:
    """One full-attention block over an unshared suffix (prefix sharing).

    Consumes AND updates the layer's batch-1 cache view: rows
    [0, start) arrive resident from adopted pages, the suffix's K/V is
    placed at [start, start + M).  Only ``attn`` blocks exist here —
    the registry gates prefix sharing to uniform full-attention
    families (windowed/recurrent blocks carry order-dependent state a
    row-offset restart cannot reproduce).
    """
    h = apply_norm(params["ln1"], x, cfg.norm_eps)
    mix, cache = attn_mod.attention_suffix_prefill(
        params["mix"], cfg, h, cache, start, kv_dtype=kv_dtype, plan=plan)
    x = x + mix
    h2 = apply_norm(params["ln2"], x, cfg.norm_eps)
    y, _ = _apply_ffn(params["ffn"], cfg, h2)
    return x + y, cache


def lm_prefill_suffix_view(
    params: Pytree,
    cfg: ModelConfig,
    caches: Tuple[Pytree, ...],         # batch-1 views, prefix resident
    tokens: jax.Array,                  # (Mb,) int32 — bucket-padded suffix
    start: jax.Array,                   # scalar int32 — first suffix row
    length: jax.Array,                  # scalar int32 — TOTAL prompt length
    *,
    plan=None,
    kv_dtype: str = "bfloat16",
) -> Tuple[jax.Array, Tuple[Pytree, ...]]:
    """Suffix-only admission prefill (prefix sharing).

    The counterpart of :func:`lm_prefill_view` when rows [0, start) of
    the slot already hold a shared prefix's K/V: one launch computes
    only the ``length - start`` unshared rows (bucket-padded to ``Mb``),
    attending over prefix + suffix through the causal ``q_offset`` mask,
    and places their K/V into the passed-in cache views.  Returns
    (logits at prompt row ``length - 1`` (vocab,) f32, updated views).

    Like :func:`lm_decode_step` this scans (params, cache) together —
    the view is an input, not an output, because the prefix rows must
    flow through.  Padding rows >= ``length - start`` hold garbage but
    land at key positions no real query attends, exactly the
    ``lm_prefill_view`` padding argument shifted by ``start``.
    """
    x = embed_tokens(params["embed"], tokens[None])      # (1, Mb, d)

    new_groups = []
    for gi, (pattern, reps) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]
        gc = caches[gi]
        assert pattern == ("attn",), \
            f"suffix prefill supports uniform attn stacks, got {pattern}"

        def body(xc, scanned):
            layer_params, layer_cache = scanned
            xc, c = block_suffix_prefill(layer_params[0], cfg, xc,
                                         layer_cache[0], start,
                                         kv_dtype=kv_dtype, plan=plan)
            return xc, (c,)

        if cfg.scan_layers:
            x, gc = jax.lax.scan(body, x, (gp, gc))
        else:
            outs = []
            for r in range(reps):
                x, c = body(x, jax.tree.map(lambda a: a[r], (gp, gc)))
                outs.append(c)
            gc = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_groups.append(gc)

    xl = jax.lax.dynamic_slice_in_dim(x, length - 1 - start, 1, axis=1)
    xl = apply_norm(params["final_norm"], xl, cfg.norm_eps)
    logits = unembed(params["embed"], xl)[0, 0]          # (vocab,)
    return logits, tuple(new_groups)


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def lm_decode_step(
    params: Pytree,
    cfg: ModelConfig,
    caches: Tuple[Pytree, ...],
    token: jax.Array,                   # (B,) int32 — the new token
    t: jax.Array,                       # scalar int32 — its position
    *,
    plan=None,
) -> Tuple[jax.Array, Tuple[Pytree, ...]]:
    """One decode step. Returns (logits (B, vocab) f32, new caches).

    ``plan``: precomputed :class:`~repro.plan.LaunchPlan` (the
    metadata-enabled path); threaded into every attention block so the
    split policy never runs inside this (traced) function.
    """
    x = embed_tokens(params["embed"], token[:, None])    # (B, 1, d)
    x = shard_activation(x, _ACT)

    new_caches = []
    for gi, (pattern, reps) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]
        gc = caches[gi]

        def body(xc, scanned, pattern=pattern):
            layer_params, layer_cache = scanned
            xc = shard_activation(xc, _ACT)
            new_lc = []
            for ki, kind in enumerate(pattern):
                xc, c = block_decode(layer_params[ki], cfg, kind, xc,
                                     layer_cache[ki], t, plan=plan)
                new_lc.append(c)
            return shard_activation(xc, _ACT), tuple(new_lc)

        if cfg.scan_layers:
            x, nc = jax.lax.scan(body, x, (gp, gc))
        else:
            outs = []
            for r in range(reps):
                x, c = body(x, jax.tree.map(lambda a: a[r], (gp, gc)))
                outs.append(c)
            nc = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_caches.append(nc)

    x = apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]           # (B, vocab)
    logits = shard_activation(logits, ("batch", "vocab"))
    return logits, tuple(new_caches)


# ---------------------------------------------------------------------------
# Speculative verify step (repro.spec)
# ---------------------------------------------------------------------------


def block_verify(params, cfg: ModelConfig, x: jax.Array,
                 cache: Pytree, t: jax.Array, *,
                 plan=None) -> Tuple[jax.Array, Pytree]:
    """One full-attention block over an M-row verify block. x: (B, M, d).

    Only ``attn`` blocks exist here — the registry gates speculation to
    uniform full-attention families, the same restriction as prefix
    sharing: windowed ring caches and recurrent state cannot roll back
    rejected rows by truncating ``kv_len``.
    """
    h = apply_norm(params["ln1"], x, cfg.norm_eps)
    mix, cache = attn_mod.attention_verify(params["mix"], cfg, h, cache, t,
                                           plan=plan)
    x = x + mix
    if _has_mlp(cfg, "attn"):
        h2 = apply_norm(params["ln2"], x, cfg.norm_eps)
        y, _ = _apply_ffn(params["ffn"], cfg, h2)
        x = x + y
    return x, cache


def lm_verify_step(
    params: Pytree,
    cfg: ModelConfig,
    caches: Tuple[Pytree, ...],
    tokens: jax.Array,                  # (B, M) int32 — current + drafts
    t: jax.Array,                       # (B,) int32 — each slot's position
    *,
    plan=None,
) -> Tuple[jax.Array, Tuple[Pytree, ...]]:
    """Speculative verify: score M = k + 1 rows per slot in one launch.

    The multi-token sibling of :func:`lm_decode_step`: ``tokens[:, 0]``
    is each slot's committed current token, ``tokens[:, 1:]`` the k
    drafts, and row ``j`` of the returned logits (B, M, vocab) is the
    model's next-token distribution after feeding rows [0, j] — the
    teacher-forced scores batched accept/reject consumes.  Every
    attention block attends causal-within-block at the slot's own
    offset through the frozen ``("verify", k, bucket)`` plan.
    """
    x = embed_tokens(params["embed"], tokens)            # (B, M, d)
    x = shard_activation(x, _ACT)

    new_caches = []
    for gi, (pattern, reps) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]
        gc = caches[gi]
        assert pattern == ("attn",), \
            f"verify step supports uniform attn stacks, got {pattern}"

        def body(xc, scanned):
            layer_params, layer_cache = scanned
            xc = shard_activation(xc, _ACT)
            xc, c = block_verify(layer_params[0], cfg, xc, layer_cache[0],
                                 t, plan=plan)
            return shard_activation(xc, _ACT), (c,)

        if cfg.scan_layers:
            x, nc = jax.lax.scan(body, x, (gp, gc))
        else:
            outs = []
            for r in range(reps):
                x, c = body(x, jax.tree.map(lambda a: a[r], (gp, gc)))
                outs.append(c)
            nc = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_caches.append(nc)

    x = apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)                 # (B, M, vocab)
    logits = shard_activation(logits, ("batch", None, "vocab"))
    return logits, tuple(new_caches)
