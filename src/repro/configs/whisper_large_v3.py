"""whisper-large-v3 — encoder-decoder with conv frontend (stub).

[audio] 32L d_model=1280 20H (GQA kv=20) d_ff=5120 vocab=51866 — enc-dec
[arXiv:2212.04356]

32 encoder + 32 decoder layers.  The conv frontend is a STUB:
``input_specs()`` supplies 1500 precomputed frame embeddings (dim 1280).
Assigned LM shapes are honored on the DECODER backbone (e.g. train_4k
trains a 4096-token decoder against the 1500-frame encoder); the decoder
cross-attends to the encoder output, and decode shapes exercise both the
self-attention KV cache and the fixed cross-attention cache — both routed
through the split policy.
"""
from repro.configs.base import FrontendConfig, ModelConfig, register_arch


@register_arch("whisper-large-v3")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="encdec",
        num_layers=32,            # decoder layers
        num_encoder_layers=32,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        cross_attention=True,
        encoder_positions=1500,
        frontend=FrontendConfig(kind="audio", num_positions=1500, embed_dim=1280),
        mlp_kind="gelu",
        rope_theta=0.0,           # whisper uses learned/sinusoidal positions
    )
