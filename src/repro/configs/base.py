"""Config system: model / mesh / run configs and the architecture registry.

Every assigned architecture registers a :class:`ModelConfig` via
``register_arch``.  Configs are plain frozen dataclasses so they hash, print
and diff cleanly, and so a config file is just data — no behaviour.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                      # per-expert FFN hidden dim
    capacity_factor: float = 1.25      # token-dropping capacity dispatch
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01      # load-balance loss
    # token dispatch impl: "gather" (scatter/gather, FLOP-light, production
    # default) | "einsum" (Switch-style one-hot matmuls, the naive baseline
    # the MoE §Perf cell hillclimbs away from)
    dispatch: str = "gather"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 family)."""
    kv_lora_rank: int = 256            # compressed KV latent dim (the cache)
    q_lora_rank: int = 768
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block parameters."""
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2                    # d_inner = expand * d_model
    ngroups: int = 1
    chunk_size: int = 256
    conv_width: int = 4


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style hybrid: pattern of recurrent vs attention blocks."""
    pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")   # 2:1 recurrent:attn
    window: int = 2048                 # local-attention window
    lru_width: Optional[int] = None    # defaults to d_model
    conv_width: int = 4


@dataclass(frozen=True)
class FrontendConfig:
    """Stubbed modality frontend: ``input_specs`` supplies precomputed embeds."""
    kind: str = "none"                 # "vision" | "audio" | "none"
    num_positions: int = 0             # patches (vision) / frames (audio)
    embed_dim: int = 0                 # frontend output dim (projected to d_model)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | mla | hybrid | ssm | vlm | moe | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None     # default d_model // num_heads
    # --- blocks / families -------------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # encoder-decoder (Whisper): encoder layer count (decoder = num_layers)
    num_encoder_layers: int = 0
    cross_attention: bool = False
    encoder_positions: int = 0         # fixed encoder length (e.g. 1500 frames)
    # --- details ------------------------------------------------------------
    mlp_kind: str = "swiglu"           # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    max_seq_len: int = 524_288
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # attention impl used by step functions: "xla" (cost-analyzable) | "pallas"
    attention_impl: str = "xla"
    # roofline probes: unrolled layer loop + unrolled inner scans so XLA's
    # cost analysis (which counts while-loop bodies ONCE) is exact.
    scan_layers: bool = True
    probe_unroll: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def q_group_size(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Mesh / parallelism configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    """Production mesh description. axis order = axis_names order."""
    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axis_names if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model"


SINGLE_POD = MeshConfig(shape=(16, 16), axis_names=("data", "model"))
MULTI_POD = MeshConfig(shape=(2, 16, 16), axis_names=("pod", "data", "model"))


# ---------------------------------------------------------------------------
# Input shapes (assigned set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES: Dict[str, ShapeConfig] = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Run (training / serving) configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"           # cosine | linear | constant
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    # distributed-optimization tricks
    grad_compression: str = "none"     # none | int8_ef  (int8 + error feedback)


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    mesh: MeshConfig = SINGLE_POD
    shape: ShapeConfig = TRAIN_4K
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    microbatches: int = 1              # gradient accumulation steps
    remat_policy: str = "nothing_saveable"   # see training/remat.py
    seed: int = 0
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    log_every: int = 10


@dataclass(frozen=True)
class ServeConfig:
    model: ModelConfig
    mesh: MeshConfig = SINGLE_POD
    shape: ShapeConfig = DECODE_32K
    # fa3_baseline | paper | tpu_adaptive | measured (repro.tune table)
    split_policy: str = "paper"
    # explicit split-count override (FA3's explicit ``num_splits``): the
    # engine's Planner bypasses the policy and freezes this count
    # (clamped per-shape to num_n_blocks).  None = the policy decides.
    num_splits_override: Optional[int] = None
    # split_policy="measured": path to the calibrated repro.tune
    # SplitTable the engine's Planner decides from (calibrate one with
    # `python -m repro.launch.tune`; the committed reference table is
    # experiments/tune/reference_reduced.json).  A table object can also
    # be passed directly via ServingEngine(tune_table=...).
    tune_table_path: Optional[str] = None
    # when set, ServingEngine.drain() dumps PlanCacheStats.to_json()
    # (hits/misses/launches/fallback traces + measured-policy fallback
    # counts) to this path — serving A/Bs read it instead of re-deriving
    # the counters by hand.
    stats_path: Optional[str] = None
    # repro.obs: when set, drain() writes a Chrome trace-event JSON of
    # the serve timeline here (request-lifecycle spans nested over
    # per-launch spans stamped with LaunchPlan provenance) — load it at
    # https://ui.perfetto.dev.  Strictly host-side; None = no tracing
    # (the zero-cost NULL_OBSERVER path).
    trace_path: Optional[str] = None
    # repro.obs: when set, drain() writes the MetricsRegistry artifact
    # here — TTFT/TPOT/queue-wait histograms, occupancy gauges, token/
    # warning counters, plus the absorbed PlanCacheStats section.  A
    # ".prom"/".txt" suffix selects Prometheus text exposition; any
    # other suffix gets the JSON snapshot.
    metrics_path: Optional[str] = None
    # metadata-enabled path (paper §5): precompute one LaunchPlan per
    # (batch, cache-length bucket) and launch the decode step
    # specialized on it.  False = the paper's weaker "internal heuristic"
    # path (policy re-evaluated at trace time inside the step).
    use_scheduler_metadata: bool = True
    # cache-length bucket width for plan lookup.  The policy's decision
    # only depends on ceil(L_K / KV_BLOCK), so any multiple of KV_BLOCK
    # (128) is decision-lossless; wider buckets = fewer specializations.
    seqlen_bucket: int = 128
    # max resident (plan, jitted step) specializations; oldest evicted
    # first.  0/None = unbounded.  Decode and fused-prefill plans share
    # this cache (int vs ("prefill", bucket) keys), so the worst-case
    # population is max_len / seqlen_bucket decode entries PLUS
    # max_len / prefill_bucket prefill entries — undersizing it makes
    # admissions and decode steps evict each other's specializations.
    plan_cache_capacity: Optional[int] = None
    # serving admission: "fused" = whole-prompt prefill in one planned
    # launch per admission (prompt padded to a prefill_bucket-wide
    # bucket, one jitted specialization per bucket); "loop" =
    # decode-by-teacher-forcing (one step per prompt token — the
    # pre-redesign baseline, and the only option for recurrent
    # families); "auto" = fused where the model supports it AND the
    # metadata path is on (use_scheduler_metadata=False A/Bs the
    # pre-metadata engine, so auto keeps its loop admission too).
    prefill_mode: str = "auto"
    # prompt-length bucket width for fused-prefill plan lookup; None =
    # seqlen_bucket.  Wider buckets = fewer prefill specializations,
    # more pad FLOPs per admission.
    prefill_bucket: Optional[int] = None
    # mesh-level split realization: "fused" = shard_map cache-write +
    # partial softmax + psum LSE combine (production default);
    # "auto" = GSPMD-auto partitioning of the functional update+attention
    # (the baseline the §Perf iteration measured 18 GiB/step of cache
    # all-gathers against)
    decode_impl: str = "fused"
    # "bfloat16" | "int8" — int8 stores symmetric per-(token, head)
    # quantized K/V + f32 scales: ~2x less cache traffic, the dominant
    # decode roofline term (§Perf C.4)
    kv_cache_dtype: str = "bfloat16"
    # repro.quant: the planned low-precision KV serving mode — a
    # QUANT_DTYPES name ("int8" | "fp8") that becomes the engine's
    # effective KV storage dtype (wins over kv_cache_dtype).  The
    # Scheduler keys every decode/verify AttentionSpec on it, so
    # quantized workloads plan their own dtype_bytes-aware splits and
    # the measured policy looks up (or explicitly misses) the matching
    # quant table family; pallas launches take the fused in-register
    # dequant kernel.  None = kv_cache_dtype rules (legacy knob).
    kv_quant: Optional[str] = None
    # repro.cache storage layout: "dense" = one (B, max_len, ...) block
    # per cache tensor (pre-redesign arrays, bit-identical); "paged" =
    # fixed-size pages + per-slot page tables — per-request capacity,
    # ragged per-slot residency, decode views sized by the RESIDENT
    # bucket (attention FLOPs/HBM stop paying for the padded tail), and
    # admission gated on free pages.  Paged rides the metadata-enabled
    # plan path and requires position-linear caches
    # (Model.supports_paged_cache).
    cache_layout: str = "dense"
    # paged layout: rows per page.  Must divide seqlen_bucket and
    # prefill_bucket (views are gathered per resident bucket).
    cache_page_size: int = 64
    # paged layout: total data pages in the pool.  None = dense-
    # equivalent (batch_slots * ceil(max_len / page_size)): nothing a
    # dense engine could serve is refused.  Smaller budgets
    # oversubscribe slots; exhaustion mid-generation finishes that
    # request with finish_reason="cache_capacity".
    cache_page_budget: Optional[int] = None
    # paged layout: share identical prompt prefixes across requests.
    # Per-page refcounts + a token-keyed prefix trie: admission adopts a
    # new prompt's already-resident prefix pages (refcount++, ZERO
    # prefill compute for the shared rows — only the unshared suffix is
    # prefilled, as an ("sprefill", ...) planned launch), writes
    # copy-on-write shared pages, and release only frees a page when its
    # last owner lets go.  Requires cache_layout="paged", fused prefill,
    # and Model.supports_prefix_sharing (uniform full-attention stack).
    share_prefix: bool = False
    # share_prefix: max pages the prefix trie may keep anchored beyond
    # their owners' lifetimes (None = unbounded, i.e. the page pool is
    # the only bound).  Anchored-only pages are evicted leaf-first LRU
    # when the pool runs dry or this bound is hit.
    prefix_capacity: Optional[int] = None
    # repro.spec: engine-wide speculative-decoding default — a drafter
    # name ("ngram" | "prompt_lookup" | a registered backend) every
    # request decodes with unless its SamplingParams.speculation says
    # otherwise.  None = plain decode.  Rides the metadata-enabled plan
    # path (verify launches are planned under ("verify", k, bucket)
    # keys) and needs Model.supports_speculation.
    speculation: Optional[str] = None
    # repro.spec: draft tokens proposed per verify step (1..64).
    speculation_k: int = 4
    # repro.spec: consecutive zero-accept verify steps before the
    # engine stops speculating for that request (None = never).
    speculation_max_rejects: Optional[int] = None
    # repro.shard: the mesh-native serving topology as a ShardSpec
    # string — "dp,sp" positional (e.g. "4,2") or "dp=4,sp=2" named,
    # parsed by ShardSpec.parse.  dp data-parallel slot shards x sp
    # sequence-shard chips per shard, needing dp*sp devices.  None =
    # the single-device ServingEngine (serve --mesh sets this).
    shard: Optional[str] = None
    max_batch: int = 128
    seed: int = 0


# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_arch(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_arch(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import config modules lazily so the registry fills itself
        from repro.configs import _load_all  # noqa: PLC0415
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    from repro.configs import _load_all  # noqa: PLC0415
    _load_all()
    return sorted(_REGISTRY)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, and why not if it doesn't.

    ``long_500k`` needs sub-quadratic attention: run only for SSM / hybrid
    (local-window attention) families.  Every assigned arch has a decoder,
    so decode shapes always apply.
    """
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""
