"""stablelm-12b — dense GQA transformer.

[dense] 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352
[hf:stabilityai/stablelm-2-1_6b family; hf]
"""
from repro.configs.base import ModelConfig, register_arch


@register_arch("stablelm-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_ff=13824,
        vocab_size=100352,
        mlp_kind="swiglu",
        qkv_bias=False,
        rope_theta=10000.0,
    )
