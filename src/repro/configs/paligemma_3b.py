"""paligemma-3b — VLM: SigLIP stub frontend + gemma MQA backbone.

[vlm] 18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216
SigLIP + gemma  [arXiv:2407.07726; hf]

The vision tower is a STUB: ``input_specs()`` supplies 256 precomputed
patch embeddings (dim 1152) which the model projects into d_model and
prepends to the text sequence.  MQA (kv=1) decode: the paper's most extreme
low-head-count case.
"""
from repro.configs.base import FrontendConfig, ModelConfig, register_arch


@register_arch("paligemma-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        family="vlm",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        d_ff=16384,
        vocab_size=257216,
        head_dim=256,             # gemma-style: head_dim != d_model/heads
        frontend=FrontendConfig(kind="vision", num_positions=256, embed_dim=1152),
        mlp_kind="geglu",
        rope_theta=10000.0,
    )
