"""Reduced (smoke-test) variants of the assigned architectures.

Same family, same block wiring, same attention ratios — tiny dims.  Used
by per-arch smoke tests and the runnable examples; the FULL configs are
exercised only through the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    HybridConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    get_arch,
)


def reduced_config(
    cfg_or_name: ModelConfig | str,
    *,
    num_layers: int = 2,
    d_model: int = 64,
    vocab_size: int = 256,
) -> ModelConfig:
    cfg = (get_arch(cfg_or_name) if isinstance(cfg_or_name, str)
           else cfg_or_name)
    # keep the GQA group ratio (it drives the paper's tile math)
    group = max(1, cfg.num_heads // max(1, cfg.num_kv_heads))
    heads = 4
    kv = max(1, heads // group)
    kw = dict(
        num_layers=num_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        d_ff=4 * d_model,
        vocab_size=vocab_size,
        head_dim=d_model // heads,
        max_seq_len=4096,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(num_experts=8, top_k=2, d_expert=d_model,
                              capacity_factor=2.0,
                              dispatch=cfg.moe.dispatch)
        kw["d_ff"] = d_model
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
        kw["num_kv_heads"] = heads            # MLA reconstructs all heads
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(state_dim=16, head_dim=16, expand=2,
                              ngroups=1, chunk_size=32, conv_width=4)
        kw["num_heads"] = 2 * d_model // 16   # d_inner / head_dim
        kw["num_kv_heads"] = kw["num_heads"]
        kw["d_ff"] = 0
    if cfg.hybrid is not None:
        kw["hybrid"] = HybridConfig(pattern=cfg.hybrid.pattern, window=64,
                                    lru_width=d_model, conv_width=4)
        kw["num_layers"] = max(num_layers, 4)  # cover pattern + remainder
    if cfg.frontend.kind == "vision":
        kw["frontend"] = dataclasses.replace(cfg.frontend, num_positions=8,
                                             embed_dim=48)
    if cfg.family == "encdec":
        kw["num_encoder_layers"] = 2
        kw["encoder_positions"] = 16
        kw["frontend"] = dataclasses.replace(cfg.frontend, num_positions=16,
                                             embed_dim=d_model)
        kw["max_seq_len"] = 512
    return dataclasses.replace(cfg, **kw)
