"""recurrentgemma-9b — hybrid RG-LRU + local attention, 1:2 attn:recurrent.

[hybrid] 38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000
RG-LRU + local attn, pattern (rglru, rglru, attn)  [arXiv:2402.19427]

Sub-quadratic (window-2048 local attention + linear recurrence) -> this arch
RUNS the long_500k shape.  Attention layers are MQA (kv=1): the paper's
extreme low-head-count case within the window.
"""
from repro.configs.base import HybridConfig, ModelConfig, register_arch


@register_arch("recurrentgemma-9b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        d_ff=12288,
        vocab_size=256000,
        hybrid=HybridConfig(
            pattern=("rglru", "rglru", "attn"),
            window=2048,
            lru_width=4096,
            conv_width=4,
        ),
        mlp_kind="geglu",
        rope_theta=10000.0,
    )
