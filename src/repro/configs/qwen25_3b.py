"""qwen2.5-3b — dense GQA transformer, kv=2: the paper's target regime.

[dense] 36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936 — GQA, QKV bias
[hf:Qwen/Qwen2.5 family; hf]

H_KV=2 decode at batch 1 gives 2 work tiles -> exactly the Table-1
H_KV=2 rows of the paper; this arch is one of the three hillclimb targets.
"""
from repro.configs.base import ModelConfig, register_arch


@register_arch("qwen2.5-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b",
        family="dense",
        num_layers=36,
        d_model=2048,
        num_heads=16,
        num_kv_heads=2,
        d_ff=11008,
        vocab_size=151936,
        mlp_kind="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )
