"""Architecture configs (one module per assigned arch) + shape sets."""
from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    MLAConfig,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    MULTI_POD,
    OptimizerConfig,
    PREFILL_32K,
    ServeConfig,
    ShapeConfig,
    SHAPES,
    SINGLE_POD,
    SSMConfig,
    TrainConfig,
    TRAIN_4K,
    get_arch,
    list_archs,
    register_arch,
    shape_applicable,
)

_LOADED = False

ARCH_MODULES = (
    "stablelm_12b",
    "minicpm3_4b",
    "codeqwen15_7b",
    "qwen25_3b",
    "recurrentgemma_9b",
    "mamba2_780m",
    "paligemma_3b",
    "qwen3_moe_235b_a22b",
    "granite_moe_3b_a800m",
    "whisper_large_v3",
)


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True
