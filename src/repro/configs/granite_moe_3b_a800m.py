"""granite-moe-3b-a800m — small MoE, 40 experts top-8.

[moe] 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8
[hf:ibm-granite family; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig, register_arch


@register_arch("granite-moe-3b-a800m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=512,                 # per-expert
        vocab_size=49155,
        moe=MoEConfig(num_experts=40, top_k=8, d_expert=512,
                      capacity_factor=1.25,
                      dispatch="ep_shard_map"),
        mlp_kind="swiglu",
        rope_theta=10000.0,
    )
