"""qwen3-moe-235b-a22b — large MoE, 128 experts top-8.

[moe] 94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936, MoE 128e top-8
[hf:Qwen/Qwen3 MoE family; hf]

d_ff=1536 is the PER-EXPERT hidden dim.  Experts are sharded on the model
axis (expert parallelism folded into TP axis).
"""
from repro.configs.base import ModelConfig, MoEConfig, register_arch


@register_arch("qwen3-moe-235b-a22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        d_ff=1536,                # per-expert
        vocab_size=151936,
        head_dim=128,
        moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536,
                      capacity_factor=1.25,
                      dispatch="ep_shard_map"),
        mlp_kind="swiglu",
        rope_theta=1_000_000.0,
    )
