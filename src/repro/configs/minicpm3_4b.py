"""minicpm3-4b — dense transformer with Multi-head Latent Attention (MLA).

[dense] 62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448 — MLA
[hf:openbmb/MiniCPM3-4B; hf]

MLA: queries/keys split into nope+rope parts; KV cache stores only the
compressed latent (kv_lora_rank) + shared rope key -> effectively a single
shared KV stream per layer, the most extreme "low head count" decode case.
"""
from repro.configs.base import MLAConfig, ModelConfig, register_arch


@register_arch("minicpm3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="mla",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,          # MLA: full heads reconstructed from latent
        d_ff=6400,
        vocab_size=73448,
        mla=MLAConfig(
            kv_lora_rank=256,
            q_lora_rank=768,
            qk_nope_head_dim=64,
            qk_rope_head_dim=32,
            v_head_dim=64,
        ),
        mlp_kind="swiglu",
        rope_theta=10000.0,
    )
