"""mamba2-780m — attention-free SSM (state-space duality / SSD).

[ssm] 48L d_model=1536 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060]

Attention-free: the paper's split technique is inapplicable (DESIGN.md
SS5) — implemented without it.  Sub-quadratic -> runs long_500k.
d_inner = 2*d_model = 3072, head_dim=64 -> 48 SSD heads.
"""
from repro.configs.base import ModelConfig, SSMConfig, register_arch


@register_arch("mamba2-780m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        num_layers=48,
        d_model=1536,
        num_heads=48,             # SSD heads = d_inner / head_dim
        num_kv_heads=48,
        d_ff=0,                   # no separate MLP; SSD block carries the FFN role
        vocab_size=50280,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, ngroups=1,
                      chunk_size=256, conv_width=4),
    )
