"""codeqwen1.5-7b — dense MHA transformer (qwen1.5 arch, QKV bias).

[dense] 32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416
[hf:Qwen/CodeQwen1.5-7B; hf]
"""
from repro.configs.base import ModelConfig, register_arch


@register_arch("codeqwen1.5-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        mlp_kind="swiglu",
        qkv_bias=True,            # qwen1.5 uses attention QKV bias
        rope_theta=1_000_000.0,
    )
