"""Version-compatibility shims over the JAX API surface.

The codebase is written against the current JAX names
(``jax.sharding.AxisType``, ``pallas.tpu.CompilerParams``); older jaxlib
wheels (0.4.x) spell these differently or not at all.  Everything that
touches a drifting name goes through this module so the same source runs
on both — and so the next rename is a one-line fix here instead of an
AttributeError cluster across kernels, launch and tests.
"""
from __future__ import annotations

import inspect
from typing import Optional, Sequence

import jax


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them.

    JAX >= 0.5 takes ``axis_types=(AxisType.Auto, ...)``; 0.4.x has
    neither the kwarg nor the enum (every axis is implicitly Auto there,
    so omitting it is semantically identical).
    """
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if (axis_type is not None
            and "axis_types" in inspect.signature(jax.make_mesh).parameters):
        kw["axis_types"] = (axis_type.Auto,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def cost_analysis(compiled) -> dict:
    """Per-program XLA cost analysis as a flat dict.

    ``Compiled.cost_analysis()`` returns a dict on current JAX but a
    one-dict-per-device LIST on 0.4.x; normalize to the dict form.
    """
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def tpu_compiler_params(*, dimension_semantics: Optional[tuple] = None,
                        **kwargs):
    """Pallas-TPU compiler params across the CompilerParams rename.

    ``pltpu.CompilerParams`` (current) vs ``pltpu.TPUCompilerParams``
    (jax 0.4.x) — identical fields, different class name.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = dimension_semantics
    return cls(**kwargs)
