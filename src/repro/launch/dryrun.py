import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes need 512 host devices.

For every cell this script:

1. builds the right step (train_step / prefill_step / serve_step),
2. ``.lower().compile()`` on the production mesh — sharding mismatches,
   compile-time OOMs and unsupported collectives fail HERE,
3. records ``memory_analysis()`` / ``cost_analysis()`` / the collective
   schedule, and the derived roofline terms, to a JSON file under
   ``experiments/dryrun/``.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
        --shape train_4k [--multi-pod] [--policy paper]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import SHAPES, get_arch, list_archs, shape_applicable
from repro.configs.base import (
    OptimizerConfig,
    ServeConfig,
    ShapeConfig,
    TrainConfig,
)
from repro.launch.mesh import make_production_mesh, mesh_name
from repro.models.registry import build_model
from repro.roofline.analysis import analyze, model_flops_for
from repro.roofline.probe import corrected_cost
from repro.serving.decode_step import build_mesh_decode_step, build_prefill_step
from repro.training.train_step import build_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# microbatch count for full train cells: fits the per-device activation
# footprint in HBM (see EXPERIMENTS.md §Dry-run)
TRAIN_MICROBATCHES = 4


def _lower_cell(arch: str, shape: ShapeConfig, mesh, policy: str):
    cfg = get_arch(arch)
    model = build_model(cfg)
    if shape.kind == "train":
        tcfg = TrainConfig(model=cfg, shape=shape,
                           optimizer=OptimizerConfig(),
                           microbatches=TRAIN_MICROBATCHES)
        bundle = build_train_step(model, tcfg, mesh)
        lowered = bundle.step.lower(*bundle.abstract_args())
        tokens = shape.global_batch * shape.seq_len
        kind = "train"
    elif shape.kind == "prefill":
        scfg = ServeConfig(model=cfg, shape=shape, split_policy=policy)
        bundle = build_prefill_step(model, scfg, mesh)
        lowered = bundle.step.lower(*bundle.abstract_args())
        tokens = shape.global_batch * shape.seq_len
        kind = "prefill"
    else:
        scfg = ServeConfig(model=cfg, shape=shape, split_policy=policy)
        bundle = build_mesh_decode_step(model, scfg, mesh)
        lowered = bundle.step.lower(*bundle.abstract_args())
        tokens = shape.global_batch                      # one token / seq
        kind = "decode"
    return model, bundle, lowered, tokens, kind


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             policy: str = "paper", verbose: bool = True
             ) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    cfg = get_arch(arch)
    ok, why = shape_applicable(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mname = mesh_name(mesh)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mname,
        "chips": mesh.devices.size, "policy": policy, "status": "ok",
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    t0 = time.time()
    model, bundle, lowered, tokens, kind = _lower_cell(
        arch, shape, mesh, policy)
    rec["lower_s"] = round(time.time() - t0, 2)

    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    try:
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        if hasattr(mem, "peak_memory_in_bytes"):
            rec["memory_analysis"]["peak_memory_in_bytes"] = int(
                mem.peak_memory_in_bytes)
    except Exception as e:                                # pragma: no cover
        rec["memory_analysis"] = {"error": str(e)}

    cost = compat.cost_analysis(compiled)
    rec["cost_analysis"] = {k: float(v) for k, v in cost.items()
                            if isinstance(v, (int, float))
                            and k in ("flops", "bytes accessed",
                                      "transcendentals",
                                      "utilization operand 0 {}")}

    hlo = compiled.as_text()
    mflops = model_flops_for(cfg, model.param_specs(), tokens=tokens,
                             step_kind=kind if kind == "train"
                             else "inference")
    # probe-corrected per-device cost (see roofline/probe.py: XLA counts
    # loop bodies once, so the raw full-compile numbers undercount)
    t2 = time.time()
    cc = corrected_cost(
        cfg, shape, mesh, policy=policy,
        microbatches=TRAIN_MICROBATCHES if kind == "train" else 1,
        remat=kind == "train",
        seq_split=bool(getattr(bundle, "mesh_splits", 1) > 1))
    rec["probe_s"] = round(time.time() - t2, 2)
    report = analyze(
        arch=arch, shape=shape_name, mesh_name=mname,
        chips=mesh.devices.size,
        cost={"flops": cc.flops, "bytes accessed": cc.bytes},
        hlo_text="", model_flops=mflops, step_kind=kind, policy=policy,
        note="probe-corrected")
    # collective bytes come from the probe correction, not the empty hlo
    from repro.roofline.analysis import ICI_LINK_BW
    from repro.roofline.hlo import wire_bytes
    report.per_category = {k: int(v) for k, v in cc.coll.items()}
    report.device_collective_bytes = float(wire_bytes(cc.coll))
    report.collective_s = report.device_collective_bytes / ICI_LINK_BW
    terms = {"compute": report.compute_s, "memory": report.memory_s,
             "collective": report.collective_s}
    report.dominant = max(terms, key=terms.get)
    rec["roofline"] = report.to_dict()
    rec["raw_cost_analysis_note"] = (
        "cost_analysis above is the RAW full-compile number (loop bodies "
        "counted once); roofline uses the probe-corrected values")
    if kind == "decode":
        rec["mesh_splits"] = bundle.mesh_splits
        # the frozen LaunchPlan the step was specialized on (Planner
        # output; None for attention-free families / heuristic path)
        rec["plan"] = (bundle.metadata.describe()
                       if bundle.metadata is not None else None)

    if verbose:
        ma = rec.get("memory_analysis", {})
        print(f"[{mname}] {arch} x {shape_name}: "
              f"lower {rec['lower_s']}s compile {rec['compile_s']}s | "
              f"args {ma.get('argument_size_in_bytes', 0)/2**30:.2f} GiB "
              f"temp {ma.get('temp_size_in_bytes', 0)/2**30:.2f} GiB | "
              f"dominant={report.dominant} "
              f"(c={report.compute_s*1e3:.2f}ms m={report.memory_s*1e3:.2f}ms "
              f"coll={report.collective_s*1e3:.2f}ms) "
              f"useful={report.useful_ratio:.2f}")
    return rec


def save_record(rec: Dict[str, Any], out_dir: Path = OUT_DIR) -> Path:
    d = out_dir / rec["mesh"] / rec["arch"]
    d.mkdir(parents=True, exist_ok=True)
    suffix = "" if rec.get("policy") in (None, "paper") \
        else f"-{rec['policy']}"
    p = d / f"{rec['shape']}{suffix}.json"
    p.write_text(json.dumps(rec, indent=2, default=str))
    return p


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch, shape)")
    ap.add_argument("--policy", default="paper",
                    choices=("fa3_baseline", "paper", "tpu_adaptive"))
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) \
        else [args.shape]

    failures = 0
    for arch in archs:
        for shape in shapes:
            try:
                rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                               policy=args.policy)
            except Exception as e:
                failures += 1
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if args.multi_pod else "16x16",
                       "policy": args.policy, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
                print(f"FAIL {arch} x {shape}: {rec['error']}")
            save_record(rec, Path(args.out))
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
