"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Drives the full substrate end-to-end on whatever devices exist: reduced
or full config, synthetic data, AdamW, remat, microbatching, async
checkpointing, elastic resume.  The quickstart example and the
integration tests call :func:`run_training` directly.
"""
from __future__ import annotations

import argparse
from typing import Dict, Optional

import jax

from repro.configs import SHAPES, get_arch
from repro.configs.base import (
    OptimizerConfig,
    ShapeConfig,
    TrainConfig,
)
from repro.configs.reduced import reduced_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.fault.elastic import resumable_train_loop
from repro.launch.mesh import make_host_mesh
from repro.models.registry import build_model
from repro.training.train_step import build_train_step


def run_training(
    arch: str,
    *,
    steps: int = 200,
    reduced: bool = True,
    d_model: int = 128,
    num_layers: int = 4,
    seq_len: int = 128,
    global_batch: int = 8,
    microbatches: int = 1,
    lr: float = 1e-3,
    ckpt_dir: str = "/tmp/repro_ckpt",
    ckpt_every: int = 50,
    model_axis: int = 1,
    remat_policy: str = "none",
    fail_at_step: Optional[int] = None,
    log_fn=print,
) -> Dict[str, float]:
    cfg = get_arch(arch)
    if reduced:
        cfg = reduced_config(cfg, num_layers=num_layers, d_model=d_model)
    model = build_model(cfg)
    mesh = make_host_mesh(model_axis)
    shape = ShapeConfig("cli", seq_len, global_batch, "train")
    tcfg = TrainConfig(
        model=cfg, shape=shape,
        optimizer=OptimizerConfig(lr=lr, warmup_steps=max(1, steps // 20),
                                  total_steps=steps),
        microbatches=microbatches, remat_policy=remat_policy,
        checkpoint_dir=ckpt_dir, checkpoint_every=ckpt_every)
    bundle = build_train_step(model, tcfg, mesh)
    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=model.text_len(seq_len) if cfg.frontend.kind != "vision"
        else model.text_len(seq_len),
        global_batch=global_batch, seed=tcfg.seed))
    if model.frontend_inputs(global_batch, seq_len):
        raise NotImplementedError(
            "CLI training drives text-only archs; frontend-stub archs are "
            "covered by examples/train_tiny.py and the integration tests")
    return resumable_train_loop(
        bundle, data, total_steps=steps, ckpt_dir=ckpt_dir,
        ckpt_every=ckpt_every, fail_at_step=fail_at_step, log_fn=log_fn)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="full config (default: reduced smoke config)")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--num-layers", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--remat", default="none")
    args = ap.parse_args()
    metrics = run_training(
        args.arch, steps=args.steps, reduced=not args.full,
        d_model=args.d_model, num_layers=args.num_layers,
        seq_len=args.seq_len, global_batch=args.global_batch,
        microbatches=args.microbatches, lr=args.lr,
        ckpt_dir=args.ckpt_dir, model_axis=args.model_axis,
        remat_policy=args.remat)
    print("final:", metrics)


if __name__ == "__main__":
    main()
