"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Loads (or random-inits) a reduced model and drives the request-lifecycle
:class:`~repro.serving.ServingEngine` (submit/step/stream/drain) over a
synthetic request stream, printing per-request completions and aggregate
TPOT.  ``--policy`` A/Bs the paper's heuristic against the flawed
baseline on the same requests; ``--temperature/--top-k/--top-p`` select
seeded sampling (default greedy); ``--stream`` prints TOKEN/FINISHED
events as the engine emits them; ``--prefill`` switches between fused
bucketed admission and the legacy teacher-forcing loop.
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import numpy as np

from repro.configs import get_arch
from repro.configs.base import ServeConfig
from repro.configs.reduced import reduced_config
from repro.core.split_policy import available_policies
from repro.models.registry import build_model
from repro.serving import (
    FINISHED,
    TOKEN,
    Request,
    SamplingParams,
    ServingEngine,
    get_sampler,
)


def run_serving(arch: str, *, num_requests: int = 8, max_new: int = 16,
                policy: str = "paper", batch_slots: int = 4,
                max_len: int = 256, d_model: int = 128,
                num_layers: int = 2, seed: int = 0,
                num_splits_override=None, temperature: float = 0.0,
                top_k: int = 0, top_p: float = 1.0,
                sampler: str = "categorical",
                prefill_mode: str = "auto", stream: bool = False,
                cache_layout: str = "dense", share_prefix: bool = False,
                speculate=None, speculate_k: int = 4,
                speculate_max_rejects=None, kv_quant=None,
                tune_table=None, stats_path=None, mesh=None,
                trace_path=None, metrics_path=None, log_fn=print):
    cfg = reduced_config(get_arch(arch), num_layers=num_layers,
                         d_model=d_model)
    if cfg.family in ("vlm", "encdec"):
        raise NotImplementedError(
            "CLI serving drives text-only archs; frontend-stub archs are "
            "exercised by the tests")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    scfg = ServeConfig(model=cfg, split_policy=policy,
                       num_splits_override=num_splits_override,
                       prefill_mode=prefill_mode,
                       cache_layout=cache_layout,
                       share_prefix=share_prefix,
                       speculation=speculate,
                       speculation_k=speculate_k,
                       speculation_max_rejects=speculate_max_rejects,
                       kv_quant=kv_quant,
                       tune_table_path=(str(tune_table) if tune_table
                                        else None),
                       stats_path=(str(stats_path) if stats_path
                                   else None),
                       trace_path=(str(trace_path) if trace_path
                                   else None),
                       metrics_path=(str(metrics_path) if metrics_path
                                     else None),
                       shard=mesh)
    if mesh:
        # mesh-native topology: --slots becomes slots PER SHARD
        from repro.shard import ShardedServingEngine, ShardSpec
        spec = ShardSpec.parse(mesh, slots_per_shard=batch_slots)
        engine = ShardedServingEngine(
            model, scfg, spec=spec, max_len=max_len,
            sampler=get_sampler(sampler))
    else:
        engine = ServingEngine(
            model, scfg, max_len=max_len, batch_slots=batch_slots,
            sampler=get_sampler(sampler))
    engine.load(params)

    rng = np.random.default_rng(seed)
    # --share-prefix traffic models the production shape the knob
    # exists for: every request opens with the same "system prompt"
    # (long enough to span full pages), then a short unique tail
    system = (rng.integers(0, cfg.vocab_size, size=96).tolist()
              if share_prefix else [])
    reqs: List[Request] = [
        Request(i, system
                + rng.integers(0, cfg.vocab_size,
                               size=rng.integers(4, 12)).tolist(),
                max_new_tokens=max_new,
                sampling=SamplingParams(temperature=temperature,
                                        top_k=top_k, top_p=top_p,
                                        seed=seed + i))
        for i in range(num_requests)]
    t0 = time.monotonic()
    handles = [engine.submit(r) for r in reqs]
    if stream:
        while engine.has_work():
            for ev in engine.step():
                if ev.kind == TOKEN:
                    log_fn(f"req {ev.request_id} token[{ev.index}] = "
                           f"{ev.token}")
                elif ev.kind == FINISHED:
                    log_fn(f"req {ev.request_id} finished "
                           f"({ev.finish_reason})")
    outs = engine.drain()
    dt = time.monotonic() - t0
    total_new = sum(len(c.tokens) for c in outs)
    for c in outs:
        log_fn(f"req {c.request_id}: prompt {len(c.prompt)} toks -> "
               f"{c.tokens[:8]}{'...' if len(c.tokens) > 8 else ''}")
    log_fn(f"policy={policy}: {len(outs)} requests, {total_new} tokens "
           f"in {dt:.2f}s ({1e3 * dt / max(1, total_new):.1f} ms/token)")
    log_fn("frozen plans (bucket -> num_splits): "
           f"{engine.planned_splits()}")
    if mesh:
        spec_d = engine.spec.describe()
        log_fn(f"shard topology dp={spec_d['dp']} x sp={spec_d['sp']} "
               f"({spec_d['total_slots']} slots over "
               f"{spec_d['num_devices']} devices, "
               f"{engine.plan.fingerprint})")
        for row in engine.describe():
            budget = (f", pages {row['free_pages']}/"
                      f"{row['total_pages']} free"
                      if "total_pages" in row else "")
            log_fn(f"  shard {row['shard']}: {row['routed']} requests "
                   f"over {row['slots']} slots, {row['launches']} "
                   f"launches{budget}")
    if kv_quant:
        log_fn(f"kv quant: {kv_quant} storage + f32 scales "
               f"(plans keyed on the {kv_quant} family, "
               f"dtype_bytes={engine.sched.decode_spec(128).workload().dtype_bytes})")
    if engine.tune_table is not None:
        st = engine.stats
        log_fn(f"measured policy: table {engine.tune_table.version}, "
               f"{st.measured_lookups} lookups, "
               f"{st.measured_fallbacks} fallbacks to "
               f"'{engine.tune_table.fallback_policy}'")
    if stats_path:
        log_fn(f"plan-cache stats snapshot: {stats_path}")
    if trace_path:
        log_fn(f"request-lifecycle trace (load at https://ui.perfetto.dev"
               f"): {trace_path}")
    if metrics_path:
        log_fn(f"serving metrics snapshot: {metrics_path}")
    if cache_layout == "paged":
        cs = engine.cache_stats()
        log_fn(f"paged cache: {cs['total_pages']} pages of "
               f"{cs['page_size']} ({cs['storage_bytes']} B vs dense "
               f"{cs['dense_bytes']} B), {cs['free_pages']} free")
        if share_prefix:
            log_fn(f"prefix sharing: {cs['prefix_hits']} hits, "
                   f"{cs['prefix_shared_rows']} prompt rows reused, "
                   f"{cs['pages_allocated_total']} pages allocated, "
                   f"{cs['prefix_copies']} page copies, "
                   f"{cs['prefix_anchored_pages']} anchored")
    if engine.prefill_mode == "fused":
        log_fn("fused prefill buckets: "
               f"{engine.planned_prefill_buckets()}")
    if speculate:
        st = engine.stats
        log_fn(f"speculation ({speculate}, k={speculate_k}): "
               f"{st.spec_steps} verify steps, acceptance "
               f"{st.spec_acceptance_rate:.2f} "
               f"({st.spec_accepted}/{st.spec_proposed} drafts), "
               f"{st.spec_tokens_per_step:.2f} tokens/step, "
               f"{st.spec_disabled} requests disabled; verify plans "
               f"{engine.sched.planned_verify_keys()}")
    assert len(handles) == len(outs)
    return outs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--policy", default="paper",
                    choices=available_policies())
    ap.add_argument("--tune-table", default=None,
                    help="calibrated repro.tune SplitTable JSON for "
                         "--policy measured (write one with `python -m "
                         "repro.launch.tune`)")
    ap.add_argument("--stats-path", default=None,
                    help="dump PlanCacheStats.to_json() here at drain")
    ap.add_argument("--trace", default=None, dest="trace_path",
                    help="repro.obs: dump the Chrome trace-event JSON "
                         "serving timeline here at drain (load it at "
                         "https://ui.perfetto.dev)")
    ap.add_argument("--metrics", default=None, dest="metrics_path",
                    help="repro.obs: dump the serving metrics snapshot "
                         "here at drain (.prom/.txt suffix selects "
                         "Prometheus text exposition, else JSON)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--splits", type=int, default=None,
                    help="explicit num_splits override: the engine's "
                         "Planner bypasses the policy (FA3's explicit "
                         "num_splits argument)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus truncation (1.0 = off)")
    ap.add_argument("--sampler", default="categorical",
                    help="sampler registry name (greedy | categorical; "
                         "extensible via repro.serving.register_sampler)")
    ap.add_argument("--prefill", default="auto",
                    choices=("auto", "fused", "loop"),
                    help="admission path: fused bucketed prefill vs the "
                         "legacy teacher-forcing loop")
    ap.add_argument("--cache-layout", default="dense",
                    choices=["dense", "paged"],
                    help="repro.cache storage layout (paged: resident-"
                         "bucket views + page-budget admission)")
    ap.add_argument("--share-prefix", action="store_true",
                    help="share identical prompt prefixes across "
                         "requests (refcounted copy-on-write pages; "
                         "requires --cache-layout paged)")
    ap.add_argument("--speculate", default=None,
                    help="speculative decoding: drafter registry name "
                         "(ngram | prompt_lookup; extensible via "
                         "repro.spec.register_drafter)")
    ap.add_argument("--speculate-k", type=int, default=4,
                    help="draft tokens per verify step (with --speculate)")
    ap.add_argument("--speculate-max-rejects", type=int, default=None,
                    help="consecutive zero-accept verify steps before a "
                         "request stops speculating (default: never)")
    ap.add_argument("--kv-quant", default=None,
                    choices=["int8", "fp8"],
                    help="repro.quant low-precision KV serving mode: "
                         "quantize-on-write KV cache + in-kernel dequant "
                         "on pallas, quant-keyed split plans everywhere")
    ap.add_argument("--mesh", default=None,
                    help="mesh-native topology 'dp,sp' (repro.shard): "
                         "dp data-parallel slot shards x sp sequence-"
                         "shard chips per shard; --slots becomes slots "
                         "PER SHARD.  Needs dp*sp devices (CPU: set "
                         "XLA_FLAGS=--xla_force_host_platform_"
                         "device_count)")
    ap.add_argument("--stream", action="store_true",
                    help="print TOKEN/FINISHED events as they happen")
    args = ap.parse_args()
    run_serving(args.arch, num_requests=args.requests,
                max_new=args.max_new, policy=args.policy,
                batch_slots=args.slots,
                num_splits_override=args.splits,
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p, sampler=args.sampler,
                prefill_mode=args.prefill, stream=args.stream,
                cache_layout=args.cache_layout,
                share_prefix=args.share_prefix,
                speculate=args.speculate,
                speculate_k=args.speculate_k,
                speculate_max_rejects=args.speculate_max_rejects,
                kv_quant=args.kv_quant,
                tune_table=args.tune_table, stats_path=args.stats_path,
                mesh=args.mesh, trace_path=args.trace_path,
                metrics_path=args.metrics_path)


if __name__ == "__main__":
    main()
