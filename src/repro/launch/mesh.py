"""Production mesh construction + the mesh-level planner entry point.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — the dry-run must
set ``XLA_FLAGS`` before the first jax initialization.

``planner_for_mesh`` is how every launcher (serve-step builder, dry-run,
benchmarks) obtains the :class:`~repro.plan.Planner` that freezes
mesh-level launch plans: the policy's ``num_cores`` becomes the chip
count on the sharding axis, so the paper's occupancy decision runs with
chips in place of SMs.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.compat import make_mesh
from repro.plan import Planner


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = one v5e pod (256 chips); 2x16x16 = two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    data = n // model_axis
    return make_mesh((data, model_axis), ("data", "model"))


def mesh_name(mesh: jax.sharding.Mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)


def planner_for_mesh(mesh: jax.sharding.Mesh, *, policy: str = "paper",
                     axis: str = "model",
                     num_splits_override: Optional[int] = None) -> Planner:
    """The planner whose machine model is ``axis`` of ``mesh``."""
    return Planner(policy=policy, num_cores=mesh.shape[axis],
                   num_splits_override=num_splits_override)
