"""Production mesh construction + the mesh-level planner entry point.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — the dry-run must
set ``XLA_FLAGS`` before the first jax initialization.

``planner_for_mesh`` is how every launcher (serve-step builder, dry-run,
benchmarks) obtains the :class:`~repro.plan.Planner` that freezes
mesh-level launch plans: the policy's ``num_cores`` becomes the chip
count on the sharding axis, so the paper's occupancy decision runs with
chips in place of SMs.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from repro.compat import make_mesh
from repro.plan import Planner


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = one v5e pod (256 chips); 2x16x16 = two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    data = n // model_axis
    return make_mesh((data, model_axis), ("data", "model"))


def make_engine_mesh(dp: int, sp: int, devices: Optional[Sequence] = None
                     ) -> Tuple[jax.sharding.Mesh,
                                Tuple[jax.sharding.Mesh, ...]]:
    """The mesh-native serving engine's topology: a (dp, sp) global mesh
    over axes ("data", "model") plus one (1, sp) sub-mesh per dp shard.

    Built with the plain ``Mesh`` constructor over an EXPLICIT device
    grid — never ``mesh_utils`` topology reordering — so shard ``d``
    deterministically owns ``devices[d*sp : (d+1)*sp]`` and two engines
    constructed for the same ShardSpec in one process agree on every
    device assignment (the per-topology plan-cache registry depends on
    this).
    """
    devs = list(devices) if devices is not None else jax.devices()
    need = dp * sp
    if dp < 1 or sp < 1:
        raise ValueError(f"mesh axes must be >= 1, got dp={dp}, sp={sp}")
    if len(devs) < need:
        raise ValueError(
            f"shard topology dp={dp} x sp={sp} needs {need} devices, "
            f"{len(devs)} visible — on CPU force virtual devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    grid = np.empty((dp, sp), dtype=object)
    for d in range(dp):
        for s in range(sp):
            grid[d, s] = devs[d * sp + s]
    mesh = jax.sharding.Mesh(grid, ("data", "model"))
    subs = tuple(jax.sharding.Mesh(grid[d:d + 1, :], ("data", "model"))
                 for d in range(dp))
    return mesh, subs


def mesh_name(mesh: jax.sharding.Mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)


def planner_for_mesh(mesh: jax.sharding.Mesh, *, policy: str = "paper",
                     axis: str = "model",
                     num_splits_override: Optional[int] = None) -> Planner:
    """The planner whose machine model is ``axis`` of ``mesh``."""
    return Planner(policy=policy, num_cores=mesh.shape[axis],
                   num_splits_override=num_splits_override)
