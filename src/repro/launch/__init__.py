"""Launchers: production mesh, dry-run, train, serve."""
from repro.launch.mesh import make_host_mesh, make_production_mesh, mesh_name  # noqa: F401
