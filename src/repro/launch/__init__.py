"""Launchers: production mesh, dry-run, train, serve."""
from repro.launch.mesh import (  # noqa: F401
    make_host_mesh,
    make_production_mesh,
    mesh_name,
    planner_for_mesh,
)
