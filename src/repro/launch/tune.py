"""Autotuning launcher: ``python -m repro.launch.tune ...``

Calibrates a :class:`~repro.tune.TuneSpec` grid through the
:class:`~repro.tune.Calibrator` and writes the resulting
:class:`~repro.tune.SplitTable` JSON under ``experiments/tune/`` — the
closed measure -> decide -> serve loop: the written table feeds
``Planner(policy="measured")`` / ``serve --tune-table``.

    # regenerate the committed reference table (deterministic, modeled)
    python -m repro.launch.tune --reference

    # calibrate a custom grid by wall-clock on this backend
    python -m repro.launch.tune --mode wallclock \
        --lk 128 256 512 1024 --batches 1 4 --heads 64:1:128 \
        --out experiments/tune/my_backend.json

    # refresh a sub-grid of an existing table in place
    python -m repro.launch.tune --lk 512 --heads 64:1:128 \
        --merge experiments/tune/my_backend.json \
        --out experiments/tune/my_backend.json
"""
from __future__ import annotations

import argparse
from pathlib import Path
from typing import Tuple

from repro.core.split_policy import available_policies
from repro.tune import (
    REFERENCE_SPEC,
    REFERENCE_TABLE_PATH,
    Calibrator,
    SplitTable,
    TABLE_DIR,
    TuneSpec,
)


def _parse_heads(items) -> Tuple[Tuple[int, int, int], ...]:
    out = []
    for it in items:
        try:
            hq, hkv, hd = (int(x) for x in it.split(":"))
        except ValueError:
            raise SystemExit(f"--heads wants HQ:HKV:HEAD_DIM, got {it!r}")
        out.append((hq, hkv, hd))
    return tuple(out)


def run_tune(spec: TuneSpec, *, mode: str = "auto", seed: int = 0,
             out: Path, merge: Path | None = None,
             log_fn=print) -> SplitTable:
    log_fn(f"calibrating {spec.grid_size()} grid cells "
           f"(mode={mode}, repeats={spec.repeats}, seed={seed}) ...")
    table = Calibrator(spec, mode=mode, seed=seed).calibrate()
    if merge is not None:
        base = SplitTable.load(merge)
        log_fn(f"merging into {merge} ({len(base)} cells, "
               f"version {base.version})")
        table = base.merge(table)
        table.validate()
    path = table.save(out)
    d = table.describe()
    log_fn(f"wrote {path}: {d['cells']} cells / {d['families']} shape "
           f"families, version {d['version']}")
    log_fn(f"fingerprint: {table.fingerprint}")
    by_split: dict = {}
    for e in table.entries:
        by_split[e["best_split"]] = by_split.get(e["best_split"], 0) + 1
    log_fn("decision histogram (num_splits -> cells): "
           f"{dict(sorted(by_split.items()))}")
    log_fn(f"serve from it: python -m repro.launch.serve --arch "
           f"qwen2.5-3b --policy measured --tune-table {path}")
    return table


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=f"registered split policies: {available_policies()} "
               "(this tool feeds the 'measured' backend)")
    ap.add_argument("--reference", action="store_true",
                    help="calibrate the REFERENCE grid in modeled mode "
                         "and write the committed reference table "
                         f"({REFERENCE_TABLE_PATH})")
    ap.add_argument("--out", type=Path, default=None,
                    help="output table path (default: "
                         "experiments/tune/split_table.json)")
    ap.add_argument("--merge", type=Path, default=None,
                    help="existing table to merge the new cells into "
                         "(new cells win; schema must match)")
    ap.add_argument("--mode", default="auto",
                    choices=("auto", "wallclock", "modeled"),
                    help="timing mode: wallclock on real backends, "
                         "modeled = deterministic analytic surrogate "
                         "(auto: modeled on CPU hosts)")
    ap.add_argument("--lk", type=int, nargs="+", default=None,
                    help="L_K grid (multiples of 128)")
    ap.add_argument("--batches", type=int, nargs="+", default=None)
    ap.add_argument("--heads", nargs="+", default=None,
                    metavar="HQ:HKV:HEAD_DIM",
                    help="head shapes, e.g. 64:1:128 16:2:128")
    ap.add_argument("--impl", nargs="+", default=None,
                    choices=("xla", "pallas"),
                    help="kernel impls to calibrate (default: xla)")
    ap.add_argument("--candidates", type=int, nargs="+", default=None,
                    help="explicit candidate split counts "
                         "(default: every feasible split)")
    ap.add_argument("--num-cores", type=int, default=None,
                    help="parallel grid slots the modeled mode targets")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timing repeats per candidate (median taken)")
    ap.add_argument("--warmup", type=int, default=None,
                    help="warmup launches discarded before timing")
    ap.add_argument("--budget-s", type=float, default=None,
                    help="global wall-clock cap; past it, remaining "
                         "cells degrade to the analytic model")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.reference:
        spec, mode, seed = REFERENCE_SPEC, "modeled", 0
        out = args.out or REFERENCE_TABLE_PATH
        overridden = [f for f, v in (
            ("--lk", args.lk), ("--batches", args.batches),
            ("--heads", args.heads), ("--impl", args.impl),
            ("--candidates", args.candidates), ("--merge", args.merge),
            ("--num-cores", args.num_cores), ("--repeats", args.repeats),
            ("--warmup", args.warmup), ("--budget-s", args.budget_s),
            ("--mode", None if args.mode == "auto" else args.mode),
            ("--seed", args.seed or None),
        ) if v is not None]
        if overridden:
            raise SystemExit(
                "--reference fixes the grid, mode=modeled and seed=0 so "
                "the committed table stays reproducible; drop "
                f"{overridden} (or run without --reference)")
    else:
        over = {k: v for k, v in dict(
            lk_buckets=tuple(args.lk) if args.lk else None,
            batches=tuple(args.batches) if args.batches else None,
            head_shapes=_parse_heads(args.heads) if args.heads else None,
            impls=tuple(args.impl) if args.impl else None,
            candidates=(tuple(args.candidates) if args.candidates
                        else None),
            num_cores=args.num_cores,
            repeats=args.repeats,
            warmup=args.warmup,
            budget_s=args.budget_s,
        ).items() if v is not None}
        spec, mode, seed = TuneSpec(**over), args.mode, args.seed
        out = args.out or TABLE_DIR / "split_table.json"
    run_tune(spec, mode=mode, seed=seed, out=out, merge=args.merge)


if __name__ == "__main__":
    main()
