"""Split-KV combine kernel: merge S unnormalized partials (paper stage 2).

On GPU FA3 this stage runs with atomics/semaphores into the output
buffer; on TPU it is a small deterministic reduction kernel — grid over
``(B, H_kv)``, each cell loads its S partials from HBM into VMEM, merges
them with the LSE algebra in f32, and writes one normalized output tile.
Bitwise-reproducible for any split count (the fixed reduction order).

The ``ops``-level decode path uses the jnp combine (XLA fuses it well);
this kernel exists for the TPU-native pipeline where the partials never
round-trip through f32 HBM tensors owned by XLA — and as the reference
for the VMEM budget note in DESIGN.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.compat import tpu_compiler_params

from repro.kernels.ref import NEG_INF


def _combine_kernel(acc_ref,          # (S, 1, 1, G, D) f32
                    l_ref,            # (S, 1, 1, G, LANES) f32
                    m_ref,            # (S, 1, 1, G, LANES) f32
                    o_ref,            # (1, 1, G, D)
                    *, num_splits: int):
    acc = acc_ref[:, 0, 0]                       # (S, G, D)
    l = l_ref[:, 0, 0, :, 0]                     # (S, G)
    m = m_ref[:, 0, 0, :, 0]                     # (S, G)

    m_glob = jnp.max(m, axis=0)                  # (G,)
    w = jnp.exp(m - m_glob[None])                # (S, G)
    num = jnp.sum(acc * w[..., None], axis=0)    # (G, D)
    den = jnp.sum(l * w, axis=0)                 # (G,)
    out = num / jnp.maximum(den[:, None], 1e-30)
    o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_combine(
    acc: jax.Array,          # (S, B, Hkv, G, D) f32 unnormalized
    l: jax.Array,            # (S, B, Hkv, G) f32
    m: jax.Array,            # (S, B, Hkv, G) f32
    *,
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    """-> (B, Hkv, G, D) normalized attention output."""
    S, B, Hkv, G, D = acc.shape
    LANES = 128
    # stats lane-replicated for TPU layout (same trick as flash_decode)
    l_r = jnp.broadcast_to(l[..., None], (S, B, Hkv, G, LANES))
    m_r = jnp.broadcast_to(m[..., None], (S, B, Hkv, G, LANES))

    kernel = functools.partial(_combine_kernel, num_splits=S)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((S, 1, 1, G, D), lambda b, h: (0, b, h, 0, 0)),
            pl.BlockSpec((S, 1, 1, G, LANES),
                         lambda b, h: (0, b, h, 0, 0)),
            pl.BlockSpec((S, 1, 1, G, LANES),
                         lambda b, h: (0, b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), out_dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name=f"flash_combine_s{S}",
    )(acc, l_r, m_r)
    return out
