"""Split-KV Flash-Decoding Pallas TPU kernel (the paper's target kernel).

Grid layout ``(B, H_KV, S, NB)``:

- ``B, H_KV, S`` are *parallel* dimensions — the work tiles the scheduler
  distributes; ``S`` is the sequence-split axis the paper's policy sizes.
- ``NB`` (KV blocks within one split) is the innermost *arbitrary*
  dimension: a float32 running-softmax state lives in VMEM scratch and is
  carried across NB steps (classic flash accumulation).

GQA packing (the paper's ``pack_gqa=True``): the ``G = H_Q/H_KV`` query
heads of one group ride the MXU M-dimension as a single ``(G, D) @ (D, BK)``
matmul — one tile per (batch, kv-head) instead of G.

Each (b, h, s) cell emits an *unnormalized* partial ``(acc, l, m)``; a
separate LSE-combine stage merges the S partials.  On GPU FA3 this combine
uses atomics/semaphores; on TPU it is a deterministic reduction — decode
results are bitwise-reproducible for any split count (tested).

VMEM budget per grid cell (bf16 K/V, f32 state):
``2*BK*D*2 + G*D*4 + 2*G*128*4 + G*D*4`` — for BK=128, D=128, G=8:
~70 KiB, far under the ~1 MiB/cell needed to double-buffer in 128 MiB VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.compat import tpu_compiler_params

from repro.kernels.ref import NEG_INF

DEFAULT_BLOCK_K = 128
STATS_LANES = 128            # stats stored lane-replicated for TPU layout


def _decode_kernel(
    # scalar prefetch
    kv_len_ref,              # (B,) int32 in SMEM
    # inputs
    q_ref,                   # (1, 1, G, D)      — pre-scaled f32/bf16
    k_ref,                   # (1, BK, 1, D)
    v_ref,                   # (1, BK, 1, D)
    # outputs
    acc_out_ref,             # (1, 1, 1, G, D)   f32 unnormalized partial
    l_out_ref,               # (1, 1, 1, G, STATS_LANES) f32
    m_out_ref,               # (1, 1, 1, G, STATS_LANES) f32
    # scratch
    m_scr,                   # (G, STATS_LANES) f32
    l_scr,                   # (G, STATS_LANES) f32
    acc_scr,                 # (G, D) f32
    *,
    num_blocks_per_split: int,
    block_k: int,
):
    b = pl.program_id(0)
    s = pl.program_id(2)
    nb = pl.program_id(3)

    @pl.when(nb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (BK, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # (G, BK)

    # mask cache positions beyond the valid length
    blk_idx = s * num_blocks_per_split + nb
    pos = blk_idx * block_k + jax.lax.broadcasted_iota(
        jnp.int32, scores.shape, 1)                        # (G, BK)
    valid = pos < kv_len_ref[b]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_scr[:, :1]                                  # (G, 1)
    m_cur = jnp.max(scores, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(scores - m_new)
    p = jnp.where(valid, p, 0.0)                           # kill exp(-inf - -inf)
    alpha = jnp.exp(m_prev - m_new)                        # (G, 1)

    l_new = l_scr[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(nb == num_blocks_per_split - 1)
    def _flush():
        acc_out_ref[0, 0, 0] = acc_scr[...]
        l_out_ref[0, 0, 0] = l_scr[...]
        m_out_ref[0, 0, 0] = m_scr[...]


def flash_decode_partials(
    q: jax.Array,            # (B, Hkv, G, D) — already GQA-packed & scaled
    k: jax.Array,            # (B, L_pad, Hkv, D)
    v: jax.Array,
    kv_len: jax.Array,       # (B,) int32
    *,
    num_splits: int,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    """Run the split-KV kernel; returns unnormalized partials.

    Returns ``(acc, l, m)`` with shapes ``(S,B,Hkv,G,D)``, ``(S,B,Hkv,G)``,
    ``(S,B,Hkv,G)`` matching :func:`repro.kernels.ref.lse_combine`.
    """
    B, Hkv, G, D = q.shape
    _, L, _, _ = k.shape
    S = num_splits
    assert L % block_k == 0, f"pad L ({L}) to block_k ({block_k})"
    nblk = L // block_k
    assert nblk % S == 0, f"pad blocks ({nblk}) to splits ({S})"
    NB = nblk // S

    kernel = functools.partial(
        _decode_kernel, num_blocks_per_split=NB, block_k=block_k)

    grid = (B, Hkv, S, NB)
    acc, l, m = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, s, nb, kvl: (b, h, 0, 0)),
                pl.BlockSpec((1, block_k, 1, D),
                             lambda b, h, s, nb, kvl: (b, s * NB + nb, h, 0)),
                pl.BlockSpec((1, block_k, 1, D),
                             lambda b, h, s, nb, kvl: (b, s * NB + nb, h, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, 1, G, D),
                             lambda b, h, s, nb, kvl: (b, h, s, 0, 0)),
                pl.BlockSpec((1, 1, 1, G, STATS_LANES),
                             lambda b, h, s, nb, kvl: (b, h, s, 0, 0)),
                pl.BlockSpec((1, 1, 1, G, STATS_LANES),
                             lambda b, h, s, nb, kvl: (b, h, s, 0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((G, STATS_LANES), jnp.float32),
                pltpu.VMEM((G, STATS_LANES), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, S, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, S, G, STATS_LANES), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, S, G, STATS_LANES), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
        name=f"flash_decode_s{S}",
    )(kv_len.astype(jnp.int32), q, k, v)

    # -> (S, B, Hkv, G, ...) layout expected by lse_combine
    acc = acc.transpose(2, 0, 1, 3, 4)
    l = l[..., 0].transpose(2, 0, 1, 3)
    m = m[..., 0].transpose(2, 0, 1, 3)
    return acc, l, m


# ---------------------------------------------------------------------------
# Fused low-precision variant: int8/fp8 KV blocks dequantized in-register
# ---------------------------------------------------------------------------


def _decode_quant_kernel(
    # scalar prefetch
    kv_len_ref,              # (B,) int32 in SMEM
    # inputs
    q_ref,                   # (1, 1, G, D)      — pre-scaled f32/bf16
    k_ref,                   # (1, BK, 1, D)     int8 / float8_e4m3fn
    v_ref,                   # (1, BK, 1, D)     int8 / float8_e4m3fn
    ks_ref,                  # (1, BK, 1) f32    per-(row, head) scales
    vs_ref,                  # (1, BK, 1) f32
    # outputs
    acc_out_ref,             # (1, 1, 1, G, D)   f32 unnormalized partial
    l_out_ref,               # (1, 1, 1, G, STATS_LANES) f32
    m_out_ref,               # (1, 1, 1, G, STATS_LANES) f32
    # scratch
    m_scr,                   # (G, STATS_LANES) f32
    l_scr,                   # (G, STATS_LANES) f32
    acc_scr,                 # (G, D) f32
    *,
    num_blocks_per_split: int,
    block_k: int,
):
    """:func:`_decode_kernel` with in-register dequant of quantized KV.

    The ONLY difference from the bf16 kernel is the two
    ``astype(f32) * scale`` lines — HBM streams 1 byte/element plus a
    4-byte scale per (row, head) (a ``4/D`` fraction, ~3% at D=128), and
    the rest of the flash accumulation is bit-identical to attending the
    dequantized arrays.  Scales of unallocated tail rows are masked by
    the same ``pos < kv_len`` predicate as the data, so poisoned (finite)
    page tails never reach the output.
    """
    b = pl.program_id(0)
    s = pl.program_id(2)
    nb = pl.program_id(3)

    @pl.when(nb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)
    ks = ks_ref[0, :, 0]                                   # (BK,)
    vs = vs_ref[0, :, 0]
    # in-register dequant: same transform as Quantizer.dequantize
    k = k_ref[0, :, 0, :].astype(jnp.float32) * ks[:, None]  # (BK, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32) * vs[:, None]

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # (G, BK)

    blk_idx = s * num_blocks_per_split + nb
    pos = blk_idx * block_k + jax.lax.broadcasted_iota(
        jnp.int32, scores.shape, 1)                        # (G, BK)
    valid = pos < kv_len_ref[b]
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_scr[:, :1]                                  # (G, 1)
    m_cur = jnp.max(scores, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(scores - m_new)
    p = jnp.where(valid, p, 0.0)                           # kill exp(-inf - -inf)
    alpha = jnp.exp(m_prev - m_new)                        # (G, 1)

    l_new = l_scr[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(nb == num_blocks_per_split - 1)
    def _flush():
        acc_out_ref[0, 0, 0] = acc_scr[...]
        l_out_ref[0, 0, 0] = l_scr[...]
        m_out_ref[0, 0, 0] = m_scr[...]


def flash_decode_quant_partials(
    q: jax.Array,            # (B, Hkv, G, D) — already GQA-packed & scaled
    k: jax.Array,            # (B, L_pad, Hkv, D) int8 / float8_e4m3fn
    v: jax.Array,
    k_scale: jax.Array,      # (B, L_pad, Hkv) f32
    v_scale: jax.Array,
    kv_len: jax.Array,       # (B,) int32
    *,
    num_splits: int,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
):
    """Split-KV kernel over a quantized cache; unnormalized partials.

    Same grid, accumulation and return layout as
    :func:`flash_decode_partials`; K/V blocks arrive in storage dtype and
    are dequantized in-register against their per-row scale blocks.
    """
    B, Hkv, G, D = q.shape
    _, L, _, _ = k.shape
    S = num_splits
    assert L % block_k == 0, f"pad L ({L}) to block_k ({block_k})"
    nblk = L // block_k
    assert nblk % S == 0, f"pad blocks ({nblk}) to splits ({S})"
    NB = nblk // S

    kernel = functools.partial(
        _decode_quant_kernel, num_blocks_per_split=NB, block_k=block_k)

    grid = (B, Hkv, S, NB)
    acc, l, m = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, s, nb, kvl: (b, h, 0, 0)),
                pl.BlockSpec((1, block_k, 1, D),
                             lambda b, h, s, nb, kvl: (b, s * NB + nb, h, 0)),
                pl.BlockSpec((1, block_k, 1, D),
                             lambda b, h, s, nb, kvl: (b, s * NB + nb, h, 0)),
                pl.BlockSpec((1, block_k, 1),
                             lambda b, h, s, nb, kvl: (b, s * NB + nb, h)),
                pl.BlockSpec((1, block_k, 1),
                             lambda b, h, s, nb, kvl: (b, s * NB + nb, h)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, 1, G, D),
                             lambda b, h, s, nb, kvl: (b, h, s, 0, 0)),
                pl.BlockSpec((1, 1, 1, G, STATS_LANES),
                             lambda b, h, s, nb, kvl: (b, h, s, 0, 0)),
                pl.BlockSpec((1, 1, 1, G, STATS_LANES),
                             lambda b, h, s, nb, kvl: (b, h, s, 0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((G, STATS_LANES), jnp.float32),
                pltpu.VMEM((G, STATS_LANES), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, S, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, S, G, STATS_LANES), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, S, G, STATS_LANES), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
        name=f"flash_decode_quant_s{S}",
    )(kv_len.astype(jnp.int32), q, k, v,
      k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))

    acc = acc.transpose(2, 0, 1, 3, 4)
    l = l[..., 0].transpose(2, 0, 1, 3)
    m = m[..., 0].transpose(2, 0, 1, 3)
    return acc, l, m
