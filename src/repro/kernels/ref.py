"""Pure-jnp reference oracles for the attention kernels.

Three tiers:

- ``naive_*``: O(L^2)-materializing einsum attention.  Ground truth for
  tiny test shapes only.
- ``flash_attention_xla``: blocked two-level-scan flash attention in pure
  jnp — differentiable, memory-safe (never materializes more than a
  (block_q, block_k) score tile), and shardable under pjit.  This is the
  default ``attention_impl="xla"`` path used by train/prefill steps, and
  the oracle the Pallas prefill kernel is tested against.
- ``split_decode_xla``: decode attention computed as S explicit partial
  softmaxes + LSE combine, in pure jnp.  The split count changes the
  *schedule*, never the math — the oracle for the Pallas decode kernel,
  and the XLA decode path whose sharding the mesh-level split uses.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # finite -inf stand-in: keeps masked softmax NaN-free


# ---------------------------------------------------------------------------
# Naive oracles
# ---------------------------------------------------------------------------


def naive_attention(
    q: jax.Array,          # (B, Lq, Hq, D)
    k: jax.Array,          # (B, Lk, Hkv, D)
    v: jax.Array,          # (B, Lk, Hkv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """Materializing attention. ``q_offset``: absolute position of q[:, 0].

    ``v`` may have a different head dim than q/k (MLA: v_head_dim 64 vs
    qk dim 96) — the output head dim follows v.
    """
    B, Lq, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, Lq, Hkv, g, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf)
    qpos = jnp.arange(Lq)[:, None] + q_offset
    kpos = jnp.arange(Lk)[None, :]
    mask = jnp.ones((Lq, Lk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(B, Lq, Hq, Dv).astype(q.dtype)


def naive_decode_attention(
    q: jax.Array,          # (B, Hq, D) — single new token
    k: jax.Array,          # (B, Lk, Hkv, D) — cache (padded)
    v: jax.Array,
    kv_len: jax.Array,     # (B,) int32 — valid cache lengths
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, D)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qf, k.astype(jnp.float32))
    valid = jnp.arange(Lk)[None, :] < kv_len[:, None]          # (B, Lk)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Blocked flash attention (differentiable XLA path + prefill oracle)
# ---------------------------------------------------------------------------


def flash_attention_xla(
    q: jax.Array,          # (B, Lq, Hq, D)
    k: jax.Array,          # (B, Lk, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    block_q: int = 512,
    block_k: int = 512,
    scale: Optional[float] = None,
) -> jax.Array:
    """Flash-style attention in pure jnp: scan over KV blocks per Q block.

    Peak live score tile is (block_q, block_k); the outer q-block loop and
    inner k-block loop are both ``lax`` control flow so XLA keeps the
    memory bound under pjit and remat policies apply cleanly.
    """
    B, Lq, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Lq)
    block_k = min(block_k, Lk)
    # pad sequence dims to block multiples
    pq = (-Lq) % block_q
    pk = (-Lk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    Lqp, Lkp = Lq + pq, Lk + pk
    nq, nk = Lqp // block_q, Lkp // block_k

    qf = (qp.astype(jnp.float32) * scale).reshape(B, nq, block_q, Hkv, g, D)
    kf = kp.astype(jnp.float32).reshape(B, nk, block_k, Hkv, D)
    vf = vp.astype(jnp.float32).reshape(B, nk, block_k, Hkv, Dv)

    kpos_all = jnp.arange(Lkp).reshape(nk, block_k)

    def q_block(iq, q_blk):
        # q_blk: (B, block_q, Hkv, g, D)
        qpos = iq * block_q + jnp.arange(block_q) + q_offset    # (bq,)

        def kv_block(carry, ik):
            m, l, acc = carry
            kb = kf[:, ik]                                      # (B, bk, Hkv, D)
            vb = vf[:, ik]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, kb)
            kpos = kpos_all[ik]
            msk = kpos[None, :] < Lk                            # padding
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                msk &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb)
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, block_q), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, block_q, Dv), jnp.float32)
        q_blk_t = q_blk.transpose(0, 2, 3, 1, 4)                # unused; kept for clarity
        del q_blk_t
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out                                               # (B,Hkv,g,bq,D)

    outs = jax.lax.map(lambda iq: q_block(iq, qf[:, iq]), jnp.arange(nq))
    # (nq, B, Hkv, g, bq, D) -> (B, nq*bq, Hq, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Lqp, Hq, Dv)
    return out[:, :Lq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Split-KV decode: partials + LSE combine (the paper's technique, in jnp)
# ---------------------------------------------------------------------------


def decode_partial(
    q: jax.Array,          # (B, Hkv, g, D) f32, pre-scaled
    k_chunk: jax.Array,    # (B, C, Hkv, D)
    v_chunk: jax.Array,    # (B, C, Hkv, D)
    valid: jax.Array,      # (B, C) bool
):
    """One split's unnormalized partial: (acc, l, m)."""
    s = jnp.einsum("bhgd,bkhd->bhgk", q, k_chunk.astype(jnp.float32))
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    m = s.max(axis=-1)                                          # (B,Hkv,g)
    # fully-masked chunk: keep m at NEG_INF, p underflows to 0
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None], p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p, v_chunk.astype(jnp.float32))
    return acc, l, m


def lse_combine(accs: jax.Array, ls: jax.Array, ms: jax.Array) -> jax.Array:
    """Merge S unnormalized partials. accs: (S,B,H,g,D), ls/ms: (S,B,H,g)."""
    m_glob = ms.max(axis=0)                                     # (B,H,g)
    w = jnp.exp(ms - m_glob[None])                              # (S,B,H,g)
    num = (accs * w[..., None]).sum(axis=0)
    den = (ls * w).sum(axis=0)
    return num / jnp.maximum(den[..., None], 1e-30)


def split_decode_xla(
    q: jax.Array,          # (B, Hq, D)
    k: jax.Array,          # (B, Lk, Hkv, D) padded cache
    v: jax.Array,
    kv_len: jax.Array,     # (B,) int32
    num_splits: int,
    *,
    scale: Optional[float] = None,
    shard_split: Optional[callable] = None,
) -> jax.Array:
    """Decode attention as ``num_splits`` explicit partials + LSE combine.

    The split axis is a real array axis, so under pjit it can be assigned a
    mesh axis — this is the mesh-level incarnation of the paper's heuristic.
    Output is bitwise-independent of ``num_splits`` up to float tolerance
    (property-tested).
    """
    B, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    s = max(1, min(num_splits, Lk))
    # pad Lk to a multiple of s
    pad = (-Lk) % s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    C = (Lk + pad) // s
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, D)
    kc = k.reshape(B, s, C, Hkv, D).transpose(1, 0, 2, 3, 4)     # (S,B,C,H,D)
    vc = v.reshape(B, s, C, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    if shard_split is not None:
        # mesh-level split: pin the S axis to a mesh axis so every chip
        # owns S/axis local splits; the LSE combine's sums over S lower
        # to the collectives the roofline measures.
        kc, vc = shard_split(kc), shard_split(vc)
    pos = jnp.arange(Lk + pad).reshape(s, C)                     # (S,C)
    valid = pos[:, None, :] < kv_len[None, :, None]              # (S,B,C)

    accs, ls, ms = jax.vmap(
        lambda kci, vci, vldi: decode_partial(qf, kci, vci, vldi)
    )(kc, vc, valid)
    out = lse_combine(accs, ls, ms)                              # (B,Hkv,g,Dv)
    return out.reshape(B, Hq, Dv).astype(q.dtype)


def verify_decode_xla(
    q: jax.Array,          # (B, M, Hq, D) — k+1-row verify query block
    k: jax.Array,          # (B, Lk, Hkv, D) padded cache (block written)
    v: jax.Array,
    pos: jax.Array,        # (B,) int32 — absolute position of q[:, 0]
    num_splits: int,
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Speculative-verify attention: split-KV decode per query row.

    Query row ``j`` of slot ``b`` sits at absolute position
    ``pos[b] + j`` and attends keys ``<= pos[b] + j`` — causal *within*
    the block, full-prefix outside it.  Computed as a vmap of
    :func:`split_decode_xla` over the row axis with per-row
    ``kv_len = pos + j + 1``, so every row reduces with exactly the
    schedule (and float accumulation order) of the single-row decode
    path it replaces, just with the verify plan's split count.
    """
    B, M, Hq, D = q.shape
    Lk = k.shape[1]

    def row(qj: jax.Array, j: jax.Array) -> jax.Array:
        lenj = jnp.clip(pos.astype(jnp.int32) + j + 1, 1, Lk)
        return split_decode_xla(qj, k, v, lenj, num_splits, scale=scale)

    out = jax.vmap(row, in_axes=(1, 0), out_axes=1)(
        q, jnp.arange(M, dtype=jnp.int32))
    return out                                                   # (B,M,Hq,Dv)
