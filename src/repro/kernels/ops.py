"""Jit-facing dispatch wrappers around the attention kernels.

Two execution paths per op, selected by ``impl``:

- ``"xla"``    — the pure-jnp reference implementations from ``ref.py``.
  Cost-analyzable, differentiable, shardable under pjit; the default for
  train/dry-run (on this CPU container it is also the fast path).
- ``"pallas"`` — the Pallas TPU kernels (``interpret=True`` on CPU).  The
  TPU-native hot path; numerics validated against ``ref.py`` in tests.

``decode_attention`` is the op the paper targets: its split count comes
from a frozen :class:`~repro.plan.LaunchPlan` (the paper's
"metadata-enabled path") — passed explicitly via ``plan=`` / legacy
``metadata=``, or injected ambiently by the serve-step builder through
:func:`repro.plan.plan_scope`.  With no frozen plan in reach, the policy
runs at trace time (the paper's weaker "internal heuristic path") using
the policy/num_cores overrides of whatever context-only plan applies.

The old ``DecodeContext`` / ``AttnContext`` dual context stacks are
deprecated shims over the single ``plan_scope`` stack; they keep old
call sites importing (with a ``DeprecationWarning``) but new code should
push a ``LaunchPlan``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.scheduler_metadata import get_scheduler_metadata
from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode_partials
from repro.kernels.flash_prefill import flash_prefill
from repro.plan import LaunchPlan, current_plan, plan_scope

_DEFAULT_POLICY = "paper"


# ---------------------------------------------------------------------------
# Deprecated context shims (pre-repro.plan API)
# ---------------------------------------------------------------------------


def DecodeContext(policy: str = _DEFAULT_POLICY,
                  num_cores: Optional[int] = None,
                  metadata: Optional[LaunchPlan] = None,
                  min_splits: int = 1,
                  split_constraint: Optional[Callable] = None,
                  seq_shard_mesh: Optional[object] = None,
                  seq_shard_axis: str = "model") -> LaunchPlan:
    """Deprecated: build a :class:`repro.plan.LaunchPlan` instead."""
    warnings.warn(
        "ops.DecodeContext is deprecated; build a repro.plan.LaunchPlan "
        "(via Planner) and enter it with repro.plan.plan_scope",
        DeprecationWarning, stacklevel=2)
    base = metadata if metadata is not None else LaunchPlan(kind="decode")
    return dataclasses.replace(
        base, kind="decode", policy=policy,
        num_cores=num_cores if num_cores is not None else base.num_cores,
        min_splits=min_splits, split_constraint=split_constraint,
        seq_shard_mesh=seq_shard_mesh, seq_shard_axis=seq_shard_axis)


def AttnContext(seq_shard_mesh: Optional[object] = None,
                seq_shard_axis: str = "model") -> LaunchPlan:
    """Deprecated: build a prefill-kind :class:`repro.plan.LaunchPlan`."""
    warnings.warn(
        "ops.AttnContext is deprecated; build a prefill-kind "
        "repro.plan.LaunchPlan and enter it with repro.plan.plan_scope",
        DeprecationWarning, stacklevel=2)
    return LaunchPlan(kind="prefill", seq_shard_mesh=seq_shard_mesh,
                      seq_shard_axis=seq_shard_axis)


def decode_context(ctx: LaunchPlan):
    """Deprecated alias of :func:`repro.plan.plan_scope`."""
    return plan_scope(ctx)


def attention_context(ctx: LaunchPlan):
    """Deprecated alias of :func:`repro.plan.plan_scope`."""
    return plan_scope(ctx)


def current_decode_context() -> LaunchPlan:
    """Deprecated: the ambient decode plan (or an empty one)."""
    plan = current_plan("decode")
    return plan if plan is not None else LaunchPlan(kind="decode")


def current_attention_context() -> LaunchPlan:
    """Deprecated: the ambient prefill plan (or an empty one)."""
    plan = current_plan("prefill")
    return plan if plan is not None else LaunchPlan(kind="prefill")


# ---------------------------------------------------------------------------
# Observability: in-dispatch policy evaluations
# ---------------------------------------------------------------------------

# How many times the split policy ran INSIDE a decode-attention dispatch
# (the paper's weaker "internal heuristic path").  Happens at trace time
# only — num_splits is static — so a jitted metadata-enabled step must
# leave this untouched; tests and benchmarks assert exactly that.
_POLICY_EVALS: int = 0

# The plan the most recent inline evaluation resolved to (regression
# surface for the scope-precedence rules; trace-time only, like the
# counter above).
_LAST_INLINE: Optional[LaunchPlan] = None


def policy_eval_count() -> int:
    return _POLICY_EVALS


def reset_policy_eval_count() -> None:
    global _POLICY_EVALS, _LAST_INLINE
    _POLICY_EVALS = 0
    _LAST_INLINE = None


def last_inline_plan() -> Optional[LaunchPlan]:
    """The frozen plan produced by the most recent in-dispatch policy
    evaluation (None if every launch so far consumed a precomputed plan)."""
    return _LAST_INLINE


def _resolve_policy(scope: Optional[LaunchPlan], plan: Optional[LaunchPlan],
                    policy: str, num_cores: Optional[int]):
    """Policy/num_cores precedence for the inline-heuristic path.

    Call-site kwargs are the base; the ambient scope overrides them; an
    explicit (context-only) plan overrides the scope.  An override's
    policy applies whenever it was deliberately set — i.e. it differs
    from the default OR its num_cores is pinned.  (The old DecodeContext
    keyed the policy override off ``num_cores is not None`` alone, so a
    context with ``policy="tpu_adaptive"`` but no num_cores was silently
    ignored.)
    """
    pol, cores = policy, num_cores
    for over in (scope, plan):
        if over is None:
            continue
        if over.num_cores is not None:
            cores = over.num_cores
        if over.policy != _DEFAULT_POLICY or over.num_cores is not None:
            pol = over.policy
    return pol, cores


# ---------------------------------------------------------------------------
# Paged-KV layout: the layout-aware gather path (repro.cache)
# ---------------------------------------------------------------------------


class PagedKV(NamedTuple):
    """A paged view of one K or V cache tensor, in place of a dense
    ``(B, L, H, D)`` array.

    ``pages`` is the shared page pool ``(P, page, *rest)``; ``page_table``
    maps each batch slot to its pages ``(B, >= num_pages) int32``; and
    ``num_pages`` is the STATIC number of pages the launch attends over
    (the resident-length bucket divided by the page size) — jitted
    callers specialize on it, exactly like ``num_splits``.  Table entries
    past a slot's allocation point at a trash page whose rows sit at
    positions >= the slot's ``kv_len`` and are therefore masked.
    """
    pages: jax.Array
    page_table: jax.Array
    num_pages: int

    @property
    def view_len(self) -> int:
        return self.num_pages * self.pages.shape[1]


def gather_pages(pages: jax.Array, page_table: jax.Array, *,
                 num_pages: int, axis: int = 0) -> jax.Array:
    """Gather a dense per-slot view from a page pool.

    ``pages``: ``(..., P, page, *rest)`` with the pool dim at ``axis``;
    ``page_table``: ``(B, >= num_pages) int32``.  Returns
    ``(..., B, num_pages * page, *rest)`` — the first ``num_pages`` pages
    of every slot, concatenated in sequence order.
    """
    pt = jax.lax.slice_in_dim(page_table, 0, num_pages, axis=1)
    g = jnp.take(pages, pt, axis=axis)       # (..., B, n, page, *rest)
    shape = (g.shape[:axis + 1]
             + (num_pages * pages.shape[axis + 1],)
             + g.shape[axis + 3:])
    return g.reshape(shape)


def scatter_pages(pages: jax.Array, view: jax.Array,
                  page_table: jax.Array, *, num_pages: int,
                  axis: int = 0) -> jax.Array:
    """Write a dense per-slot view back into the page pool (inverse of
    :func:`gather_pages`).

    Duplicate table entries (every slot's unallocated tail points at the
    shared trash page) make that one page's content nondeterministic —
    harmless, since trash rows are masked by ``kv_len`` everywhere.
    """
    pt = jax.lax.slice_in_dim(page_table, 0, num_pages, axis=1)
    page = pages.shape[axis + 1]
    vp = view.reshape(view.shape[:axis]
                      + (pt.shape[0], num_pages, page)
                      + view.shape[axis + 2:])
    idx = (slice(None),) * axis + (pt,)
    return pages.at[idx].set(vp.astype(pages.dtype))


def _resolve_paged(x):
    """Dense array -> itself; :class:`PagedKV` -> gathered dense view."""
    if isinstance(x, PagedKV):
        return gather_pages(x.pages, x.page_table, num_pages=x.num_pages)
    return x


# ---------------------------------------------------------------------------
# Full-sequence (train / prefill) attention
# ---------------------------------------------------------------------------


def attention(
    q: jax.Array,            # (B, Lq, Hq, D)
    k: jax.Array,            # (B, Lk, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int | jax.Array = 0,
    impl: str = "xla",
    interpret: bool = True,
) -> jax.Array:
    """Full (training / prefill) attention.

    An ambient prefill-kind :class:`LaunchPlan` (``plan_scope``) with
    ``seq_shard_mesh`` turns on sequence-parallel attention: the QUERY
    rows shard over ``seq_shard_axis`` and each chip runs blocked flash
    on its chunk with the right ``q_offset`` (K/V stay whole).  This is
    the §Perf fix for head counts that don't divide the model axis
    (MiniCPM3: 40, Whisper: 20).
    """
    scope = current_plan("prefill")
    if (scope is not None and scope.seq_shard_mesh is not None
            and impl in ("xla", "naive") and isinstance(q_offset, int)):
        mesh = scope.seq_shard_mesh
        n = mesh.shape[scope.seq_shard_axis]
        if q.shape[1] % n == 0 and q.shape[1] >= 2 * n:
            return _attention_seqpar(
                q, k, v, causal=causal, window=window, q_offset=q_offset,
                mesh=mesh, axis=scope.seq_shard_axis, impl=impl)
    if impl == "pallas":
        if not isinstance(q_offset, int):
            raise ValueError("pallas prefill path needs a static q_offset")
        return flash_prefill(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, interpret=interpret)
    if impl == "naive":
        return ref.naive_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
    return ref.flash_attention_xla(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)


def _attention_seqpar(q, k, v, *, causal, window, q_offset, mesh,
                      axis: str, impl: str = "xla") -> jax.Array:
    """Sequence-parallel blocked attention: q rows sharded over ``axis``,
    each chip runs local flash on its chunk with the global offset."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, Lq, Hq, D = q.shape
    n = mesh.shape[axis]
    C = Lq // n
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = data_axes if (data_axes and B % _prod(
        mesh.shape[a] for a in data_axes) == 0) else None

    def body(qc, kf, vf):
        i = jax.lax.axis_index(axis)
        # dynamic global offset of this chunk's first query row
        off = q_offset + i * C
        if impl == "naive":                  # probe path: exact counting
            return ref.naive_attention(qc, kf, vf, causal=causal,
                                       window=window, q_offset=off)
        return ref.flash_attention_xla(qc, kf, vf, causal=causal,
                                       window=window, q_offset=off)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, axis, None, None),
                  P(bspec, None, None, None),
                  P(bspec, None, None, None)),
        out_specs=P(bspec, axis, None, None),
        check_rep=False)
    return fn(q, k, v)


# ---------------------------------------------------------------------------
# Split-KV decode attention
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,            # (B, Hq, D) — one new token per sequence
    k: jax.Array,            # (B, Lk, Hkv, D) padded KV cache
    v: jax.Array,
    kv_len: jax.Array,       # (B,) int32 valid lengths
    *,
    k_scale: Optional[jax.Array] = None,   # (B, Lk, Hkv) quantized-KV scales
    v_scale: Optional[jax.Array] = None,
    plan: Optional[LaunchPlan] = None,
    metadata: Optional[LaunchPlan] = None,   # legacy alias of ``plan``
    use_ctx_metadata: bool = True,
    policy: str = _DEFAULT_POLICY,
    num_cores: Optional[int] = None,
    impl: str = "xla",
    interpret: bool = True,
    scale: Optional[float] = None,
) -> jax.Array:
    """Split-KV decode attention, split count from the paper's policy.

    A frozen ``plan`` (precomputed :class:`LaunchPlan`) is the paper's
    fast path; otherwise the policy runs at trace time (internal-
    heuristic path).  ``num_splits`` is always a static Python int, so
    XLA / Pallas specialize the schedule on it — changing the policy
    changes the *compiled program*, which is exactly what the dry-run
    measures.

    An ambient plan (:func:`repro.plan.plan_scope`, set by the serving
    engine / serve-step builder) supplies the frozen decision when no
    explicit one is passed, overrides policy / num_cores for inline
    evaluation, and can pin the split axis onto a mesh axis (mesh-level
    sequence split of the KV cache).  ``use_ctx_metadata=False`` opts a
    differently-shaped launch (e.g. encdec cross-attention) out of the
    ambient frozen plan.

    ``k`` / ``v`` may also be :class:`PagedKV` views (the
    ``repro.cache`` paged layout): the launch then attends over the
    gathered resident pages — ``L_K`` is the resident-length bucket, not
    the padded slot capacity, so the split decision and the HBM traffic
    both track what is actually resident.

    Quantized caches (``repro.quant``): pass the per-(row, head) scales
    via ``k_scale`` / ``v_scale`` (dense or ``PagedKV`` views — the
    scale pools page with the data pools).  ``impl="pallas"`` then runs
    the fused kernel (storage-dtype KV blocks dequantized in-register);
    the xla/naive impls dequantize up front and attend the f32 arrays —
    the dequant-then-attend reference the fused path is A/B'd against.
    """
    k = _resolve_paged(k)
    v = _resolve_paged(v)
    k_scale = _resolve_paged(k_scale)
    v_scale = _resolve_paged(v_scale)
    scope = current_plan("decode")
    if plan is None:
        plan = metadata
    if (plan is None or not plan.frozen) and use_ctx_metadata \
            and scope is not None and scope.frozen:
        plan = scope

    B, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    if plan is not None and plan.impl is not None:
        impl = plan.impl
    if plan is None or not plan.frozen:
        global _POLICY_EVALS, _LAST_INLINE
        _POLICY_EVALS += 1
        pol, cores = _resolve_policy(scope, plan, policy, num_cores)
        kwargs = {} if cores is None else {"num_cores": cores}
        plan = get_scheduler_metadata(B, 1, Lk, Hq, Hkv, D, policy=pol,
                                      **kwargs)
        _LAST_INLINE = plan
    s = max(1, min(plan.num_splits, Lk))
    min_splits = max(plan.min_splits,
                     scope.min_splits if scope is not None else 1)
    if min_splits > 1:
        # mesh-level split: round s up to a multiple of the sharded axis so
        # the S axis shards evenly (serving pads caches so min_splits | Lk)
        s = -(-s // min_splits) * min_splits
        s = min(s, Lk)
    split_constraint = plan.split_constraint
    if split_constraint is None and scope is not None:
        split_constraint = scope.split_constraint

    if impl == "pallas":
        assert scale is None, "pallas path computes its own scale"
        if k_scale is not None:
            return _decode_pallas_quant(
                q, k, v, k_scale, v_scale, kv_len, num_splits=s,
                block_k=plan.block_k, interpret=interpret)
        return _decode_pallas(q, k, v, kv_len, num_splits=s,
                              block_k=plan.block_k, interpret=interpret)
    if k_scale is not None:
        # unfused reference: materialize the dequantized cache, then
        # attend it (bit-identical to Quantizer.dequantize + attend)
        k = k.astype(jnp.float32) * k_scale[..., None]
        v = v.astype(jnp.float32) * v_scale[..., None]
    if impl == "naive":
        return ref.naive_decode_attention(q, k, v, kv_len, scale=scale)
    return ref.split_decode_xla(q, k, v, kv_len, s, scale=scale,
                                shard_split=split_constraint)


def decode_attention_quant(
    q: jax.Array,            # (B, Hq, D)
    qkv,                     # repro.quant.QuantizedKV (leaves may be PagedKV)
    kv_len: jax.Array,       # (B,) int32
    **kw,
) -> jax.Array:
    """Split-KV decode over a quantized cache artifact.

    Thin entry point for :class:`repro.quant.QuantizedKV` (or any
    4-sequence ``(k, v, k_scale, v_scale)``): one plan-resolution path
    with :func:`decode_attention`, so quantized launches consume frozen
    plans / ambient scopes / inline policy evaluation identically to
    bf16 ones — the split decision differs only through the workload's
    ``dtype_bytes`` / ``kv_dtype`` family.
    """
    k, v, k_scale, v_scale = qkv
    return decode_attention(q, k, v, kv_len,
                            k_scale=k_scale, v_scale=v_scale, **kw)


def verify_attention(
    q: jax.Array,            # (B, M, Hq, D) — k+1-row verify query block
    k: jax.Array,            # (B, Lk, Hkv, D) cache (or PagedKV view)
    v: jax.Array,
    pos: jax.Array,          # (B,) int32 absolute position of q[:, 0]
    *,
    plan: Optional[LaunchPlan] = None,
    use_ctx_metadata: bool = True,
    policy: str = _DEFAULT_POLICY,
    num_cores: Optional[int] = None,
    impl: str = "xla",
    scale: Optional[float] = None,
) -> jax.Array:
    """Speculative-decoding verify attention: one planned launch scoring
    a block of ``M = k + 1`` query rows per slot (the committed current
    token + k drafts), causal *within* the block at the slot's traced
    absolute offset, full prefix outside it.

    Plans come from the same surfaces as :func:`decode_attention` — an
    explicit frozen ``plan`` (the serving engine's
    ``("verify", k, bucket)`` entries) or the ambient decode-family
    scope; with neither, the split policy runs at trace time on the
    M-row workload and counts as an in-dispatch policy evaluation.
    The k-row query block scales ``num_m_blocks``, so the sequence-aware
    policy sees the occupancy shift speculation buys — that is the
    planning-side point of the verify kind.

    ``pos`` is traced (per-slot offsets differ in a lockstep batch), so
    the pallas/seqpar impls — which need static offsets — fall back to
    the xla reference, mirroring ``attention_suffix_prefill``.
    """
    k = _resolve_paged(k)
    v = _resolve_paged(v)
    scope = current_plan("decode")
    if (plan is None or not plan.frozen) and use_ctx_metadata \
            and scope is not None and scope.frozen:
        plan = scope

    B, M, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    if plan is not None and plan.impl is not None:
        impl = plan.impl
    if plan is None or not plan.frozen:
        global _POLICY_EVALS, _LAST_INLINE
        _POLICY_EVALS += 1
        pol, cores = _resolve_policy(scope, plan, policy, num_cores)
        kwargs = {} if cores is None else {"num_cores": cores}
        plan = get_scheduler_metadata(B, M, Lk, Hq, Hkv, D, policy=pol,
                                      **kwargs)
        _LAST_INLINE = plan
    s = max(1, min(plan.num_splits, Lk))
    if impl in ("pallas", "seqpar"):
        impl = "xla"                     # traced per-slot offsets
    if impl == "naive":
        tv = pos.astype(jnp.int32)
        lens = tv[:, None] + jnp.arange(M, dtype=jnp.int32)[None, :] + 1

        def row(qj, lenj):
            return ref.naive_decode_attention(
                qj, k, v, jnp.clip(lenj, 1, Lk), scale=scale)

        return jax.vmap(row, in_axes=(1, 1), out_axes=1)(q, lens)
    return ref.verify_decode_xla(q, k, v, pos, s, scale=scale)


def decode_attention_update(
    q: jax.Array,            # (B, Hq, Dq) — new token's queries (UNscaled)
    cache_k: jax.Array,      # (B, L, Hkv, Dk)
    cache_v: Optional[jax.Array],   # (B, L, Hkv, Dv) or None (MLA: v ⊂ k)
    k_new: jax.Array,        # (B, Hkv, Dk)
    v_new: Optional[jax.Array],
    t: jax.Array,            # (B,) int32 write positions
    kv_len: jax.Array,       # (B,) int32 valid lengths AFTER the write
    *,
    v_width: Optional[int] = None,  # MLA: v = k[..., :v_width]
    scale: Optional[float] = None,
    plan: Optional[LaunchPlan] = None,
    metadata: Optional[LaunchPlan] = None,   # legacy alias of ``plan``
    use_ctx_metadata: bool = True,
    policy: str = _DEFAULT_POLICY,
    num_cores: Optional[int] = None,
    impl: Optional[str] = None,     # None = xla (a plan's impl overrides)
    quant: Optional[dict] = None,   # quantized cache: {"k_s","v_s","k_ns","v_ns"}
) -> tuple:
    """Fused cache-write + split decode attention.

    Default path: functional update then :func:`decode_attention` (GSPMD
    decides the collectives).  When the ambient plan has
    ``seq_shard_mesh``, the fused shard_map path runs instead: each chip
    writes only its own cache shard and computes a partial softmax over
    it; partials merge with a psum/pmax LSE combine — the paper's
    split-KV combine as explicit mesh collectives.

    Returns (out (B, Hq, Dv), new_cache_k, new_cache_v).
    """
    scope = current_plan("decode")
    if plan is None:
        plan = metadata
    # explicit plan overrides the ambient scope (same precedence as
    # decode_attention); a plan without a mesh defers to the scope
    if plan is not None and plan.seq_shard_mesh is not None:
        shard = plan
    else:
        shard = scope
    if shard is not None and shard.seq_shard_mesh is not None:
        return _decode_seqsharded(
            q, cache_k, cache_v, k_new, v_new, t, kv_len,
            mesh=shard.seq_shard_mesh, axis=shard.seq_shard_axis,
            v_width=v_width, scale=scale, quant=quant)

    # functional update + policy-split attention (auto-SPMD path)
    def upd(c, new, ti):
        return jax.lax.dynamic_update_slice(
            c, new[None].astype(c.dtype),
            (ti, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)))

    def upd2(c, new, ti):
        return jax.lax.dynamic_update_slice(
            c, new[None].astype(c.dtype), (ti, jnp.zeros((), jnp.int32)))

    cache_k = jax.vmap(upd)(cache_k, k_new, t)
    if cache_v is not None:
        cache_v = jax.vmap(upd)(cache_v, v_new, t)
    if quant is not None:
        k_s = jax.vmap(upd2)(quant["k_s"], quant["k_ns"], t)
        v_s = jax.vmap(upd2)(quant["v_s"], quant["v_ns"], t)
        # scales ride into decode_attention: xla/naive dequantize up
        # front (the old dequant-then-attend, numerics unchanged) while
        # a plan carrying impl="pallas" hits the fused in-register path
        out = decode_attention(q, cache_k, cache_v, kv_len,
                               k_scale=k_s, v_scale=v_s,
                               scale=scale, plan=plan,
                               use_ctx_metadata=use_ctx_metadata,
                               policy=policy, num_cores=num_cores,
                               impl=impl or "xla")
        return out, cache_k, cache_v, k_s, v_s
    v_used = cache_v if cache_v is not None else cache_k[..., :v_width]
    out = decode_attention(q, cache_k, v_used, kv_len, scale=scale,
                           plan=plan,
                           use_ctx_metadata=use_ctx_metadata,
                           policy=policy, num_cores=num_cores,
                           impl=impl or "xla")
    return out, cache_k, cache_v


def _decode_seqsharded(q, cache_k, cache_v, k_new, v_new, t, kv_len, *,
                       mesh, axis: str, v_width: Optional[int],
                       scale: Optional[float],
                       quant: Optional[dict] = None) -> tuple:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, Hq, Dq = q.shape
    _, L, Hkv, Dk = cache_k.shape
    g = Hq // Hkv
    n = mesh.shape[axis]
    assert L % n == 0, f"cache len {L} must divide the {axis} axis ({n})"
    C = L // n
    scale = scale if scale is not None else Dq ** -0.5
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = data_axes if (data_axes and B % _prod(
        mesh.shape[a] for a in data_axes) == 0) else None

    cache_spec = P(bspec, axis, None, None)
    sc_spec = P(bspec, axis, None)
    vec_spec = P(bspec, None, None)
    hvec_spec = P(bspec, None)
    scal_spec = P(bspec)

    def upd(c, new, ti, ok):
        zeros = (jnp.zeros((), jnp.int32),) * (c.ndim - 1)
        newc = jax.lax.dynamic_update_slice(
            c, new[None].astype(c.dtype), (ti,) + zeros)
        return jnp.where(ok, newc, c)

    def core(qb, kf, vf, lenb, i):
        qf = (qb.astype(jnp.float32) * scale).reshape(-1, Hkv, g, Dq)
        pos = i * C + jnp.arange(C)                       # global positions
        valid = pos[None, :] < lenb[:, None]              # (B_loc, C)
        acc, l, m = ref.decode_partial(qf, kf, vf, valid)
        m_glob = jax.lax.pmax(m, axis)
        w = jnp.exp(m - m_glob)
        num = jax.lax.psum(acc * w[..., None], axis)
        den = jax.lax.psum(l * w, axis)
        out = num / jnp.maximum(den[..., None], 1e-30)
        Dv = out.shape[-1]
        return out.reshape(-1, Hq, Dv).astype(qb.dtype)

    def body(qb, kc, vc, kn, vn, tb, lenb):
        # kc: (B_loc, C, Hkv, Dk) — this chip's sequence shard
        i = jax.lax.axis_index(axis)
        local_t = tb - i * C                              # (B_loc,)
        in_range = (local_t >= 0) & (local_t < C)
        lt = jnp.clip(local_t, 0, C - 1)
        kc = jax.vmap(upd)(kc, kn, lt, in_range)
        if vc is not None:
            vc = jax.vmap(upd)(vc, vn, lt, in_range)
            vloc = vc
        else:
            vloc = kc[..., :v_width]
        return core(qb, kc, vloc, lenb, i), kc, vc

    def body_q(qb, kc, vc, ksc, vsc, kn, vn, kns, vns, tb, lenb):
        # int8 cache: scales ride along; dequant happens shard-locally
        # (HBM reads stay int8 — the memory-roofline win)
        from repro.models.attention import dequantize_kv
        i = jax.lax.axis_index(axis)
        local_t = tb - i * C
        in_range = (local_t >= 0) & (local_t < C)
        lt = jnp.clip(local_t, 0, C - 1)
        kc = jax.vmap(upd)(kc, kn, lt, in_range)
        vc = jax.vmap(upd)(vc, vn, lt, in_range)
        ksc = jax.vmap(upd)(ksc, kns, lt, in_range)
        vsc = jax.vmap(upd)(vsc, vns, lt, in_range)
        kf = dequantize_kv(kc, ksc)
        vf = dequantize_kv(vc, vsc)
        return core(qb, kf, vf, lenb, i), kc, vc, ksc, vsc

    if quant is not None:
        fn = shard_map(
            body_q, mesh=mesh,
            in_specs=(vec_spec, cache_spec, cache_spec, sc_spec, sc_spec,
                      vec_spec, vec_spec, hvec_spec, hvec_spec,
                      scal_spec, scal_spec),
            out_specs=(vec_spec, cache_spec, cache_spec, sc_spec, sc_spec),
            check_rep=False)
        return fn(q, cache_k, cache_v, quant["k_s"], quant["v_s"],
                  k_new, v_new, quant["k_ns"], quant["v_ns"], t, kv_len)

    if cache_v is not None:
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(vec_spec, cache_spec, cache_spec, vec_spec,
                      vec_spec, scal_spec, scal_spec),
            out_specs=(vec_spec, cache_spec, cache_spec),
            check_rep=False)
        return fn(q, cache_k, cache_v, k_new, v_new, t, kv_len)

    def body_nov(qb, kc, kn, tb, lenb):
        o, ck, _ = body(qb, kc, None, kn, None, tb, lenb)
        return o, ck

    fn = shard_map(
        body_nov, mesh=mesh,
        in_specs=(vec_spec, cache_spec, vec_spec, scal_spec, scal_spec),
        out_specs=(vec_spec, cache_spec),
        check_rep=False)
    out, ck = fn(q, cache_k, k_new, t, kv_len)
    return out, ck, None


def _prod(it) -> int:
    r = 1
    for x in it:
        r *= x
    return r


def _decode_pallas(q, k, v, kv_len, *, num_splits: int,
                   block_k: Optional[int] = None,
                   interpret: bool) -> jax.Array:
    """GQA-pack, pad, run the Pallas split kernel, LSE-combine."""
    from repro.kernels.flash_decode import DEFAULT_BLOCK_K

    B, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = D ** -0.5
    qp = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, D)

    block_k = min(block_k or DEFAULT_BLOCK_K, Lk)
    # pad cache so blocks divide evenly into splits
    blocks = -(-Lk // block_k)
    blocks = -(-blocks // num_splits) * num_splits
    Lp = blocks * block_k
    if Lp != Lk:
        k = jnp.pad(k, ((0, 0), (0, Lp - Lk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Lp - Lk), (0, 0), (0, 0)))

    acc, l, m = flash_decode_partials(
        qp.astype(q.dtype), k, v, kv_len, num_splits=num_splits,
        block_k=block_k, interpret=interpret)
    from repro.kernels.flash_combine import flash_combine
    out = flash_combine(acc, l, m, interpret=interpret)  # (B, Hkv, g, D)
    return out.reshape(B, Hq, D).astype(q.dtype)


def _decode_pallas_quant(q, k, v, k_scale, v_scale, kv_len, *,
                         num_splits: int, block_k: Optional[int] = None,
                         interpret: bool) -> jax.Array:
    """Quantized-cache twin of :func:`_decode_pallas`: GQA-pack, pad the
    storage-dtype cache AND its scale leaves, run the fused in-register
    dequant kernel, LSE-combine.  Padded tail rows carry zero scales but
    are masked by ``kv_len`` regardless (the repo-wide invariant)."""
    from repro.kernels.flash_decode import (DEFAULT_BLOCK_K,
                                            flash_decode_quant_partials)

    B, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = D ** -0.5
    qp = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, D)

    block_k = min(block_k or DEFAULT_BLOCK_K, Lk)
    blocks = -(-Lk // block_k)
    blocks = -(-blocks // num_splits) * num_splits
    Lp = blocks * block_k
    if Lp != Lk:
        pad4 = ((0, 0), (0, Lp - Lk), (0, 0), (0, 0))
        k = jnp.pad(k, pad4)
        v = jnp.pad(v, pad4)
        k_scale = jnp.pad(k_scale, pad4[:3])
        v_scale = jnp.pad(v_scale, pad4[:3])

    acc, l, m = flash_decode_quant_partials(
        qp.astype(q.dtype), k, v, k_scale, v_scale, kv_len,
        num_splits=num_splits, block_k=block_k, interpret=interpret)
    from repro.kernels.flash_combine import flash_combine
    out = flash_combine(acc, l, m, interpret=interpret)  # (B, Hkv, g, D)
    return out.reshape(B, Hq, D).astype(q.dtype)
