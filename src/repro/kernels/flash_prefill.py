"""Blocked causal flash-attention forward Pallas kernel (prefill/training fwd).

Grid ``(B, H_Q, NQ, NK)`` — NK innermost ("arbitrary") carries the running
softmax state in VMEM scratch; B/H/NQ are parallel tiles.  GQA is handled
by indexing the KV head ``h // group`` in the BlockSpec index map (no KV
replication in HBM).  Supports causal masking, local windows
(RecurrentGemma) and a static ``q_offset`` for chunked prefill.

Out-of-range blocks (fully above the causal diagonal / outside the window)
still DMA their KV tile but skip the FLOPs via ``pl.when`` — acceptable for
a forward demonstration kernel; the XLA path is used where autodiff or
block-sparse skipping matters (see DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.compat import tpu_compiler_params

from repro.kernels.ref import NEG_INF

STATS_LANES = 128


def _prefill_kernel(
    q_ref,                   # (1, BQ, 1, D) pre-scaled
    k_ref,                   # (1, BK, 1, D)
    v_ref,                   # (1, BK, 1, D)
    o_ref,                   # (1, BQ, 1, D)
    m_scr, l_scr, acc_scr,   # (BQ, STATS_LANES) x2, (BQ, D)
    *,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    seqlen_k: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = iq * block_q + q_offset          # absolute first q position
    k_lo = ik * block_k

    # static-shape bounds check is dynamic on grid ids -> use pl.when
    needed = jnp.bool_(True)
    if causal:
        needed &= k_lo <= q_lo + block_q - 1
    if window is not None:
        needed &= k_lo + block_k - 1 > q_lo - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (BQ, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (BK, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seqlen_k
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = jnp.broadcast_to(
            l_scr[:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True),
            l_scr.shape)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ik == num_k_blocks - 1)
    def _flush():
        out = acc_scr[...] / jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_prefill(
    q: jax.Array,            # (B, Lq, Hq, D)
    k: jax.Array,            # (B, Lk, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    scale: Optional[float] = None,
    interpret: bool = True,
) -> jax.Array:
    B, Lq, Hq, D = q.shape
    _, Lk, Hkv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    block_q = min(block_q, max(8, Lq))
    block_k = min(block_k, Lk)
    pq, pk = (-Lq) % block_q, (-Lk) % block_k
    qs = (q.astype(jnp.float32) * scale).astype(q.dtype)
    if pq:
        qs = jnp.pad(qs, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    NQ, NK = (Lq + pq) // block_q, (Lk + pk) // block_k

    kernel = functools.partial(
        _prefill_kernel, block_q=block_q, block_k=block_k, num_k_blocks=NK,
        causal=causal, window=window, q_offset=q_offset, seqlen_k=Lk)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, NQ, NK),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, iq, ik, g=group: (b, ik, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, iq, ik, g=group: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Lq + pq, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, STATS_LANES), jnp.float32),
            pltpu.VMEM((block_q, STATS_LANES), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
        name="flash_prefill",
    )(qs, k, v)
    return out[:, :Lq]
