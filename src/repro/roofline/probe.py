"""Probe-based exact cost accounting for scanned programs.

XLA's ``cost_analysis`` counts a while-loop body ONCE regardless of trip
count (verified experimentally in this repo: an 8-step scan reports 1/8
of the unrolled FLOPs).  The production programs scan over layers, so raw
numbers from the full compile are wrong by ~num_layers.

Fix: compile two PROBE variants of the same cell with the layer loop
**unrolled** (``scan_layers=False``) at 1 and 2 superblocks, naive
attention (no inner scans) and unrolled SSD chunk scans — their
difference isolates the exact per-superblock cost, and

    corrected = C1 - body + total_trips * body * adjustments

Adjustments applied analytically (documented in EXPERIMENTS.md):
- train remat ``nothing_saveable``: backward recomputes the forward
  body -> matmul-ish FLOPs x 4/3 over the no-remat probe (fwd+bwd = 3
  fwd-equivalents -> 4).
- microbatching (M > 1): per-layer FSDP param collectives (all-gather /
  reduce-scatter) happen once per microbatch -> x M; activation-sized
  collectives (all-reduce / all-to-all) track tokens -> unchanged;
  param-read bytes x M (layer param bytes known exactly from the specs).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro import compat
from repro.configs.base import (
    ModelConfig,
    OptimizerConfig,
    ServeConfig,
    ShapeConfig,
    TrainConfig,
)
from repro.models.common import is_spec
from repro.roofline.hlo import collective_bytes

Pytree = Any


@dataclass
class ProbeCost:
    flops: float
    bytes: float
    coll: Dict[str, float]

    def sub(self, o: "ProbeCost") -> "ProbeCost":
        return ProbeCost(self.flops - o.flops, self.bytes - o.bytes,
                         {k: self.coll.get(k, 0) - o.coll.get(k, 0)
                          for k in set(self.coll) | set(o.coll)})


def _pattern_len(cfg: ModelConfig) -> int:
    return len(cfg.hybrid.pattern) if cfg.family == "hybrid" else 1


def _probe_cfg(cfg: ModelConfig, n_super: int, kind: str) -> ModelConfig:
    pl = _pattern_len(cfg)
    kw = dict(
        num_layers=n_super * pl,
        scan_layers=False,
        probe_unroll=True,
        # naive attention has no inner scans -> exact counting; decode uses
        # the real split path (its collectives ARE the measurement)
        attention_impl="naive" if kind != "decode" else "xla",
    )
    if cfg.family == "encdec":
        kw["num_encoder_layers"] = n_super
        kw["num_layers"] = n_super
    return cfg.replace(**kw)


def _measure(arch_cfg: ModelConfig, shape: ShapeConfig, mesh, policy: str
             ) -> ProbeCost:
    """Lower+compile one probe variant; extract flops/bytes/collectives."""
    from repro.models.registry import Model
    from repro.serving.decode_step import build_mesh_decode_step, build_prefill_step
    from repro.training.train_step import build_train_step

    model = Model(arch_cfg)
    if shape.kind == "train":
        tcfg = TrainConfig(model=arch_cfg, shape=shape,
                           optimizer=OptimizerConfig(),
                           microbatches=1, remat_policy="none")
        bundle = build_train_step(model, tcfg, mesh)
    elif shape.kind == "prefill":
        scfg = ServeConfig(model=arch_cfg, shape=shape, split_policy=policy)
        bundle = build_prefill_step(model, scfg, mesh)
    else:
        scfg = ServeConfig(model=arch_cfg, shape=shape, split_policy=policy)
        bundle = build_mesh_decode_step(model, scfg, mesh)
    compiled = bundle.step.lower(*bundle.abstract_args()).compile()
    cost = compat.cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())
    return ProbeCost(float(cost.get("flops", 0.0)),
                     float(cost.get("bytes accessed", 0.0)),
                     {k: float(v) for k, v in coll.items()})


def layer_param_bytes(cfg: ModelConfig) -> float:
    """bf16 bytes of ONE superblock's params (for the micro correction)."""
    from repro.models.lm import block_specs, layer_groups
    import jax

    if cfg.family == "encdec":
        from repro.models.encdec import _dec_block_specs, _enc_block_specs
        specs = {"e": _enc_block_specs(cfg), "d": _dec_block_specs(cfg)}
    else:
        pattern = layer_groups(cfg)[0][0]
        specs = {f"k{i}": block_specs(cfg, k)
                 for i, k in enumerate(pattern)}
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return float(sum(int(np.prod(s.shape)) * 2 for s in leaves))


@dataclass
class CorrectedCost:
    flops: float                       # per-device
    bytes: float
    coll: Dict[str, float]
    trips: float
    body: ProbeCost
    nonloop: ProbeCost


def attention_stream_bytes(cfg: ModelConfig, shape: ShapeConfig, mesh,
                           *, block_q: int = 512) -> float:
    """Analytic per-device K/V/Q streaming bytes of ONE superblock's
    attention at full sequence (train/prefill).

    The flash-xla probe undercounts these (its KV loop body is counted
    once); everything it streams is re-derived here: each of ``nq`` query
    blocks re-reads K and V (causal ~halves it), Q and the output are
    touched once.
    """
    if cfg.family == "ssm":
        return 0.0
    ndev = mesh.devices.size
    model_ax = mesh.shape["model"]
    data_sz = ndev // model_ax
    B, L = shape.global_batch, shape.seq_len
    b_dev = B // data_sz if B % data_sz == 0 else B
    dt = 2  # bf16
    # heads that don't divide the axis run sequence-parallel attention
    # (ops.AttnContext): each chip streams K/V for its OWN q chunk only
    seqpar = cfg.num_heads % model_ax != 0

    def one_attn(lq, lk, hq, hkv, dqk, dv, causal, window=None):
        hq_d = hq // model_ax if hq % model_ax == 0 else hq
        hkv_d = hkv // model_ax if hkv % model_ax == 0 else hkv
        nq = -(-lq // block_q)
        if seqpar and lq % model_ax == 0:
            nq = max(1, nq // model_ax)
        lk_eff = min(lk, (window or lk) + block_q)
        cf = 0.5 if (causal and window is None and lq == lk) else 1.0
        kv = nq * lk_eff * hkv_d * (dqk + dv) * dt * cf
        qo = lq * hq_d * (dqk + dv) * dt / (model_ax if seqpar else 1)
        return b_dev * (kv + qo)

    hd = cfg.resolved_head_dim
    total = 0.0
    if cfg.family == "encdec":
        # encoder self (bidirectional) + decoder self + cross, one of each
        T = cfg.encoder_positions
        total += one_attn(T, T, cfg.num_heads, cfg.num_kv_heads, hd, hd,
                          causal=False)
        total += one_attn(L, L, cfg.num_heads, cfg.num_kv_heads, hd, hd,
                          causal=True)
        total += one_attn(L, T, cfg.num_heads, cfg.num_kv_heads, hd, hd,
                          causal=False)
        return total
    if cfg.mla is not None:
        m = cfg.mla
        dqk = m.qk_nope_head_dim + m.qk_rope_head_dim
        return one_attn(L, L, cfg.num_heads, cfg.num_heads, dqk,
                        m.v_head_dim, causal=True)
    if cfg.family == "hybrid":
        # one windowed attention per superblock (pattern has 1 attn layer)
        n_attn = sum(1 for k in cfg.hybrid.pattern if k == "attn")
        return n_attn * one_attn(L, L, cfg.num_heads, cfg.num_kv_heads,
                                 hd, hd, causal=True,
                                 window=cfg.hybrid.window)
    return one_attn(L, L, cfg.num_heads, cfg.num_kv_heads, hd, hd,
                    causal=True)


def _sharded_bytes_per_device(specs: Pytree, mesh, rules) -> float:
    """Exact per-device bytes of a spec tree under the given rules."""
    import jax
    from repro.sharding.rules import spec_for

    total = 0.0
    for s in jax.tree_util.tree_leaves(specs, is_leaf=is_spec):
        nbytes = float(np.prod(s.shape)) * np.dtype(s.jdtype).itemsize
        pspec = spec_for(s.shape, s.axes, rules, mesh)
        shards = 1
        for entry in pspec:
            if entry is None:
                continue
            for ax in ((entry,) if isinstance(entry, str) else entry):
                shards *= mesh.shape[ax]
        total += nbytes / shards
    return total


# modeled activation touches per layer per forward pass (reads+writes of
# (tokens, d_model)-sized tensors through norms/projections/residuals)
_ACT_TOUCHES = {"dense": 16, "vlm": 16, "moe": 28, "mla": 22,
                "ssm": 30, "hybrid": 20, "encdec": 24}


def analytic_memory_bytes(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                          microbatches: int, kind: str,
                          seq_split: bool = False,
                          kv_dtype: str = "bfloat16") -> float:
    """Modeled per-device HBM bytes for one step (see EXPERIMENTS.md).

    CPU-backend ``bytes accessed`` reflects the weakly-fused CPU HLO (15x
    the TPU traffic in our measurements), so the memory term is modeled:
    parameter passes (FSDP-gathered for train, TP-resident for serve),
    optimizer state, activation touches with remat, attention streaming,
    logits/loss, and KV-cache traffic — all from the specs, exactly.
    """
    import jax
    from repro.models.registry import Model
    from repro.serving.decode_step import serve_param_rules
    from repro.sharding.rules import cache_rules, param_rules

    model = Model(cfg)
    ndev = mesh.devices.size
    model_ax = mesh.shape["model"]
    data_sz = ndev // model_ax
    B, L = shape.global_batch, shape.seq_len
    tokens_dev = B * L / data_sz if kind != "decode" else B / data_sz
    d = cfg.d_model
    vshard = cfg.vocab_size / (model_ax if cfg.vocab_size % model_ax == 0
                               else 1)
    specs = model.param_specs()
    touches = _ACT_TOUCHES.get(cfg.family, 16)

    if kind == "train":
        M = max(1, microbatches)
        # FSDP: every device materializes+reads the FULL layer params per
        # microbatch per pass (fwd, remat-fwd, bwd)
        p_full = float(sum(np.prod(s.shape) * 2 for s, _ in
                           _iter_specs_bytes(specs)))
        param_traffic = 3.0 * M * p_full
        p_dev = _sharded_bytes_per_device(specs, mesh, param_rules())
        opt_traffic = 6.0 * p_dev * 2.0     # m, v, p read+write (f32~2xbf16)
        act = tokens_dev * d * 2 * touches * cfg.num_layers * 3.0
        attn = attention_stream_bytes(cfg, shape, mesh) \
            * (cfg.num_layers / _pattern_len(cfg)) * 3.0
        loss = tokens_dev * vshard * 4 * 4.0
        return param_traffic + opt_traffic + act + attn + loss

    p_dev = _sharded_bytes_per_device(specs, mesh, serve_param_rules())
    if cfg.moe is not None and kind == "decode":
        # decode touches only the routed experts' weights
        frac = min(1.0, B * cfg.moe.top_k / cfg.moe.num_experts)
        p_dev *= max(frac, 0.1)
    cache = _sharded_bytes_per_device(
        model.cache_specs(B, max(L, 1), kv_dtype), mesh,
        cache_rules(seq_split))

    if kind == "prefill":
        act = tokens_dev * d * 2 * touches * cfg.num_layers
        attn = attention_stream_bytes(cfg, shape, mesh) \
            * (cfg.num_layers / _pattern_len(cfg))
        return p_dev + act + attn + cache + tokens_dev / L * vshard * 4
    # decode: read params + read whole cache + write one entry
    act = tokens_dev * d * 2 * touches * cfg.num_layers
    return p_dev + cache + act + B / data_sz * vshard * 4


def _iter_specs_bytes(specs):
    import jax
    for s in jax.tree_util.tree_leaves(specs, is_leaf=is_spec):
        yield s, s.axes


def corrected_cost(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                   policy: str = "paper", microbatches: int = 1,
                   remat: bool = True, seq_split: bool = False,
                   kv_dtype: str = "bfloat16") -> CorrectedCost:
    """FLOPs from the unrolled naive-attention probe pair (loop-free ->
    exact); collectives from the flash-attention probe pair (naive
    probes materialize L^2 score tensors that GSPMD then reshards —
    64 GiB phantom all-gathers measured on the MoE cell); memory term
    from the analytic model (CPU bytes-accessed reflects weak CPU
    fusion, not TPU HBM traffic).
    """
    cA1 = _measure(_probe_cfg(cfg, 1, shape.kind), shape, mesh, policy)
    cA2 = _measure(_probe_cfg(cfg, 2, shape.kind), shape, mesh, policy)
    bodyA = cA2.sub(cA1)
    nonloopA = cA1.sub(bodyA)

    if shape.kind != "decode":
        fl1 = dataclasses.replace(_probe_cfg(cfg, 1, shape.kind),
                                  attention_impl="xla")
        fl2 = dataclasses.replace(_probe_cfg(cfg, 2, shape.kind),
                                  attention_impl="xla")
        cB1 = _measure(fl1, shape, mesh, policy)
        cB2 = _measure(fl2, shape, mesh, policy)
        bodyC = cB2.sub(cB1)
        nonloopC = cB1.sub(bodyC)
    else:
        bodyC, nonloopC = bodyA, nonloopA

    pl = _pattern_len(cfg)
    trips = cfg.num_layers / pl        # fractional remainder approximated

    is_train = shape.kind == "train"
    remat_f = (4.0 / 3.0) if (is_train and remat) else 1.0
    M = max(1, microbatches) if is_train else 1

    flops = nonloopA.flops + trips * bodyA.flops * remat_f
    bytes_ = analytic_memory_bytes(cfg, shape, mesh, microbatches=M,
                                   kind=shape.kind, seq_split=seq_split,
                                   kv_dtype=kv_dtype)

    coll: Dict[str, float] = {}
    for cat in set(bodyC.coll) | set(nonloopC.coll):
        b = bodyC.coll.get(cat, 0.0)
        if cat in ("all-gather", "reduce-scatter") and M > 1 and is_train:
            b *= M
        coll[cat] = nonloopC.coll.get(cat, 0.0) + trips * b
    return CorrectedCost(flops, bytes_, coll, trips, bodyA, nonloopA)
