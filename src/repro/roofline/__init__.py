"""Roofline: 3-term analysis from compiled dry-runs + probes."""
from repro.roofline.analysis import (  # noqa: F401
    HBM_BW,
    ICI_LINK_BW,
    PEAK_FLOPS_BF16,
    RooflineReport,
    analyze,
    model_flops_for,
)
from repro.roofline.hlo import collective_bytes, wire_bytes  # noqa: F401
