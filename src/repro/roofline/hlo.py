"""Collective-byte accounting from compiled (post-SPMD) HLO text.

``compiled.as_text()`` is the per-device program after GSPMD partitioning
— every cross-chip transfer appears as an explicit collective op.  We sum
result-shape bytes per collective category; ``cost_analysis`` does not
report these, so this parser feeds the roofline's collective term.

Wire-byte model (ring algorithms, documented approximation):
    all-gather / reduce-scatter / all-to-all / collective-permute:
        ~= result bytes (x (n-1)/n ~ 1)
    all-reduce: ~= 2 x operand bytes (reduce-scatter + all-gather phases)
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# shapes like  bf16[2,4096]{1,0}  or f32[] ; tuples are handled by findall
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# an op line:  %name.123 = <shape or tuple> opcode(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\]{},\s]+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-category result bytes (per device) of every collective op."""
    out: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        shape_text, opcode = m.group(1), m.group(2)
        # async pairs: count the -start, skip the matching -done (same
        # shape appears twice otherwise)
        line_start = hlo_text.rfind("\n", 0, m.start()) + 1
        line = hlo_text[line_start:hlo_text.find("\n", m.start())]
        if f"{opcode}-done" in line:
            continue
        out[opcode] += _shape_bytes(shape_text)
    return out


def wire_bytes(per_category: Dict[str, int]) -> int:
    """Modeled bytes on the wire per device (ring factors)."""
    total = 0
    for cat, b in per_category.items():
        total += 2 * b if cat == "all-reduce" else b
    return total
