"""Three-term roofline from a compiled dry-run artifact.

Per (arch x shape x mesh) we derive, from the per-device SPMD program:

    compute term    = device_FLOPs / peak_FLOP/s          (197 TF bf16, v5e)
    memory term     = device_bytes / HBM_bw               (819 GB/s)
    collective term = device_wire_bytes / link_bw         (~50 GB/s ICI)

``cost_analysis()`` provides FLOPs and bytes-accessed of the per-device
program; collective bytes come from the HLO parser.  The dominant term is
the bottleneck the §Perf loop iterates on; ``MODEL_FLOPS / HLO_FLOPs``
exposes remat/dispatch/replication waste (>1 means the compiled program
does *less* than the analytic minimum suggests — usually fused away;
<1 means redundant compute).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Any, Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import is_spec, param_count
from repro.roofline.hlo import collective_bytes, wire_bytes

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw per-device numbers
    device_flops: float
    device_bytes: float
    device_collective_bytes: float
    per_category: Dict[str, int]
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # usefulness
    model_flops_global: float
    hlo_flops_global: float
    useful_ratio: float
    # bookkeeping
    step_kind: str = "train"
    policy: Optional[str] = None
    note: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)


def active_param_count(cfg: ModelConfig, specs) -> float:
    """N (dense) or N_active (MoE: expert params scaled by top_k / E)."""
    import jax
    total = 0.0
    for leaf, axes in _iter_specs(specs):
        n = float(np.prod(leaf.shape))
        if cfg.moe is not None and "experts" in axes:
            n *= cfg.moe.top_k / cfg.moe.num_experts
        total += n
    return total


def _iter_specs(specs):
    import jax
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    for s in leaves:
        yield s, s.axes


def model_flops_for(cfg: ModelConfig, specs, *, tokens: int,
                    step_kind: str) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train, 2·N·D inference (N active)."""
    n = active_param_count(cfg, specs)
    mult = 6.0 if step_kind == "train" else 2.0
    return mult * n * tokens


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    model_flops: float,
    step_kind: str,
    policy: Optional[str] = None,
    note: str = "",
) -> RooflineReport:
    dev_flops = float(cost.get("flops", 0.0))
    dev_bytes = float(cost.get("bytes accessed", 0.0))
    per_cat = collective_bytes(hlo_text)
    dev_wire = float(wire_bytes(per_cat))

    compute_s = dev_flops / PEAK_FLOPS_BF16
    memory_s = dev_bytes / HBM_BW
    collective_s = dev_wire / ICI_LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    hlo_global = dev_flops * chips
    useful = model_flops / hlo_global if hlo_global > 0 else float("nan")

    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        device_flops=dev_flops, device_bytes=dev_bytes,
        device_collective_bytes=dev_wire, per_category=per_cat,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops_global=model_flops,
        hlo_flops_global=hlo_global, useful_ratio=useful,
        step_kind=step_kind, policy=policy, note=note)
