"""Pluggable token sampling for the serving engine.

:class:`SamplingParams` is the per-request knob set (temperature /
top-k / top-p / seed / stop tokens); a :class:`Sampler` turns a batch of
logits into a batch of tokens *inside the jitted step*.  The engine
keeps one row of sampler state per decode slot (the params as arrays
plus a per-request PRNG key) and passes the whole state dict through
the jit boundary, so changing a request's sampling params never
recompiles the step.

Determinism contract: the PRNG key is derived from the request's
``seed`` alone and folded with the *absolute position* of the sampled
token, so a request's tokens are a pure function of (params, prompt,
sampling params) — independent of which slot it lands in or how many
slots the engine runs (asserted in tests across ``batch_slots`` 1/2/4).

``temperature == 0`` is exact greedy argmax — bit-identical to the
pre-redesign engine's ``jnp.argmax`` path, which the legacy
``DecodeEngine`` wrapper relies on.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.spec import SpecConfig

Array = jax.Array
# sampler state: one row per decode slot, threaded through the jit
SamplerState = Dict[str, Array]


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs (vLLM-style).

    ``temperature == 0`` selects greedy argmax; ``top_k == 0`` and
    ``top_p == 1.0`` disable the respective truncations.  ``stop`` is a
    tuple of token ids that end the request with
    ``finish_reason="stop"`` (the stop token itself is still emitted).

    ``speculation`` opts the request into speculative decoding: a
    :class:`repro.spec.SpecConfig` naming the drafter, draft length k,
    and give-up threshold.  Validated at submit (drafter must exist in
    the registry, the model family must pass
    ``Model.supports_speculation``); ``None`` = plain decode.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    stop: Tuple[int, ...] = ()
    speculation: Optional[SpecConfig] = None

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {self.temperature}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.speculation is not None and \
                not isinstance(self.speculation, SpecConfig):
            raise TypeError(
                "SamplingParams.speculation must be a repro.spec."
                f"SpecConfig or None, got {type(self.speculation).__name__}")


GREEDY = SamplingParams()


def _mask_top_k(scaled: Array, top_k: Array) -> Array:
    """Keep each row's k largest logits (k == 0 disables). Ties at the
    threshold are kept, per the usual top-k convention."""
    V = scaled.shape[-1]
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    k = jnp.clip(jnp.where(top_k <= 0, V, top_k), 1, V)
    thresh = jnp.take_along_axis(desc, (k - 1)[:, None], axis=-1)
    return jnp.where(scaled < thresh, -jnp.inf, scaled)


def _mask_top_p(scaled: Array, top_p: Array) -> Array:
    """Nucleus truncation: keep the smallest prefix of the
    probability-sorted vocab whose mass reaches ``top_p`` (the argmax is
    always kept)."""
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = (cum - probs) < top_p[:, None]        # mass BEFORE this token
    kept = jnp.where(keep, desc, jnp.inf)
    thresh = jnp.min(kept, axis=-1, keepdims=True)
    return jnp.where(scaled < thresh, -jnp.inf, scaled)


class Sampler:
    """Base sampler: per-slot state rows + an in-jit ``sample``.

    ``slot_state(params)`` produces the host-side scalars the engine
    writes into slot ``i`` of its state arrays at admission;
    ``sample(logits, state, pos)`` runs inside the jitted step.
    Subclass (and :func:`register_sampler`) to plug in new strategies.
    """

    #: state-array layout: name -> (trailing shape, dtype)
    STATE_SPEC = {
        "temperature": ((), np.float32),
        "top_k": ((), np.int32),
        "top_p": ((), np.float32),
        "key": ((2,), np.uint32),
    }

    def init_state(self, batch_slots: int) -> Dict[str, np.ndarray]:
        """Host-side per-slot state arrays (one row per decode slot)."""
        state = {}
        for name, (shape, dtype) in self.STATE_SPEC.items():
            state[name] = np.zeros((batch_slots,) + shape, dtype)
        state["top_p"][:] = 1.0
        return state

    def slot_state(self, sp: SamplingParams) -> Dict[str, np.ndarray]:
        """One request's state row, written at slot admission."""
        return {
            "temperature": np.float32(sp.temperature),
            "top_k": np.int32(sp.top_k),
            "top_p": np.float32(sp.top_p),
            "key": np.asarray(jax.random.PRNGKey(sp.seed), np.uint32),
        }

    def check(self, sp: SamplingParams) -> None:
        """Reject params this sampler would silently ignore (called at
        ``ServingEngine.submit`` so the mismatch fails fast)."""

    def sample(self, logits: Array, state: SamplerState,
               pos: Array) -> Array:
        """logits (B, V), state rows (B, ...), pos (B,) -> tokens (B,).

        Runs at trace time inside the jitted decode/prefill step."""
        raise NotImplementedError

    def verify(self, logits: Array, draft: Array, state: SamplerState,
               pos: Array) -> Tuple[Array, Array]:
        """Batched speculative accept/reject, inside the jitted step.

        ``logits``: (B, M, V) teacher-forced verify scores — row ``j``
        is the distribution of the token at absolute position
        ``pos + j + 1``; ``draft``: (B, M - 1) proposed tokens for rows
        0..M-2 (row M-1 is the bonus row when everything accepts);
        ``pos``: (B,) absolute position of each slot's first fed row.

        Returns ``(tokens (B, M) int32, accepted (B,) int32)``:
        ``accepted`` is the longest accepted draft prefix, and
        ``tokens[b, accepted[b]]`` is the correction/bonus token the
        engine emits after the accepted drafts.  The engine clamps
        ``accepted`` by each slot's true draft length (padding rows
        must never commit).
        """
        raise NotImplementedError(
            f"{type(self).__name__} does not implement speculative "
            "verify; use GreedySampler or CategoricalSampler for "
            "requests with SamplingParams.speculation")


def _accepted_prefix(accept_rows: Array) -> Array:
    """(B, M-1) per-row accept bools -> (B,) longest-accepted-prefix."""
    return jnp.sum(jnp.cumprod(accept_rows.astype(jnp.int32), axis=1),
                   axis=1)


class GreedySampler(Sampler):
    """Pure argmax — the cheapest jitted step (no vocab sorts / PRNG).
    Rejects requests that actually ask for sampling."""

    def sample(self, logits: Array, state: SamplerState,
               pos: Array) -> Array:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def verify(self, logits: Array, draft: Array, state: SamplerState,
               pos: Array) -> Tuple[Array, Array]:
        """Longest-accepted-prefix: row j accepts iff the draft equals
        the teacher-forced argmax, so the emitted stream is bit-identical
        to sequential greedy decode by construction (the acceptance-rule
        oracle the property test drives)."""
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, M)
        accepted = _accepted_prefix(greedy[:, :-1] == draft)
        return greedy, accepted.astype(jnp.int32)

    def check(self, sp: SamplingParams) -> None:
        if sp.temperature > 0 or sp.top_k > 0 or sp.top_p < 1.0:
            raise ValueError(
                "GreedySampler ignores temperature/top_k/top_p; use "
                "CategoricalSampler (the ServingEngine default) for "
                f"sampled requests, got {sp}")


class CategoricalSampler(Sampler):
    """Temperature / top-k / top-p sampling, greedy where temp == 0.

    All three truncations compose (k then p, both over the temperature-
    scaled logits).  The greedy branch is exact ``jnp.argmax`` — rows
    with ``temperature == 0`` are bit-identical to :class:`GreedySampler`.
    """

    def sample(self, logits: Array, state: SamplerState,
               pos: Array) -> Array:
        temp = state["temperature"]
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        scaled = logits.astype(jnp.float32) / jnp.maximum(temp, 1e-6)[:, None]
        scaled = _mask_top_k(scaled, state["top_k"])
        scaled = _mask_top_p(scaled, state["top_p"])
        keys = jax.vmap(jax.random.fold_in)(state["key"],
                                            pos.astype(jnp.uint32))
        sampled = jax.vmap(jax.random.categorical)(keys, scaled)
        return jnp.where(temp <= 0.0, greedy, sampled.astype(jnp.int32))

    def verify(self, logits: Array, draft: Array, state: SamplerState,
               pos: Array) -> Tuple[Array, Array]:
        """Standard rejection sampling against the teacher-forced target.

        Our drafters propose deterministically (point-mass draft
        distribution), so the textbook rule reduces to: accept draft
        ``d`` at row ``j`` with probability ``p_j(d)`` (the masked,
        temperature-scaled target probability); on rejection, resample
        from the residual — ``p_j`` with ``d`` removed, renormalized —
        which keeps every emitted token exactly target-distributed.

        PRNG reuse: the per-row key is the request key folded with the
        row's absolute position — the same derivation ``sample`` uses —
        so the bonus row (all drafts accepted) draws the bit-identical
        token sequential decode would have drawn at that position; the
        accept coin and the residual draw fold in distinct tags so they
        never reuse a stream.  Greedy rows (``temperature == 0``) take
        the exact argmax-prefix rule instead.
        """
        B, M, V = logits.shape
        temp = state["temperature"]                              # (B,)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, M)

        scaled = logits.astype(jnp.float32) \
            / jnp.maximum(temp, 1e-6)[:, None, None]
        flat = scaled.reshape(B * M, V)
        flat = _mask_top_k(flat, jnp.repeat(state["top_k"], M))
        flat = _mask_top_p(flat, jnp.repeat(state["top_p"], M))
        scaled = flat.reshape(B, M, V)

        rows_pos = (pos[:, None].astype(jnp.uint32)
                    + jnp.arange(M, dtype=jnp.uint32)[None, :])  # (B, M)
        keys = jax.vmap(jax.vmap(jax.random.fold_in, in_axes=(None, 0)))(
            state["key"], rows_pos)                              # (B, M, 2)

        # accept coin per draft row: u < p(draft)
        probs = jax.nn.softmax(scaled, axis=-1)
        p_draft = jnp.take_along_axis(
            probs[:, :M - 1], draft[..., None], axis=-1)[..., 0]
        coin_keys = jax.vmap(jax.vmap(
            lambda kk: jax.random.fold_in(kk, jnp.uint32(0x5EC))))(
                keys[:, :M - 1])
        coin = jax.vmap(jax.vmap(jax.random.uniform))(coin_keys)
        accept_rows = coin < p_draft                             # (B, M-1)

        # correction token per draft row: residual = target minus the
        # rejected point mass, renormalized (categorical over the
        # draft-masked scaled logits)
        onehot = jax.nn.one_hot(draft, V, dtype=bool)
        resid = jnp.where(onehot, -jnp.inf, scaled[:, :M - 1])
        res_keys = jax.vmap(jax.vmap(
            lambda kk: jax.random.fold_in(kk, jnp.uint32(0x5ED))))(
                keys[:, :M - 1])
        res_tok = jax.vmap(jax.vmap(jax.random.categorical))(
            res_keys, resid).astype(jnp.int32)
        # bonus row: plain categorical with the UNsplit positional key —
        # bit-identical to what sequential decode would draw there
        bonus = jax.vmap(jax.random.categorical)(
            keys[:, M - 1], scaled[:, M - 1]).astype(jnp.int32)
        sampled = jnp.concatenate([res_tok, bonus[:, None]], axis=1)

        g = temp[:, None] <= 0.0
        tokens = jnp.where(g, greedy, sampled)
        accept_rows = jnp.where(g, greedy[:, :M - 1] == draft, accept_rows)
        accepted = _accepted_prefix(accept_rows)
        return tokens, accepted.astype(jnp.int32)


_SAMPLERS: Dict[str, Type[Sampler]] = {}


def register_sampler(name: str, cls: Type[Sampler]) -> None:
    _SAMPLERS[name] = cls


def get_sampler(name: str) -> Sampler:
    """Sampler registry: ``greedy`` | ``categorical`` (aliases
    ``temperature`` / ``top_k`` / ``top_p`` — the knobs live in
    :class:`SamplingParams`, the math in one sampler)."""
    try:
        return _SAMPLERS[name]()
    except KeyError:
        raise ValueError(f"unknown sampler {name!r}; "
                         f"known: {sorted(_SAMPLERS)}") from None


register_sampler("greedy", GreedySampler)
register_sampler("categorical", CategoricalSampler)
register_sampler("temperature", CategoricalSampler)
register_sampler("top_k", CategoricalSampler)
register_sampler("top_p", CategoricalSampler)
