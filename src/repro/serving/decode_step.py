"""Serve-step builder: policy-driven, mesh-aware batched decode.

This is the paper's insight lifted to TPU-pod scale.  At decode, the
batch rides the data axes; the **model axis** is where starvation lives:

- head-sharded KV (the fa3_baseline analogue): parallelism on the model
  axis is ``H_KV`` — an MQA/MLA model leaves 15 of 16 chips idle (or
  redundantly replicated), exactly the paper's "8 CTAs on 132 SMs".
- sequence-sharded KV (the sequence-aware path): the cache's L dim is
  sharded over the model axis, every chip computes a partial softmax
  over its shard, and the LSE-combine algebra runs as an all-reduce —
  identical math to the paper's split-KV, with chips in place of SMs.

``build_mesh_decode_step`` freezes one :class:`~repro.plan.LaunchPlan`
through the mesh-level :class:`~repro.plan.Planner`
(:func:`~repro.launch.mesh.planner_for_mesh`), builds the cache
shardings from its ``mesh_splits`` decision, and pins the plan into the
decode ops via :func:`repro.plan.plan_scope`.  The decision is *per
(arch, shape)* and entirely static — the A/B between policies compiles
two different programs, which the dry-run + roofline compare.

The builder is the FROZEN, single-launch form of this idea (dry-run /
roofline probes); the request-lifecycle form — per-bucket plans, slot
admission, dp routing — is ``repro.shard.ShardedServingEngine``, which
supersedes the old ``build_serve_step`` name (kept as a warn-once
delegating shim).
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ServeConfig, ShapeConfig
from repro.core.split_policy import DecodeWorkload
from repro.launch.mesh import planner_for_mesh
from repro.models.common import abstract_params
from repro.models.registry import Model
from repro.plan import AttentionSpec, LaunchPlan, plan_scope
from repro.sharding.ctx import activation_mesh
from repro.sharding.rules import (
    cache_rules,
    serve_param_rules,  # noqa: F401  (historic home; re-exported)
    spec_for,
    tree_shardings,
)

Pytree = Any


def effective_kv_heads(cfg: ModelConfig) -> int:
    """H_KV as the decode workload sees it (MLA: one shared latent)."""
    if cfg.mla is not None:
        return 1
    return cfg.num_kv_heads


def attention_spec(cfg: ModelConfig, shape: ShapeConfig) -> AttentionSpec:
    """The per-replica decode launch spec for one (arch, shape) cell."""
    return AttentionSpec.decode(
        1,                                    # per-replica view of the axis
        shape.seq_len,
        cfg.num_heads,
        effective_kv_heads(cfg),
        cfg.resolved_head_dim,
        window=cfg.hybrid.window if cfg.family == "hybrid" else None,
        v_width=cfg.mla.kv_lora_rank if cfg.mla is not None else None,
    )


def decode_workload(cfg: ModelConfig, shape: ShapeConfig) -> DecodeWorkload:
    return attention_spec(cfg, shape).workload()


def mesh_launch_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     policy: str) -> Optional[LaunchPlan]:
    """The mesh-level launch plan, frozen once at build time.

    This is the serving engine's plan-cache idea applied statically: the
    mesh :class:`~repro.plan.Planner` freezes the split decision for the
    (arch, shape) cell and BOTH consumers read it — the sharding layout
    in :func:`build_serve_step` (via ``plan.mesh_splits``) and the decode
    ops inside the jitted step (via :func:`repro.plan.plan_scope`) — so
    the policy is never re-evaluated inside the traced program.  See
    :meth:`repro.plan.Planner.mesh_plan` for the occupancy- vs
    storage-driven split reasons.  ``None`` for attention-free families.
    """
    if cfg.family == "ssm":
        return None                           # attention-free (DESIGN.md §5)
    return planner_for_mesh(mesh, policy=policy).mesh_plan(
        attention_spec(cfg, shape), axis_size=mesh.shape["model"],
        axis="model")


def mesh_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              policy: str) -> Tuple[Optional[LaunchPlan], int]:
    """Legacy surface: (frozen plan, sequence-shard ways)."""
    plan = mesh_launch_plan(cfg, shape, mesh, policy)
    return plan, (plan.mesh_splits if plan is not None else 1)


def mesh_split_decision(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                        policy: str) -> int:
    """How many ways the model axis sequence-shards the KV cache (1 = off)."""
    return mesh_plan(cfg, shape, mesh, policy)[1]


@dataclass
class ServeStepBundle:
    model: Model
    scfg: ServeConfig
    mesh: Mesh
    step: Callable                            # jitted
    param_shardings: Pytree
    cache_shardings: Pytree
    max_len: int
    mesh_splits: int                          # 1 = head-sharded path
    # launch plan the step was specialized on (context-only under the
    # internal-heuristic A/B path; None for attention-free families)
    plan: Optional[LaunchPlan] = None

    @property
    def metadata(self) -> Optional[LaunchPlan]:
        """Legacy name: the frozen plan (None when nothing is frozen)."""
        return self.plan if (self.plan is not None
                             and self.plan.frozen) else None

    def abstract_args(self):
        aparams = abstract_params(self.model.param_specs())
        B = self.scfg.shape.global_batch
        acache = self.model.abstract_cache(B, self.max_len,
                                           self.scfg.kv_cache_dtype)
        tok = jax.ShapeDtypeStruct((B,), jnp.int32)
        t = jax.ShapeDtypeStruct((), jnp.int32)
        return aparams, acache, tok, t


def build_mesh_decode_step(model: Model, scfg: ServeConfig, mesh: Mesh
                           ) -> ServeStepBundle:
    cfg = model.cfg
    B, L = scfg.shape.global_batch, scfg.shape.seq_len
    model_ax = mesh.shape["model"]
    # cache length padded so a whole-axis sequence shard divides evenly
    max_len = -(-L // model_ax) * model_ax

    plan = mesh_launch_plan(cfg, scfg.shape, mesh, scfg.split_policy)
    splits = plan.mesh_splits if plan is not None else 1
    if plan is not None and not scfg.use_scheduler_metadata:
        # internal-heuristic A/B path: drop the frozen decision, keep the
        # policy / num_cores overrides and the mesh-shard realization
        plan = plan.context_only()
    seq_split = splits > 1

    prules = serve_param_rules()
    aparams = abstract_params(model.param_specs())
    pshard = tree_shardings(mesh, aparams, model.param_axes(), prules)

    crules = cache_rules(seq_split)
    acache = model.abstract_cache(B, max_len, scfg.kv_cache_dtype)
    caxes = model.cache_axes(B, max_len, scfg.kv_cache_dtype)
    cshard = tree_shardings(mesh, acache, caxes, crules)

    tok_spec = spec_for((B,), ("batch",), crules, mesh)

    def constraint(x):
        # x: (S, B, C, H, D) split-KV tensors — pin S to the model axis
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*( ("model",) +
                                        (None,) * (x.ndim - 1) ))))

    # the scope realizes the plan's mesh decision on THIS mesh: fused =
    # shard_map cache-write + psum LSE combine; auto = GSPMD split-axis
    # constraint with the kernel split rounded up to the axis
    use_fused = seq_split and scfg.decode_impl == "fused"
    scope = plan if plan is not None else LaunchPlan(
        kind="decode", policy=scfg.split_policy, num_cores=model_ax)
    scope = dataclasses.replace(
        scope,
        min_splits=1 if use_fused else splits,
        split_constraint=(None if use_fused else
                          (constraint if seq_split else None)),
        seq_shard_mesh=mesh if use_fused else None,
        seq_shard_axis="model",
    )

    def step(params, caches, token, t):
        with plan_scope(scope), activation_mesh(mesh):
            logits, caches = model.decode_step(
                params, caches, token, t, plan=scope)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    jitted = jax.jit(
        step,
        in_shardings=(pshard, cshard,
                      NamedSharding(mesh, tok_spec),
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, tok_spec), cshard),
        donate_argnums=(1,),
    )
    return ServeStepBundle(model, scfg, mesh, jitted, pshard, cshard,
                           max_len, splits, scope)


_BUILD_SERVE_STEP_WARNED = False


def build_serve_step(model: Model, scfg: ServeConfig, mesh: Mesh
                     ) -> ServeStepBundle:
    """Deprecated name for :func:`build_mesh_decode_step` (warns once
    per process, then delegates bit-identically).

    The old name suggested this was THE serving entry point; it builds
    one frozen single-launch decode step.  Request-lifecycle serving on
    a mesh is ``repro.shard.ShardedServingEngine`` (or a single-shard
    ``ServingEngine(mesh=...)``); the frozen builder keeps its job
    under the name that says what it does.
    """
    global _BUILD_SERVE_STEP_WARNED
    if not _BUILD_SERVE_STEP_WARNED:
        _BUILD_SERVE_STEP_WARNED = True
        warnings.warn(
            "build_serve_step is deprecated: use build_mesh_decode_step "
            "(same frozen single-launch builder), or serve requests "
            "through repro.shard.ShardedServingEngine / "
            "ServingEngine(mesh=...)",
            DeprecationWarning, stacklevel=2)
    return build_mesh_decode_step(model, scfg, mesh)


# ---------------------------------------------------------------------------
# Prefill step (inference-prefill shapes)
# ---------------------------------------------------------------------------


@dataclass
class PrefillStepBundle:
    model: Model
    scfg: ServeConfig
    mesh: Mesh
    step: Callable
    param_shardings: Pytree
    cache_shardings: Pytree
    max_len: int
    batch_shapes: Dict[str, jax.ShapeDtypeStruct]

    def abstract_args(self):
        aparams = abstract_params(self.model.param_specs())
        return aparams, self.batch_shapes


def build_prefill_step(model: Model, scfg: ServeConfig, mesh: Mesh
                       ) -> PrefillStepBundle:
    """Jitted prompt prefill: forward + decode-cache emission.

    Inference layout (TP, no FSDP); caches come out sharded exactly as the
    decode step consumes them, so prefill->decode needs no resharding.
    """
    from repro.training.train_step import batch_shardings as bshard_fn

    cfg = model.cfg
    B, L = scfg.shape.global_batch, scfg.shape.seq_len
    model_ax = mesh.shape["model"]
    max_len = -(-L // model_ax) * model_ax

    splits = mesh_split_decision(cfg, scfg.shape, mesh, scfg.split_policy)
    prules = serve_param_rules()
    aparams = abstract_params(model.param_specs())
    pshard = tree_shardings(mesh, aparams, model.param_axes(), prules)
    crules = cache_rules(splits > 1)
    acache = model.abstract_cache(B, max_len)
    cshard = tree_shardings(mesh, acache, model.cache_axes(B, max_len),
                            crules)

    Lt = model.text_len(L)
    bshapes: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((B, Lt), jnp.int32)}
    for k, (shp, dt) in model.frontend_inputs(B, L).items():
        bshapes[k] = jax.ShapeDtypeStruct(shp, jnp.dtype(dt))
    bshard = bshard_fn(mesh, bshapes)

    # prefill-kind plan: sequence-parallel attention when head counts
    # don't divide the model axis (MiniCPM3: 40, Whisper: 20)
    prefill_plan = LaunchPlan(
        kind="prefill",
        seq_shard_mesh=(mesh if cfg.num_heads % mesh.shape["model"] != 0
                        else None))

    def step(params, batch):
        with activation_mesh(mesh), plan_scope(prefill_plan):
            logits, caches = model.prefill(params, batch, max_len)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    tok_spec = spec_for((B,), ("batch",), crules, mesh)
    jitted = jax.jit(
        step,
        in_shardings=(pshard, bshard),
        out_shardings=(NamedSharding(mesh, tok_spec), cshard),
    )
    return PrefillStepBundle(model, scfg, mesh, jitted, pshard, cshard,
                             max_len, bshapes)
