"""Serve-step builder: policy-driven, mesh-aware batched decode.

This is the paper's insight lifted to TPU-pod scale.  At decode, the
batch rides the data axes; the **model axis** is where starvation lives:

- head-sharded KV (the fa3_baseline analogue): parallelism on the model
  axis is ``H_KV`` — an MQA/MLA model leaves 15 of 16 chips idle (or
  redundantly replicated), exactly the paper's "8 CTAs on 132 SMs".
- sequence-sharded KV (the sequence-aware path): the cache's L dim is
  sharded over the model axis, every chip computes a partial softmax
  over its shard, and the LSE-combine algebra runs as an all-reduce —
  identical math to the paper's split-KV, with chips in place of SMs.

``build_serve_step`` asks the selected policy (fa3_baseline / paper /
tpu_adaptive) whether to split, builds the cache shardings accordingly,
and pins the split axis inside the decode ops via
:class:`~repro.kernels.ops.DecodeContext`.  The decision is *per
(arch, shape)* and entirely static — the A/B between policies compiles
two different programs, which the dry-run + roofline compare.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ServeConfig, ShapeConfig
from repro.core.scheduler_metadata import SchedulerMetadata, get_scheduler_metadata
from repro.core.split_policy import DecodeWorkload, choose_mesh_splits
from repro.kernels import ops
from repro.models.common import abstract_params
from repro.models.registry import Model
from repro.sharding.ctx import activation_mesh
from repro.sharding.rules import (
    ShardingRules,
    cache_rules,
    spec_for,
    tree_shardings,
)

Pytree = Any


def serve_param_rules() -> ShardingRules:
    """Inference layout: TP on model, no FSDP (no per-step all-gathers).

    Expert weights additionally spread over the data axes — big MoE
    checkpoints (Qwen3-235B) exceed one chip's HBM under TP-16 alone.
    """
    return ShardingRules({
        "embed": None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "state": "model",
        "experts": ("pod", "data", "model"),
    })


def effective_kv_heads(cfg: ModelConfig) -> int:
    """H_KV as the decode workload sees it (MLA: one shared latent)."""
    if cfg.mla is not None:
        return 1
    return cfg.num_kv_heads


def decode_workload(cfg: ModelConfig, shape: ShapeConfig) -> DecodeWorkload:
    lk = shape.seq_len
    if cfg.family == "hybrid":
        lk = min(cfg.hybrid.window, lk)
    return DecodeWorkload(
        batch=1,                              # per-replica view of the axis
        seqlen_q=1,
        seqlen_k=lk,
        num_heads_q=cfg.num_heads,
        num_heads_kv=effective_kv_heads(cfg),
        head_dim=cfg.resolved_head_dim,
    )


def mesh_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              policy: str) -> Tuple[Optional[SchedulerMetadata], int]:
    """The mesh-level launch plan: (frozen metadata, sequence-shard ways).

    This is the serving engine's plan-cache idea applied once, statically,
    at build time: ``get_scheduler_metadata`` freezes the split decision
    for the (arch, shape) cell and BOTH consumers read it — the sharding
    layout below and the decode ops inside the jitted step (via
    :class:`~repro.kernels.ops.DecodeContext.metadata`), so the policy is
    never re-evaluated inside the traced program.

    Two reasons to split: (a) the paper's occupancy policy says the model
    axis is starved, or (b) *storage*: when H_KV doesn't divide the model
    axis, head-sharding falls back to full replication (whisper kv=20 on
    a 16-axis: 42 GiB/device of cache, measured) — sequence-sharding is
    then strictly better regardless of the compute policy.
    """
    if cfg.family == "ssm":
        return None, 1                        # attention-free (DESIGN.md §5)
    model_ax = mesh.shape["model"]
    w = decode_workload(cfg, shape)
    kv = effective_kv_heads(cfg)
    if kv % model_ax != 0:                    # storage-driven split (b)
        md = get_scheduler_metadata(
            w.batch, 1, w.seqlen_k, w.num_heads_q, w.num_heads_kv,
            w.head_dim, policy=policy, num_cores=model_ax,
            num_splits_override=model_ax)
        return md, model_ax
    md = get_scheduler_metadata(
        w.batch, 1, w.seqlen_k, w.num_heads_q, w.num_heads_kv,
        w.head_dim, policy=policy, num_cores=model_ax)
    # the SHARD decision keeps the divisor constraint (an axis with no
    # usable divisor <= the split count stays head-sharded); binary
    # realization on a fixed mesh: any split -> whole-axis shard
    # (fractional axis splits need sub-axes; recorded as future work)
    s_mesh = choose_mesh_splits(w, model_ax, policy=policy)
    return md, (model_ax if s_mesh > 1 else 1)


def mesh_split_decision(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                        policy: str) -> int:
    """How many ways the model axis sequence-shards the KV cache (1 = off)."""
    return mesh_plan(cfg, shape, mesh, policy)[1]


@dataclass
class ServeStepBundle:
    model: Model
    scfg: ServeConfig
    mesh: Mesh
    step: Callable                            # jitted
    param_shardings: Pytree
    cache_shardings: Pytree
    max_len: int
    mesh_splits: int                          # 1 = head-sharded path
    # frozen launch plan the step was specialized on (None = the
    # internal-heuristic path or an attention-free family)
    metadata: Optional[SchedulerMetadata] = None

    def abstract_args(self):
        aparams = abstract_params(self.model.param_specs())
        B = self.scfg.shape.global_batch
        acache = self.model.abstract_cache(B, self.max_len,
                                           self.scfg.kv_cache_dtype)
        tok = jax.ShapeDtypeStruct((B,), jnp.int32)
        t = jax.ShapeDtypeStruct((), jnp.int32)
        return aparams, acache, tok, t


def build_serve_step(model: Model, scfg: ServeConfig, mesh: Mesh
                     ) -> ServeStepBundle:
    cfg = model.cfg
    B, L = scfg.shape.global_batch, scfg.shape.seq_len
    model_ax = mesh.shape["model"]
    # cache length padded so a whole-axis sequence shard divides evenly
    max_len = -(-L // model_ax) * model_ax

    metadata, splits = mesh_plan(cfg, scfg.shape, mesh, scfg.split_policy)
    if not scfg.use_scheduler_metadata:
        metadata = None                   # internal-heuristic A/B path
    seq_split = splits > 1

    prules = serve_param_rules()
    aparams = abstract_params(model.param_specs())
    pshard = tree_shardings(mesh, aparams, model.param_axes(), prules)

    crules = cache_rules(seq_split)
    acache = model.abstract_cache(B, max_len, scfg.kv_cache_dtype)
    caxes = model.cache_axes(B, max_len, scfg.kv_cache_dtype)
    cshard = tree_shardings(mesh, acache, caxes, crules)

    tok_spec = spec_for((B,), ("batch",), crules, mesh)

    def constraint(x):
        # x: (S, B, C, H, D) split-KV tensors — pin S to the model axis
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*( ("model",) +
                                        (None,) * (x.ndim - 1) ))))

    use_fused = seq_split and scfg.decode_impl == "fused"
    ctx = ops.DecodeContext(
        policy=scfg.split_policy,
        num_cores=model_ax,
        metadata=metadata,
        min_splits=1 if use_fused else splits,
        split_constraint=(None if use_fused else
                          (constraint if seq_split else None)),
        seq_shard_mesh=mesh if use_fused else None,
        seq_shard_axis="model",
    )

    def step(params, caches, token, t):
        with ops.decode_context(ctx), activation_mesh(mesh):
            logits, caches = model.decode_step(
                params, caches, token, t, metadata=metadata,
                policy=scfg.split_policy, num_cores=model_ax)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    jitted = jax.jit(
        step,
        in_shardings=(pshard, cshard,
                      NamedSharding(mesh, tok_spec),
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, tok_spec), cshard),
        donate_argnums=(1,),
    )
    return ServeStepBundle(model, scfg, mesh, jitted, pshard, cshard,
                           max_len, splits, metadata)


# ---------------------------------------------------------------------------
# Prefill step (inference-prefill shapes)
# ---------------------------------------------------------------------------


@dataclass
class PrefillStepBundle:
    model: Model
    scfg: ServeConfig
    mesh: Mesh
    step: Callable
    param_shardings: Pytree
    cache_shardings: Pytree
    max_len: int
    batch_shapes: Dict[str, jax.ShapeDtypeStruct]

    def abstract_args(self):
        aparams = abstract_params(self.model.param_specs())
        return aparams, self.batch_shapes


def build_prefill_step(model: Model, scfg: ServeConfig, mesh: Mesh
                       ) -> PrefillStepBundle:
    """Jitted prompt prefill: forward + decode-cache emission.

    Inference layout (TP, no FSDP); caches come out sharded exactly as the
    decode step consumes them, so prefill->decode needs no resharding.
    """
    from repro.training.train_step import batch_shardings as bshard_fn

    cfg = model.cfg
    B, L = scfg.shape.global_batch, scfg.shape.seq_len
    model_ax = mesh.shape["model"]
    max_len = -(-L // model_ax) * model_ax

    splits = mesh_split_decision(cfg, scfg.shape, mesh, scfg.split_policy)
    prules = serve_param_rules()
    aparams = abstract_params(model.param_specs())
    pshard = tree_shardings(mesh, aparams, model.param_axes(), prules)
    crules = cache_rules(splits > 1)
    acache = model.abstract_cache(B, max_len)
    cshard = tree_shardings(mesh, acache, model.cache_axes(B, max_len),
                            crules)

    Lt = model.text_len(L)
    bshapes: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((B, Lt), jnp.int32)}
    for k, (shp, dt) in model.frontend_inputs(B, L).items():
        bshapes[k] = jax.ShapeDtypeStruct(shp, jnp.dtype(dt))
    bshard = bshard_fn(mesh, bshapes)

    attn_ctx = (ops.AttnContext(seq_shard_mesh=mesh)
                if cfg.num_heads % mesh.shape["model"] != 0
                else ops.AttnContext())

    def step(params, batch):
        with activation_mesh(mesh), ops.attention_context(attn_ctx):
            logits, caches = model.prefill(params, batch, max_len)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    tok_spec = spec_for((B,), ("batch",), crules, mesh)
    jitted = jax.jit(
        step,
        in_shardings=(pshard, bshard),
        out_shardings=(NamedSharding(mesh, tok_spec), cshard),
    )
    return PrefillStepBundle(model, scfg, mesh, jitted, pshard, cshard,
                             max_len, bshapes)
