"""Serving: request-lifecycle engine (submit/step/stream/drain),
scheduler, pluggable sampling, and the mesh-level serve-step builder."""
from repro.serving.decode_step import (  # noqa: F401
    ServeStepBundle,
    attention_spec,
    build_mesh_decode_step,
    build_prefill_step,
    build_serve_step,
    decode_workload,
    mesh_launch_plan,
    mesh_plan,
    mesh_split_decision,
    serve_param_rules,
)
from repro.serving.engine import (  # noqa: F401
    Completion,
    DecodeEngine,
    PlanCacheStats,
    Request,
    ServingEngine,
)
from repro.serving.events import (  # noqa: F401
    FINISH_CACHE_CAPACITY,
    FINISH_EOS,
    FINISH_LENGTH,
    FINISH_REASONS,
    FINISH_STOP,
    FINISHED,
    TOKEN,
    Event,
)
from repro.serving.sampling import (  # noqa: F401
    GREEDY,
    CategoricalSampler,
    GreedySampler,
    Sampler,
    SamplingParams,
    get_sampler,
    register_sampler,
)
from repro.serving.scheduler import (  # noqa: F401
    PlanEntry,
    Scheduler,
    SlotState,
)
