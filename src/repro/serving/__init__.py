"""Serving: policy-driven batched decode (mesh-level split) + engine."""
from repro.serving.decode_step import (  # noqa: F401
    ServeStepBundle,
    attention_spec,
    build_serve_step,
    decode_workload,
    mesh_launch_plan,
    mesh_plan,
    mesh_split_decision,
    serve_param_rules,
)
from repro.serving.engine import (  # noqa: F401
    Completion,
    DecodeEngine,
    PlanCacheStats,
    Request,
)
