"""Serving events: what :meth:`ServingEngine.step` emits per launch.

Every generated token surfaces as one :data:`TOKEN` event; a request's
last event is always a :data:`FINISHED` event carrying the
``finish_reason`` that ended it:

- ``"eos"``            — the request's ``eos_id`` was sampled.
- ``"stop"``           — a ``SamplingParams.stop`` token was sampled.
- ``"length"``         — the ``max_new_tokens`` budget is exhausted.
- ``"cache_capacity"`` — the slot hit the KV cache's last writable row
  (``max_len - 1``).  The pre-redesign engine ended these requests
  indistinguishably from EOS; surfacing the reason (plus a once-per-
  engine warning) is how operators notice undersized caches.

Events are plain frozen dataclasses so they hash, compare and log
cleanly; streaming consumers (:meth:`ServingEngine.stream`) receive the
same objects ``step()`` returned.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

# event kinds
TOKEN = "token"
FINISHED = "finished"

# finish reasons
FINISH_EOS = "eos"
FINISH_STOP = "stop"
FINISH_LENGTH = "length"
FINISH_CACHE_CAPACITY = "cache_capacity"

FINISH_REASONS = (FINISH_EOS, FINISH_STOP, FINISH_LENGTH,
                  FINISH_CACHE_CAPACITY)


@dataclass(frozen=True)
class Event:
    """One serving event.

    ``index`` is the 0-based position of ``token`` within the request's
    generated tokens (TOKEN events only); ``finish_reason`` is set on
    FINISHED events only.
    """
    kind: str                           # TOKEN | FINISHED
    handle: int                         # ServingEngine.submit() handle
    request_id: int
    token: Optional[int] = None
    index: Optional[int] = None
    finish_reason: Optional[str] = None
