"""Batched generation engine with continuous-batching-lite.

A fixed pool of ``B`` decode slots runs in lockstep through the jitted
decode step; each slot carries its own position ``t`` (the step takes a
(B,) position vector).  When a slot finishes (EOS or per-request token
budget) it is refilled from the pending queue at position 0 — no global
drain/refill barrier, which is the "lite" version of vLLM-style
continuous batching.

Prefill is decode-by-teacher-forcing (one step per prompt token).  For
the short-prompt regime the paper targets (L_K <= 512) this is the
latency-dominant path the split policy accelerates; a fused prefill is a
recorded future optimization.

The engine uses the **metadata-enabled path** (paper §5): split plans are
precomputed per cache-length bucket via ``get_scheduler_metadata`` and
the jitted step is specialized on them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core.scheduler_metadata import bucket_seqlen, get_scheduler_metadata
from repro.kernels import ops
from repro.models.registry import Model

Pytree = Any


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None


@dataclass
class Completion:
    request_id: int
    prompt: List[int]
    tokens: List[int] = field(default_factory=list)
    steps: int = 0


class DecodeEngine:
    """Single-host engine over a (possibly 1-device) mesh."""

    def __init__(self, model: Model, scfg: ServeConfig, *,
                 max_len: int = 256, batch_slots: int = 4,
                 policy: Optional[str] = None):
        self.model = model
        self.cfg = model.cfg
        self.policy = policy or scfg.split_policy
        self.max_len = max_len
        self.B = batch_slots
        self._params: Optional[Pytree] = None
        self._caches: Optional[Pytree] = None
        self._step = jax.jit(self._step_impl, donate_argnums=(1,))

    # --- state ----------------------------------------------------------------

    def load(self, params: Pytree) -> None:
        self._params = params
        self._caches = self.model.init_cache(self.B, self.max_len)

    def _metadata(self, t_max: int):
        """Precompute the launch plan for the current length bucket."""
        lk = bucket_seqlen(min(t_max + 1, self.max_len))
        return get_scheduler_metadata(
            self.B, 1, lk, self.cfg.num_heads,
            1 if self.cfg.mla else self.cfg.num_kv_heads,
            self.cfg.resolved_head_dim, policy=self.policy)

    def _step_impl(self, params, caches, token, t):
        logits, caches = self.model.decode_step(
            params, caches, token, t, policy=self.policy)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    # --- scheduling -------------------------------------------------------------

    def _zero_slot(self, i: int) -> None:
        """Clear slot i's cache (recurrent states must not leak across
        requests; zeroing KV is harmless since kv_len masks it anyway)."""
        self._caches = jax.tree.map(
            lambda a: a.at[i].set(jnp.zeros_like(a[i])), self._caches)

    def generate(self, requests: Sequence[Request]) -> List[Completion]:
        assert self._params is not None, "call load(params) first"
        pending = list(requests)
        slots: List[Optional[Completion]] = [None] * self.B
        budget = [0] * self.B
        eos: List[Optional[int]] = [None] * self.B
        slot_pos = np.zeros(self.B, np.int32)          # next write position
        slot_prompt_left: List[List[int]] = [[] for _ in range(self.B)]
        next_token = np.zeros(self.B, np.int32)
        done: List[Completion] = []

        def refill(i: int) -> None:
            if not pending:
                return
            req = pending.pop(0)
            slots[i] = Completion(req.request_id, list(req.prompt))
            budget[i] = req.max_new_tokens
            eos[i] = req.eos_id
            slot_prompt_left[i] = list(req.prompt)
            slot_pos[i] = 0
            next_token[i] = slot_prompt_left[i].pop(0)
            self._zero_slot(i)

        for i in range(self.B):
            refill(i)

        while any(s is not None for s in slots):
            tok = jnp.asarray(next_token)
            t = jnp.asarray(slot_pos)
            out, self._caches = self._step(self._params, self._caches,
                                           tok, t)
            out = np.asarray(out)
            for i, comp in enumerate(slots):
                if comp is None:
                    continue
                slot_pos[i] += 1
                comp.steps += 1
                if slot_prompt_left[i]:                 # still prefilling
                    next_token[i] = slot_prompt_left[i].pop(0)
                    continue
                tok_out = int(out[i])
                comp.tokens.append(tok_out)
                finished = (len(comp.tokens) >= budget[i]
                            or (eos[i] is not None and tok_out == eos[i])
                            or slot_pos[i] >= self.max_len - 1)
                if finished:
                    done.append(comp)
                    slots[i] = None
                    refill(i)
                else:
                    next_token[i] = tok_out
        done.sort(key=lambda c: c.request_id)
        return done
