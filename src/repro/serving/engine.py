"""Batched generation engine with continuous-batching-lite.

A fixed pool of ``B`` decode slots runs in lockstep through the jitted
decode step; each slot carries its own position ``t`` (the step takes a
(B,) position vector).  When a slot finishes (EOS or per-request token
budget) it is refilled from the pending queue at position 0 — no global
drain/refill barrier, which is the "lite" version of vLLM-style
continuous batching.

Prefill is decode-by-teacher-forcing (one step per prompt token).  For
the short-prompt regime the paper targets (L_K <= 512) this is the
latency-dominant path the split policy accelerates; a fused prefill is a
recorded future optimization.

Metadata-enabled path (paper §5)
--------------------------------
The paper's 21-24% decoder-efficiency win applies to deployments that
*precompute* scheduling metadata (FA3 / vLLM ``get_scheduler_metadata``)
instead of re-running the split heuristic at every launch.  The engine
realizes that as a three-stage flow:

1. **bucket** — before each step, the live cache length ``t_max + 1`` is
   quantized to a ``seqlen_bucket``-wide bucket (decision-lossless: the
   policy only reads ``ceil(L_K / KV_BLOCK)``).
2. **plan** — the first time a bucket is seen, ``get_scheduler_metadata``
   freezes a :class:`SchedulerMetadata` launch plan for it (policy runs
   exactly once per bucket, OUTSIDE any traced code).
3. **specialized step** — each plan owns its own jitted decode step with
   the plan closed over as a static value, so XLA specializes the whole
   program (kernel grid included) on the frozen ``num_splits``.  Inside
   the jitted body the policy is evaluated **zero** times
   (``kernels.ops.policy_eval_count`` stays flat — asserted in tests).

The planning itself lives in ``repro.plan``: the engine owns a
:class:`~repro.plan.Planner` (policy backend + optional
``num_splits_override`` from :class:`ServeConfig`) and a shared
:class:`~repro.plan.PlanCache` of per-bucket (plan, jitted step)
specializations.  Observability lives in the cache's built-in
:class:`~repro.plan.PlanCacheStats` (``engine.stats``): hits/misses,
per-bucket launch counters, the recent-launch trace, and the persistent
seen-bucket set, so tests and benchmarks can assert the metadata path
was actually exercised.  ``use_scheduler_metadata=False`` keeps the
paper's weaker "internal heuristic" path for A/B comparison.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.models.registry import Model
from repro.plan import (
    AttentionSpec,
    LaunchPlan,
    PlanCache,
    PlanCacheStats,
    Planner,
    bucket_seqlen,
)

Pytree = Any


@dataclass
class Request:
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None


@dataclass
class Completion:
    request_id: int
    prompt: List[int]
    tokens: List[int] = field(default_factory=list)
    steps: int = 0


@dataclass
class _Plan:
    """One plan-cache entry: a frozen launch plan + its specialized step."""
    bucket: int                      # bucketed L_K this plan covers
    plan: LaunchPlan
    step: Any                        # jitted, specialized on ``plan``

    @property
    def metadata(self) -> LaunchPlan:   # legacy field name
        return self.plan


class DecodeEngine:
    """Single-host engine over a (possibly 1-device) mesh."""

    def __init__(self, model: Model, scfg: ServeConfig, *,
                 max_len: int = 256, batch_slots: int = 4,
                 policy: Optional[str] = None):
        self.model = model
        self.cfg = model.cfg
        self.policy = policy or scfg.split_policy
        self.max_len = max_len
        self.B = batch_slots
        self.use_metadata = scfg.use_scheduler_metadata
        self.bucket_width = scfg.seqlen_bucket
        self.plan_capacity = scfg.plan_cache_capacity
        self._params: Optional[Pytree] = None
        self._caches: Optional[Pytree] = None
        self.planner = Planner(
            policy=self.policy,
            num_splits_override=scfg.num_splits_override)
        self._plans: PlanCache = PlanCache(self.plan_capacity)
        # internal-heuristic fallback: ONE step for all lengths, policy
        # evaluated at trace time on the padded cache length (the A/B
        # baseline the paper measures its metadata path against)
        self._step = jax.jit(self._step_impl, donate_argnums=(1,))

    @property
    def stats(self) -> PlanCacheStats:
        return self._plans.stats

    # --- state ----------------------------------------------------------------

    def load(self, params: Pytree) -> None:
        self._params = params
        self._caches = self.model.init_cache(self.B, self.max_len)

    # --- plan cache (metadata-enabled path) -----------------------------------

    def _bucket(self, t_max: int) -> int:
        """Cache-length bucket for the longest live position."""
        return bucket_seqlen(min(int(t_max) + 1, self.max_len),
                             self.bucket_width)

    def _spec(self, t_max: int) -> AttentionSpec:
        """Declarative launch spec for the current bucket."""
        return AttentionSpec.decode(
            self.B, self._bucket(t_max), self.cfg.num_heads,
            1 if self.cfg.mla else self.cfg.num_kv_heads,
            self.cfg.resolved_head_dim)

    def _metadata(self, t_max: int) -> LaunchPlan:
        """Compute (not cache) the launch plan for the current bucket."""
        lk = self._bucket(t_max)
        return self.planner.plan(self._spec(t_max), bucket=lk)

    def _plan(self, t_max: int) -> _Plan:
        """Plan-cache lookup: one specialized jitted step per bucket."""
        lk = self._bucket(t_max)

        def build() -> _Plan:
            plan = self._metadata(t_max)
            step = jax.jit(
                functools.partial(self._step_impl, plan=plan),
                donate_argnums=(1,))
            return _Plan(lk, plan, step)

        return self._plans.get_or_build(lk, build)

    def planned_splits(self) -> Dict[int, int]:
        """bucket -> frozen num_splits, for every resident plan."""
        return {lk: p.plan.num_splits for lk, p in self._plans.items()}

    def _step_impl(self, params, caches, token, t,
                   plan: Optional[LaunchPlan] = None):
        logits, caches = self.model.decode_step(
            params, caches, token, t, plan=plan, policy=self.policy)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    # --- scheduling -------------------------------------------------------------

    def _zero_slot(self, i: int) -> None:
        """Clear slot i's cache (recurrent states must not leak across
        requests; zeroing KV is harmless since kv_len masks it anyway)."""
        self._caches = jax.tree.map(
            lambda a: a.at[i].set(jnp.zeros_like(a[i])), self._caches)

    def generate(self, requests: Sequence[Request]) -> List[Completion]:
        assert self._params is not None, "call load(params) first"
        pending = list(requests)
        slots: List[Optional[Completion]] = [None] * self.B
        budget = [0] * self.B
        eos: List[Optional[int]] = [None] * self.B
        slot_pos = np.zeros(self.B, np.int32)          # next write position
        slot_prompt_left: List[List[int]] = [[] for _ in range(self.B)]
        next_token = np.zeros(self.B, np.int32)
        done: List[Completion] = []

        # validate up front: a bad request must fail fast, not abort the
        # batch mid-flight after other requests already completed
        for req in pending:
            if not req.prompt:
                raise ValueError(f"request {req.request_id}: empty prompt")
            if len(req.prompt) >= self.max_len:
                # prefill would write past the cache and silently corrupt
                # the last row (dynamic_update_slice clamps) — refuse
                raise ValueError(
                    f"request {req.request_id}: prompt length "
                    f"{len(req.prompt)} >= max_len ({self.max_len})")

        def refill(i: int) -> None:
            if not pending:
                return
            req = pending.pop(0)
            slots[i] = Completion(req.request_id, list(req.prompt))
            budget[i] = req.max_new_tokens
            eos[i] = req.eos_id
            slot_prompt_left[i] = list(req.prompt)
            slot_pos[i] = 0
            next_token[i] = slot_prompt_left[i].pop(0)
            self._zero_slot(i)

        for i in range(self.B):
            refill(i)

        while any(s is not None for s in slots):
            tok = jnp.asarray(next_token)
            t = jnp.asarray(slot_pos)
            if self.use_metadata:
                t_max = max(int(slot_pos[i]) for i, s in enumerate(slots)
                            if s is not None)
                step = self._plan(t_max).step
            else:
                step = self._step
            out, self._caches = step(self._params, self._caches, tok, t)
            out = np.asarray(out)
            for i, comp in enumerate(slots):
                if comp is None:
                    continue
                slot_pos[i] += 1
                comp.steps += 1
                if slot_prompt_left[i]:                 # still prefilling
                    next_token[i] = slot_prompt_left[i].pop(0)
                    continue
                tok_out = int(out[i])
                comp.tokens.append(tok_out)
                finished = (len(comp.tokens) >= budget[i]
                            or (eos[i] is not None and tok_out == eos[i])
                            or slot_pos[i] >= self.max_len - 1)
                if finished:
                    done.append(comp)
                    slots[i] = None
                    refill(i)
                else:
                    next_token[i] = tok_out
        done.sort(key=lambda c: c.request_id)
        return done
