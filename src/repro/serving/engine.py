"""Request-lifecycle serving engine: submit / step / stream / drain.

A fixed pool of ``B`` decode slots runs in lockstep through plan-
specialized jitted decode steps; each slot carries its own position
(the step takes a (B,) position vector).  The public surface is
request-shaped, the way real metadata-enabled engines (FA3 / vLLM
``get_scheduler_metadata``) are driven — per scheduling step, not per
``generate()`` call:

- :meth:`ServingEngine.submit`  — enqueue a :class:`Request`, get a
  handle back immediately.
- :meth:`ServingEngine.step`    — run one scheduling step (admissions +
  one lockstep decode launch) and return the :class:`Event` list it
  produced (TOKEN per generated token, FINISHED with a
  ``finish_reason``).
- :meth:`ServingEngine.stream`  — iterate one handle's events, pumping
  ``step()`` on demand.
- :meth:`ServingEngine.drain`   — run to completion, return
  :class:`Completion` objects.

Fused bucketed prefill (admission)
----------------------------------
Admitting a request prefills its whole prompt in **O(1) planned
launches** instead of O(prompt_len) teacher-forced decode steps: the
prompt is padded to a ``prefill_bucket``-wide bucket and pushed through
a jitted single-slot prefill (``Model.prefill_slot``) specialized per
bucket.  The prefill launch is planned like any other — a
``kind="prefill"`` :class:`~repro.plan.AttentionSpec` through the same
:class:`~repro.plan.Planner`, resident in the same
:class:`~repro.plan.PlanCache` under ``("prefill", bucket)`` keys — so
``PlanCacheStats`` counts admissions and tests can assert the O(1)
claim structurally.  Families with recurrent per-token state (ssm,
hybrid) or a non-token frontend (vlm) cannot consume a padded prompt in
one pass; they keep the teacher-forcing path
(``prefill_mode="loop"``), which is also the pre-redesign baseline the
serving A/B benchmark measures against.

Sampling
--------
A pluggable :class:`~repro.serving.sampling.Sampler` runs *inside* the
jitted step over per-slot state arrays (temperature / top-k / top-p /
PRNG key), so per-request sampling never recompiles.  Keys derive from
the request's seed and fold in the absolute token position — tokens are
independent of slot packing (``batch_slots`` ∈ {1, 2, 4} agree).

Cache layouts (repro.cache)
---------------------------
The engine no longer owns raw cache arrays: a
:class:`~repro.cache.CacheManager` resolves a
:class:`~repro.cache.CacheSpec` into a layout.  ``dense`` keeps the
pre-redesign ``(layers, B, max_len, ...)`` arrays bit-identically;
``paged`` stores position-linear cache leaves as fixed-size pages with
per-slot page tables.  Under the paged layout every decode launch
gathers a view sized by the RESIDENT-length bucket (``gather_view`` →
model → ``write_token``, writing back only the one row each slot
produced), so mixed-length batches stop paying attention FLOPs/HBM for
the padded tail; admission is gated on free
pages (:meth:`Scheduler.admit_next`'s ``admissible`` hook), and a
mid-generation allocation failure finishes only THAT request with
``finish_reason="cache_capacity"`` — a per-request page-exhaustion
signal instead of the engine-wide ``max_len`` wall.

Metadata-enabled path (paper §5)
--------------------------------
Unchanged from the pre-redesign engine, now owned by the
:class:`~repro.serving.scheduler.Scheduler`: live cache length →
resident bucket → frozen :class:`~repro.plan.LaunchPlan` → per-plan
jitted step, with the policy evaluated **zero** times inside traced
code (``kernels.ops.policy_eval_count`` stays flat — asserted in
tests).  ``use_scheduler_metadata=False`` keeps the paper's weaker
"internal heuristic" path for A/B — one step for all lengths, policy
evaluated at trace time on the PADDED cache length; each such launch
records the resident summary it actually covered in
``PlanCacheStats.fallback_trace`` so A/Bs can attribute it.

Speculative decoding (repro.spec)
---------------------------------
A request opting in via ``SamplingParams.speculation`` (or an engine-
wide ``ServeConfig.speculation`` default) decodes through planned
**verify** launches instead of 1-token decode launches: a host-side
:class:`~repro.spec.Drafter` proposes up to ``k`` draft tokens from the
slot's own token history, the model scores the slot's current token
plus all drafts in ONE ``kind="verify"`` launch (planned and frozen
under ``("verify", k, bucket)`` keys in the same PlanCache), and the
sampler accepts the longest valid draft prefix *inside the jitted step*
(argmax match for greedy; standard rejection sampling for sampled
rows).  Accepted rows commit to the paged cache via an accept-masked
multi-row write-back (``PagedKVCache.write_rows``); rejected rows die
by rolling ``kv_len`` back (``CacheManager.truncate``) — pages are
never freed mid-request, so page conservation holds under any
accept/reject interleaving.  Greedy speculative output is bit-identical
to plain greedy decode by construction of the acceptance rule.
``PlanCacheStats`` carries acceptance-rate / effective-tokens-per-step
counters; ``SpecConfig.max_rejects`` consecutive zero-accept steps
disable speculation for that request (counted in ``spec_disabled``).

:class:`DecodeEngine` is the legacy batch-synchronous facade
(``generate(requests) -> completions``): a thin wrapper pinned to
``prefill_mode="loop"``, bit-identical to the pre-redesign engine for
greedy decoding.
"""
from __future__ import annotations

import functools
import warnings
from collections import deque
from typing import Any, Deque, Dict, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig
from repro.core.split_policy import KV_DTYPES, get_policy
from repro.models.registry import Model
from repro.obs import atomic_write_json, resolve_obs
from repro.plan import LaunchPlan, PlanCacheStats, Planner, plan_scope
from repro.serving.events import (
    FINISH_CACHE_CAPACITY,
    FINISH_EOS,
    FINISH_LENGTH,
    FINISH_STOP,
    FINISHED,
    TOKEN,
    Event,
)
from repro.serving.sampling import CategoricalSampler, GreedySampler, \
    Sampler
from repro.serving.scheduler import (
    Completion,
    Request,
    Scheduler,
    SlotState,
)
from repro.sharding.ctx import activation_mesh
from repro.sharding.rules import cache_rules, replicated, \
    serve_param_rules, tree_shardings
from repro.spec import Drafter, SpecConfig, get_drafter

Pytree = Any

PREFILL_MODES = ("auto", "fused", "loop")


PARAM_POLICIES = ("replicated", "tp")


class ServingEngine:
    """Request-lifecycle engine over one device (default) or one shard
    sub-mesh of the mesh-native topology (``mesh=...``).

    With a mesh bound, the engine is ONE shard of a
    :class:`~repro.shard.ShardedServingEngine`: params land per
    ``param_policy`` ("replicated" or "tp" over the model axis via
    :func:`~repro.sharding.rules.serve_param_rules`), the dense KV cache
    sequence-shards its L dim over the model axis when the mesh has one
    wider than 1 (``seq_shards > 1``), every plan the scheduler freezes
    carries ``mesh_splits`` provenance (``Planner.mesh_plan``), and
    decode launches take the fused shard_map sequence-sharded path
    (per-chip partial softmax + LSE combine).  ``plan_cache`` shares one
    :class:`~repro.plan.PlanCache` (plans AND compiled steps) across
    same-topology engines; ``shard_id`` labels the cache manager so
    page-conservation failures name the owning shard."""

    def __init__(self, model: Model, scfg: ServeConfig, *,
                 max_len: int = 256, batch_slots: int = 4,
                 policy: Optional[str] = None,
                 sampler: Optional[Sampler] = None,
                 prefill_mode: Optional[str] = None,
                 cache_layout: Optional[str] = None,
                 tune_table: Optional[Any] = None,
                 mesh: Optional[Any] = None,
                 plan_cache: Optional[Any] = None,
                 shard_id: Optional[int] = None,
                 param_policy: str = "replicated",
                 obs: Optional[Any] = None):
        self.model = model
        self.cfg = model.cfg
        self.policy = policy or scfg.split_policy
        self.max_len = max_len
        self.B = batch_slots
        self.use_metadata = scfg.use_scheduler_metadata
        if param_policy not in PARAM_POLICIES:
            raise ValueError(f"unknown param_policy {param_policy!r}; "
                             f"known: {PARAM_POLICIES}")
        self.mesh = mesh
        self.shard_id = shard_id
        self.param_policy = param_policy
        self.seq_shards = int(mesh.shape["model"]) if mesh is not None \
            else 1
        if self.seq_shards > 1:
            if not self.use_metadata:
                raise ValueError(
                    "sequence-sharded decode rides the metadata-enabled "
                    "plan path (the fused shard_map kernel is pinned on "
                    "frozen plans); set use_scheduler_metadata=True or "
                    "a model axis of 1")
            if self.cfg.family not in ("dense", "moe", "mla"):
                raise ValueError(
                    f"{self.cfg.family} models cannot sequence-shard "
                    "their decode (needs a position-linear k/v cache "
                    "consumed by the fused split-KV combine); use a "
                    "model axis of 1")
        if scfg.kv_quant is not None:
            from repro.quant import QUANT_DTYPES
            if scfg.kv_quant not in QUANT_DTYPES:
                raise ValueError(
                    f"unknown kv_quant {scfg.kv_quant!r}; "
                    f"known: {sorted(QUANT_DTYPES)}")
        self.kv_dtype = scfg.kv_quant or scfg.kv_cache_dtype
        self._stats_path = scfg.stats_path

        # repro.obs: an injected observer (a ShardedServingEngine's
        # per-shard view) wins and the injector owns the artifact dumps;
        # otherwise resolve from the config's paths — NULL_OBSERVER when
        # both are unset, so the disabled path costs one attribute read
        # per guarded site and allocates nothing
        self._trace_path = scfg.trace_path
        self._metrics_path = scfg.metrics_path
        if obs is not None:
            self._obs = obs
            self._owns_obs = False
        else:
            self._obs = resolve_obs(scfg)
            self._owns_obs = self._obs.enabled
        # KV bytes one cached prompt row avoids recomputing+storing
        # (prefix-shared-bytes counter): K + V across layers, at the
        # engine's effective storage dtype
        kvh = 1 if self.cfg.mla else self.cfg.num_kv_heads
        self._kv_row_bytes = (2 * self.cfg.num_layers * kvh
                              * self.cfg.resolved_head_dim
                              * KV_DTYPES.get(self.kv_dtype, 2))

        # measured policy (repro.tune): resolve the SplitTable once —
        # an explicit object wins over the config's path.  The path may
        # be a DIRECTORY of tables (a registry): the one whose backend
        # fingerprint matches the live jax.devices() is picked, with a
        # counted-warning fallback when none matches.
        self.tune_table = tune_table
        self._table_registry_fallback = False
        if self.tune_table is None and scfg.tune_table_path:
            from repro.tune import select_table
            self.tune_table, matched = \
                select_table(scfg.tune_table_path)
            self._table_registry_fallback = not matched
        if getattr(get_policy(self.policy), "needs_table", False) \
                and not self.use_metadata:
            raise ValueError(
                f"split_policy={self.policy!r} rides the metadata-enabled "
                "plan path (the SplitTable is consulted when plans "
                "freeze, never at trace time); set "
                "use_scheduler_metadata=True or an analytic policy")
        # CategoricalSampler by default so per-request SamplingParams
        # are always honored; it pays vocab sorts inside every step even
        # for all-greedy traffic, so cost-sensitive greedy-only callers
        # (e.g. the legacy DecodeEngine facade) pass GreedySampler,
        # which instead REJECTS sampled requests at submit()
        self.sampler = sampler if sampler is not None else \
            CategoricalSampler()

        mode = prefill_mode or scfg.prefill_mode
        if mode not in PREFILL_MODES:
            raise ValueError(f"unknown prefill_mode {mode!r}; "
                             f"known: {PREFILL_MODES}")
        if mode == "auto":
            mode = "fused" if (self.use_metadata
                               and model.supports_fused_prefill) else "loop"
        elif mode == "fused":
            if not model.supports_fused_prefill:
                raise ValueError(
                    f"{self.cfg.family} models cannot fused-prefill a "
                    "padded prompt (recurrent state / non-token "
                    "frontend); use prefill_mode='loop'")
            if not self.use_metadata:
                raise ValueError(
                    "fused prefill admission rides the metadata-enabled "
                    "plan path; set use_scheduler_metadata=True or "
                    "prefill_mode='loop'")
        self.prefill_mode = mode

        layout = cache_layout or scfg.cache_layout
        if layout == "paged":
            # (family support is checked by Model.cache_spec below)
            if not self.use_metadata:
                raise ValueError(
                    "the paged cache layout rides the metadata-enabled "
                    "plan path (views are gathered per resident-length "
                    "bucket); set use_scheduler_metadata=True or "
                    "cache_layout='dense'")
            for width in (scfg.seqlen_bucket,
                          scfg.prefill_bucket or scfg.seqlen_bucket):
                if width % scfg.cache_page_size:
                    raise ValueError(
                        f"cache_page_size ({scfg.cache_page_size}) must "
                        f"divide the plan bucket widths (got {width})")
        self.cache_layout = layout

        if self.seq_shards > 1:
            # the fused path shards the cache's L dim (dense: max_len;
            # paged: the gathered view, whose length is a page multiple)
            if layout == "paged":
                if scfg.cache_page_size % self.seq_shards:
                    raise ValueError(
                        f"cache_page_size ({scfg.cache_page_size}) must "
                        f"divide over the model axis "
                        f"({self.seq_shards}) for sequence-sharded "
                        "paged decode")
            elif max_len % self.seq_shards:
                raise ValueError(
                    f"max_len ({max_len}) must divide over the model "
                    f"axis ({self.seq_shards}) for sequence-sharded "
                    "decode")

        self.share_prefix = scfg.share_prefix
        if self.share_prefix:
            if layout != "paged":
                raise ValueError(
                    "share_prefix maps prompts onto existing pages; set "
                    "cache_layout='paged'")
            if self.prefill_mode != "fused":
                raise ValueError(
                    "prefix sharing admits through the fused prefill "
                    "path (suffix prefill is its restartable form); "
                    "prefill_mode='loop' cannot start from adopted pages")
            if not model.supports_prefix_sharing:
                raise ValueError(
                    f"{self.cfg.family} models cannot share prefix pages "
                    "(needs a uniform full-attention stack over the "
                    "standard k/v cache)")
        self._cache_kw = dict(kv_dtype=self.kv_dtype, layout=layout,
                              page_size=scfg.cache_page_size,
                              page_budget=scfg.cache_page_budget,
                              share_prefix=scfg.share_prefix,
                              prefix_capacity=scfg.prefix_capacity,
                              label=(f"shard{shard_id}"
                                     if shard_id is not None else ""))
        # residency bookkeeping + layout resolution (storage arrays stay
        # on the engine for the donation flow; load() re-creates both)
        self.cache = model.cache_manager(self.B, self.max_len,
                                         **self._cache_kw)

        self.sched = Scheduler(
            self.cfg, batch_slots=batch_slots, max_len=max_len,
            policy=self.policy,
            num_splits_override=scfg.num_splits_override,
            bucket_width=scfg.seqlen_bucket,
            prefill_bucket=scfg.prefill_bucket,
            plan_capacity=scfg.plan_cache_capacity,
            cache_layout=layout,
            kv_dtype=self.kv_dtype,
            table=self.tune_table,
            mesh=self.mesh,
            seq_shards=self.seq_shards,
            plans=plan_cache)
        if self._table_registry_fallback:
            self.stats.table_registry_fallbacks += 1
            if self._obs.enabled:
                self._obs.on_warning(
                    "table_registry_fallback",
                    f"no table in {scfg.tune_table_path} matches the "
                    "live backend fingerprint; using the registry's "
                    "first table")

        self._params: Optional[Pytree] = None
        self._caches: Optional[Pytree] = None
        self._state: Dict[str, np.ndarray] = {}
        # device copy of the sampler state, refreshed only when an
        # admission dirties a row (not re-uploaded every decode step)
        self._state_dev: Optional[Dict[str, jax.Array]] = None
        # the ONLY copy of each slot's next write position / next fed
        # token.  Dead-slot entries keep their last values on purpose:
        # the lockstep launch always covers all B rows, and keeping the
        # arrays stable keeps the legacy wrapper bit-identical to the
        # pre-redesign engine (whose arrays behaved the same way)
        self._pos = np.zeros(self.B, np.int32)
        self._next_token = np.zeros(self.B, np.int32)

        # engine-wide speculation default (per-request SamplingParams
        # wins); per-slot drafter instances + disable bookkeeping
        self._default_spec: Optional[SpecConfig] = None
        if scfg.speculation:
            self._default_spec = SpecConfig(
                method=scfg.speculation, k=scfg.speculation_k,
                max_rejects=scfg.speculation_max_rejects)
            self._check_speculation(self._default_spec, "ServeConfig")
        self._spec_cfg: List[Optional[SpecConfig]] = [None] * self.B
        self._drafters: List[Optional[Drafter]] = [None] * self.B
        self._spec_rejects = [0] * self.B

        self._next_handle = 0
        self._queues: Dict[int, Deque[Event]] = {}
        self._completions: Dict[int, Completion] = {}
        self._undrained: List[int] = []
        # once-per-engine warnings, one flag PER capacity condition (the
        # max_len wall and page-pool exhaustion are distinct signals; the
        # first must not suppress the other)
        self._warned_len_capacity = False
        self._warned_page_capacity = False

        # internal-heuristic fallback: ONE step for all lengths, policy
        # evaluated at trace time on the padded cache length (the A/B
        # baseline the paper measures its metadata path against; dense
        # only — paged requires the metadata path, enforced above)
        self._fallback_step = jax.jit(self._decode_impl,
                                      donate_argnums=(1,))
        # slot reset: jitted + donated, one compile for every slot (the
        # pre-redesign engine rebuilt the whole cache pytree with
        # un-jitted .at[i].set per admission — a host round trip per
        # refill).  Paged storage resets only the NON-paged leaves:
        # freshly allocated pages hold stale rows strictly above the new
        # request's kv_len, which every consumer masks.
        self._zero_step = jax.jit(
            self._zero_paged_impl if layout == "paged" else self._zero_impl,
            donate_argnums=(0,))
        # device page copy (copy-on-adopt / copy-on-write): applied
        # between launches, before any gather can read the copied-into
        # page (prefix sharing)
        if layout == "paged":
            self._copy_step = jax.jit(self._copy_page_impl,
                                      donate_argnums=(0,))

    # --- observability ------------------------------------------------------

    @property
    def stats(self) -> PlanCacheStats:
        return self.sched.plans.stats

    @property
    def planner(self) -> Planner:
        return self.sched.planner

    def planned_splits(self) -> Dict[int, int]:
        """bucket -> frozen num_splits, for every resident decode plan."""
        return self.sched.planned_splits()

    def planned_prefill_buckets(self) -> List[int]:
        return self.sched.planned_prefill_buckets()

    def planned_suffix_buckets(self) -> List[Any]:
        return self.sched.planned_suffix_buckets()

    def cache_stats(self) -> Dict[str, Any]:
        """The cache manager's layout / residency / page-pool summary."""
        return self.cache.describe()

    def _metadata(self, t_max: int) -> LaunchPlan:
        """Compute (not cache) the decode launch plan for ``t_max``."""
        return self.sched.decode_plan(t_max)

    # --- state --------------------------------------------------------------

    def load(self, params: Pytree) -> None:
        if self.mesh is not None:
            params = self._place_params(params)
        self._params = params
        # a (re)load is a fresh serve session: new storage AND new
        # residency / page-table state (a stale free list over fresh
        # zeroed storage would leak phantom allocations)
        self.cache = self.model.cache_manager(self.B, self.max_len,
                                              **self._cache_kw)
        self._caches = self.cache.init_storage()
        if self.mesh is not None:
            self._caches = self._place_caches(self._caches)
        self._state = self.sampler.init_state(self.B)
        self._state_dev = None

    def _place_params(self, params: Pytree) -> Pytree:
        """Land params on the shard sub-mesh: replicated (default — the
        dp regime, one full copy per shard) or TP over the model axis
        (``param_policy="tp"``, the serve-step builder's layout)."""
        if self.param_policy == "tp":
            sh = tree_shardings(self.mesh, params,
                                self.model.param_axes(),
                                serve_param_rules())
            return jax.device_put(params, sh)
        return jax.device_put(params, replicated(self.mesh))

    def _place_caches(self, storage: Pytree) -> Pytree:
        """Land cache storage on the shard sub-mesh.  Dense storage
        sequence-shards its L dim over the model axis when the fused
        path is on; the paged page pool stays replicated (the gathered
        view is re-partitioned per launch by the shard_map)."""
        if self.seq_shards > 1 and not self.cache.is_paged:
            axes = self.model.cache_axes(self.B, self.max_len,
                                         self.kv_dtype)
            sh = tree_shardings(self.mesh, storage, axes,
                                cache_rules(True))
            return jax.device_put(storage, sh)
        return jax.device_put(storage, replicated(self.mesh))

    # --- jitted impls -------------------------------------------------------

    def _decode_impl(self, params, caches, token, t, state,
                     plan: Optional[LaunchPlan] = None):
        with activation_mesh(self.mesh):
            logits, caches = self.model.decode_step(
                params, caches, token, t, plan=plan, policy=self.policy)
        tok = self.sampler.sample(logits, state, t)
        return tok, caches

    def _prefill_impl(self, params, caches, tokens, slot, length, state,
                      plan: Optional[LaunchPlan] = None):
        """Fused single-slot prompt prefill + first-token sampling."""
        with plan_scope(plan), activation_mesh(self.mesh):
            logits, caches = self.model.prefill_slot(
                params, caches, tokens, slot, length, self.max_len,
                plan=plan, kv_dtype=self.kv_dtype)
        tok = self.sampler.sample(logits[None], state, (length - 1)[None])
        return tok[0], caches

    def _zero_impl(self, caches, slot):
        """Zero slot ``slot`` across every cache leaf (batch axis 1 of
        the layer-stacked pytree).  Recurrent states must not leak
        across requests; zeroing KV is harmless since kv_len masks it."""
        def z(a):
            row = jnp.zeros(a.shape[:1] + (1,) + a.shape[2:], a.dtype)
            start = (0, slot) + (0,) * (a.ndim - 2)
            return jax.lax.dynamic_update_slice(a, row, start)
        return jax.tree.map(z, caches)

    def _zero_paged_impl(self, caches, slot):
        return self.cache.layout.zero_slot(caches, slot)

    # --- jitted impls: paged layout -----------------------------------------

    def _decode_paged_impl(self, params, storage, token, t, state, table,
                           plan: Optional[LaunchPlan] = None,
                           num_pages: int = 1):
        """Lockstep decode over the RESIDENT-bucket gathered view.

        ``num_pages`` is static (one jitted specialization per resident
        bucket, exactly mirroring the per-bucket plan specialization):
        gather the first ``num_pages`` pages of every slot into a dense
        view, run the planned decode step on it, then write back ONLY
        the one row each slot produced (``write_token``).  The launch's
        attention L_K is the view length — FLOPs and HBM both track
        residency, not the padded slot capacity.
        """
        lay = self.cache.layout
        view = lay.gather_view(storage, table, num_pages)
        with activation_mesh(self.mesh):
            logits, view = self.model.decode_step(
                params, view, token, t, plan=plan, policy=self.policy)
        tok = self.sampler.sample(logits, state, t)
        storage = lay.write_token(storage, view, table, t, num_pages)
        return tok, storage

    def _prefill_paged_impl(self, params, storage, tokens, slot, length,
                            state, table,
                            plan: Optional[LaunchPlan] = None,
                            num_pages: int = 1):
        """Fused single-slot prefill straight into the slot's pages."""
        lay = self.cache.layout
        with plan_scope(plan), activation_mesh(self.mesh):
            logits, view = self.model.prefill_slot_view(
                params, storage, tokens, slot, length,
                num_pages * self.cache.spec.page_size,
                plan=plan, kv_dtype=self.kv_dtype)
        tok = self.sampler.sample(logits[None], state, (length - 1)[None])
        storage = lay.write_slot(storage, view, table, slot, num_pages)
        return tok[0], storage

    def _verify_impl(self, params, caches, tokens, t, dlen, state,
                     plan: Optional[LaunchPlan] = None):
        """Speculative verify over the dense cache: score (B, M = K+1)
        token rows in one planned launch, accept/reject in-batch.
        ``dlen`` (B,) is each slot's TRUE draft count — ``accepted`` is
        clamped by it so mixed-k padding rows never commit."""
        with activation_mesh(self.mesh):
            logits, caches = self.model.verify_step(
                params, caches, tokens, t, plan=plan)
        toks, acc = self.sampler.verify(logits, tokens[:, 1:], state, t)
        acc = jnp.minimum(acc, dlen)
        return toks, acc, caches

    def _verify_paged_impl(self, params, storage, tokens, t, dlen, state,
                           table, plan: Optional[LaunchPlan] = None,
                           num_pages: int = 1):
        """Paged verify: gather the resident view, score the K+1-row
        block, then commit ONLY the pages overlapping each slot's
        accepted rows ``[t, t + accepted + 1)`` — rejected draft rows
        never reach storage (their span pages are redirected to the
        trash page inside the jitted step)."""
        lay = self.cache.layout
        view = lay.gather_view(storage, table, num_pages)
        with activation_mesh(self.mesh):
            logits, view = self.model.verify_step(
                params, view, tokens, t, plan=plan)
        toks, acc = self.sampler.verify(logits, tokens[:, 1:], state, t)
        acc = jnp.minimum(acc, dlen)
        storage = lay.write_rows(storage, view, table, t, acc + 1,
                                 tokens.shape[1], num_pages)
        return toks, acc, storage

    def _copy_page_impl(self, storage, src, dst):
        return self.cache.layout.copy_page(storage, src, dst)

    def _apply_copies(self) -> None:
        """Apply the cache manager's queued (src, dst) device page
        copies.  MUST run before any launch that could gather a
        copied-into page — until the copy lands the page holds garbage
        (fresh from the free list)."""
        for src, dst in self.cache.drain_copies():
            self._caches = self._copy_step(self._caches,
                                           jnp.asarray(src, jnp.int32),
                                           jnp.asarray(dst, jnp.int32))

    def _suffix_prefill_paged_impl(self, params, storage, tokens, slot,
                                   start, length, state, table,
                                   plan: Optional[LaunchPlan] = None,
                                   num_pages: int = 1):
        """Suffix-only fused prefill (prefix sharing): gather the slot's
        view — rows [0, start) already resident from adopted pages —
        compute only the unshared suffix against it, scatter back."""
        lay = self.cache.layout
        view = lay.slot_view(storage, table, slot, num_pages)
        with plan_scope(plan), activation_mesh(self.mesh):
            logits, view = self.model.prefill_suffix_view(
                params, view, tokens, start, length,
                plan=plan, kv_dtype=self.kv_dtype)
        tok = self.sampler.sample(logits[None], state, (length - 1)[None])
        storage = lay.write_slot(storage, view, table, slot, num_pages)
        return tok[0], storage

    def _build_decode(self, plan: LaunchPlan):
        if self.cache.is_paged:
            return jax.jit(
                functools.partial(self._decode_paged_impl, plan=plan,
                                  num_pages=self.cache.spec.view_pages(
                                      plan.bucket)),
                donate_argnums=(1,))
        return jax.jit(functools.partial(self._decode_impl, plan=plan),
                       donate_argnums=(1,))

    def _build_prefill(self, plan: LaunchPlan):
        if self.cache.is_paged:
            return jax.jit(
                functools.partial(self._prefill_paged_impl, plan=plan,
                                  num_pages=self.cache.spec.view_pages(
                                      plan.bucket)),
                donate_argnums=(1,))
        return jax.jit(functools.partial(self._prefill_impl, plan=plan),
                       donate_argnums=(1,))

    def _build_verify(self, plan: LaunchPlan):
        if self.cache.is_paged:
            return jax.jit(
                functools.partial(self._verify_paged_impl, plan=plan,
                                  num_pages=self.cache.spec.view_pages(
                                      plan.bucket)),
                donate_argnums=(1,))
        return jax.jit(functools.partial(self._verify_impl, plan=plan),
                       donate_argnums=(1,))

    def _build_suffix_prefill(self, plan: LaunchPlan):
        # plan.bucket is the VIEW bucket (whole resident prompt): the
        # gather must span prefix + suffix, like decode's resident view
        return jax.jit(
            functools.partial(self._suffix_prefill_paged_impl, plan=plan,
                              num_pages=self.cache.spec.view_pages(
                                  plan.bucket)),
            donate_argnums=(1,))

    # --- request lifecycle --------------------------------------------------

    def _check_speculation(self, spec: SpecConfig, who: str) -> None:
        """Shared submit-time / engine-default speculation gate: the
        drafter name must resolve, the family must support multi-row
        verify + kv_len rollback, and the verify launch is planned —
        it cannot ride the internal-heuristic fallback."""
        try:
            get_drafter(spec.method)
        except KeyError as e:
            raise ValueError(f"{who}: {e.args[0]}") from None
        if not self.model.supports_speculation:
            raise ValueError(
                f"{who}: {self.cfg.family} models cannot run speculative "
                "verify steps (needs a uniform full-attention stack over "
                "the standard k/v cache; see Model.supports_speculation)")
        if not self.use_metadata:
            raise ValueError(
                f"{who}: speculative decoding rides the metadata-enabled "
                "plan path (verify launches are planned under "
                "('verify', k, bucket) keys); set "
                "use_scheduler_metadata=True or drop the speculation knob")

    def validate(self, req: Request) -> None:
        """Raise on requests that could never run (no state mutated)."""
        self.sched.validate(req)
        self.sampler.check(req.sampling)
        spec = req.sampling.speculation or self._default_spec
        if spec is not None:
            self._check_speculation(spec, f"request {req.request_id}")
        if self.cache.is_paged:
            # +1: the request must also fit its FIRST decode-token row.
            # A prompt whose pages exactly fill the pool would admit,
            # then deadlock the FIFO head forever — alone in the pool,
            # waiting on a page no finish can ever free.
            need = self.cache.pages_for(len(req.prompt) + 1)
            limit = self.cache.max_request_pages()
            if need > limit:
                raise ValueError(
                    f"request {req.request_id}: prompt plus its first "
                    f"decode row needs {need} pages, page budget allows "
                    f"{limit} per request "
                    f"(page_size={self.cache.spec.page_size})")

    def submit(self, req: Request) -> int:
        """Enqueue a request; returns its handle (admission happens on a
        later :meth:`step`)."""
        handle = self._next_handle
        self.validate(req)                      # incl. sampler.check
        st = self.sched.submit(handle, req)
        self._next_handle += 1
        self._completions[handle] = st.completion
        self._queues[handle] = deque()
        self._undrained.append(handle)
        if self._obs.enabled:
            self._obs.on_submit(handle, req.request_id, len(req.prompt))
        return handle

    def has_work(self) -> bool:
        return self.sched.has_work()

    def step(self) -> List[Event]:
        """One scheduling step: admit pending requests into free slots
        (fused prefill = one planned launch each), then one lockstep
        decode launch over the live slots.  Returns the events."""
        assert self._params is not None, "call load(params) first"
        events: List[Event] = []
        while True:
            adm = self.sched.admit_next(self._admissible)
            if adm is None:
                break
            self._admit(*adm, events)
        live = self.sched.live()
        if live:
            self._decode_launch(live, events)
        if self._obs.enabled:
            occ, slots = self.sched.occupancy()
            kw = (dict(free_pages=self.cache.free_pages,
                       total_pages=self.cache.spec.total_pages)
                  if self.cache.is_paged else {})
            self._obs.sample_occupancy(occ, slots,
                                       self.sched.queue_depth(), **kw)
        return events

    def _admissible(self, st: SlotState) -> bool:
        """Page-budget admission gate (paged layout; dense always
        admits): the queue head needs its whole prompt's pages free —
        under prefix sharing, only the pages its shared prefix does NOT
        already cover."""
        if self.share_prefix:
            return self.cache.can_admit(st.request.prompt)
        return self.cache.can_reserve(len(st.request.prompt))

    def stream(self, handle: int) -> Iterator[Event]:
        """Iterate one handle's events in order, running :meth:`step`
        whenever the queue is empty.  Single consumer per handle; once
        FINISHED is yielded the handle is fully released (the consumer
        saw every token, so :meth:`drain` will not return it again) —
        a streaming-only server holds no per-request state after the
        stream ends."""
        if handle not in self._queues:
            raise ValueError(
                f"handle {handle} is unknown, already streamed to "
                "FINISHED, or drained")
        while True:
            # re-fetch per event: if a concurrent drain() released the
            # handle between yields, stop instead of replaying tokens
            # the drain already delivered from an orphaned queue
            q = self._queues.get(handle)
            if q is None:
                return
            if q:
                ev = q.popleft()
                yield ev
                if ev.kind == FINISHED:
                    self._queues.pop(handle, None)
                    self._completions.pop(handle, None)
                    if handle in self._undrained:
                        self._undrained.remove(handle)
                    return
            elif not self.sched.has_work():
                return
            else:
                self.step()

    def drain(self) -> List[Completion]:
        """Run to completion; returns every not-yet-drained submitted
        request's :class:`Completion`, sorted by request_id.  Drained
        handles are released — a long-lived engine holds state only for
        in-flight and not-yet-drained requests.  With
        ``ServeConfig.stats_path`` set, the plan-cache counters are
        snapshotted to that path as JSON (:meth:`PlanCacheStats.to_json`)."""
        while self.sched.has_work():
            self.step()
        done = []
        for h in self._undrained:
            done.append(self._completions.pop(h))
            self._queues.pop(h, None)
        self._undrained = []
        done.sort(key=lambda c: c.request_id)
        if self._stats_path:
            self.dump_stats(self._stats_path)
        if self._owns_obs:
            self.dump_obs()
        return done

    def _stats_snapshot(self) -> Dict[str, Any]:
        """The PlanCacheStats JSON snapshot plus this engine's identity
        (policy / shard / measured-table version when loaded)."""
        snap = self.stats.to_json()
        snap["policy"] = self.policy
        if self.shard_id is not None:
            snap["shard"] = self.shard_id
        if self.tune_table is not None:
            snap["table_version"] = self.tune_table.version
        return snap

    def dump_stats(self, path: str) -> None:
        """Atomically write the PlanCacheStats JSON snapshot (temp file
        in the target directory + ``os.replace`` — a concurrent reader
        never sees a torn file)."""
        atomic_write_json(path, self._stats_snapshot())

    def dump_obs(self) -> None:
        """Write the trace / metrics artifacts the engine's own
        ``ServeConfig`` paths asked for (no-op when neither is set or
        the observer was injected — the injector owns the dump)."""
        if self._obs.enabled and (self._trace_path or self._metrics_path):
            self._obs.dump(self._trace_path, self._metrics_path,
                           plan_stats=self._stats_snapshot())

    # --- internals ----------------------------------------------------------

    def _admit(self, i: int, st: SlotState, events: List[Event]) -> None:
        if self._obs.enabled:           # closes the queue_wait span
            self._obs.on_admit_start(st.handle)
        # the whole prompt's pages are reserved up front (all-or-nothing;
        # _admissible already checked the free list, so this cannot fail)
        if self.share_prefix:
            shared = self.cache.admit_prompt(i, st.request.prompt)
            assert shared is not None, "admission raced the page free list"
        else:
            ok = self.cache.reserve(i, len(st.request.prompt))
            assert ok, "admission raced the page free list"
            shared = 0
        # the reset launch is only needed when the admission path leaves
        # any of the slot's cache rows unwritten: always for loop
        # teacher-forcing, and for fused prefill only when the model
        # says so (encdec's cross-cache leaves stay untouched)
        if (self.prefill_mode != "fused"
                or not self.model.prefill_writes_full_slot):
            self._caches = self._zero_step(
                self._caches, jnp.asarray(i, jnp.int32))
        for name, value in self.sampler.slot_state(
                st.request.sampling).items():
            self._state[name][i] = value
        self._state_dev = None                  # row dirtied: re-upload
        spec = st.request.sampling.speculation or self._default_spec
        self._spec_cfg[i] = spec
        self._drafters[i] = get_drafter(spec.method)() if spec else None
        self._spec_rejects[i] = 0
        if self.prefill_mode == "fused":
            self._admit_fused(i, st, events, shared)
        else:
            st.prompt_left = list(st.request.prompt)
            self._pos[i] = 0
            self._next_token[i] = st.prompt_left.pop(0)
            if self._obs.enabled:       # teacher-forcing admission
                self._obs.on_admit_end(st.handle, "loop")

    def _admit_fused(self, i: int, st: SlotState, events: List[Event],
                     shared: int = 0) -> None:
        """Prefill the prompt in one planned launch; the slot joins the
        decode lockstep already holding its first token.  With
        ``shared`` > 0 (prefix sharing) the launch is the SUFFIX-only
        specialization: rows [0, shared) arrived with the adopted pages,
        so only ``n - shared`` rows are computed — and the launch counts
        under an ``("sprefill", ...)`` key, never ``("prefill", ...)``,
        which is the structural form of the zero-prefill-launches-for-
        shared-pages claim."""
        prompt = st.request.prompt
        n = len(prompt)
        state_row = {k: jnp.asarray(v[i:i + 1])
                     for k, v in self._state.items()}
        if shared:
            # adopted boundary rows travel by device page copy — land
            # them before the suffix launch gathers the slot's view
            self._apply_copies()
            entry = self.sched.suffix_prefill_entry(
                n - shared, n, self._build_suffix_prefill)
            toks = np.zeros(entry.key[2], np.int32)
            toks[:n - shared] = prompt[shared:]
            args = (self._params, self._caches, jnp.asarray(toks),
                    jnp.asarray(i, jnp.int32),
                    jnp.asarray(shared, jnp.int32),
                    jnp.asarray(n, jnp.int32), state_row,
                    self.cache.table_device())
        else:
            entry = self.sched.prefill_entry(n, self._build_prefill)
            toks = np.zeros(entry.key[1], np.int32)
            toks[:n] = prompt
            args = (self._params, self._caches, jnp.asarray(toks),
                    jnp.asarray(i, jnp.int32), jnp.asarray(n, jnp.int32),
                    state_row)
            if self.cache.is_paged:
                args += (self.cache.table_device(),)
        t0 = self._obs.now_us() if self._obs.enabled else 0
        tok, self._caches = entry.step(*args)
        tok = int(tok)                  # device sync closes the launch
        if self._obs.enabled:
            self._obs.on_launch("sprefill" if shared else "prefill",
                                entry.key, entry.plan, t0)
        self.cache.note_write(i, n - 1)
        if self.share_prefix:
            # index this prompt's (now fully resident) full pages so the
            # NEXT request sharing the prefix adopts instead of computing
            self.cache.register_prefix(i, prompt)
        self._pos[i] = n
        st.completion.steps += 1
        if self._obs.enabled:
            # close the admit span BEFORE emitting: the first token may
            # immediately finish the request, and the request span must
            # contain the admit span
            self._obs.on_admit_end(st.handle,
                                   "suffix" if shared else "full",
                                   shared, shared * self._kv_row_bytes)
        self._emit_token(i, st, tok, events)

    def _decode_launch(self, live, events: List[Event]) -> None:
        drafts = self._collect_drafts(live)
        if drafts:
            self._verify_launch(live, drafts, events)
        else:
            self._plain_launch(live, events)

    def _plain_launch(self, live, events: List[Event]) -> None:
        if self.cache.is_paged:
            # every live slot is about to write row _pos[i]: allocate its
            # page now, and finish (only) the requests whose allocation
            # the pool cannot cover — the per-request page-exhaustion
            # signal.  A finish releases pages, so later slots in the
            # same pass may succeed because an earlier one was culled.
            for i, st in live:
                if not self.cache.ensure(i, int(self._pos[i])):
                    self._finish_capacity(i, st, events)
            live = self.sched.live()
            if not live:
                return
            if self.share_prefix:
                # ensure() may have copy-on-written a shared page;
                # its contents must land before this launch's gather
                self._apply_copies()
        for i, _ in live:                       # residency bookkeeping
            self.cache.note_write(i, int(self._pos[i]))
        tok = jnp.asarray(self._next_token)
        t = jnp.asarray(self._pos)
        t_max = max(int(self._pos[i]) for i, _ in live)
        if self.use_metadata:
            entry = self.sched.decode_entry(t_max, self._build_decode)
            step = entry.step
        else:
            entry = None
            step = self._fallback_step
            # attribute this unplanned launch: the policy saw the PADDED
            # cache length at trace time; record what was resident
            self.stats.record_fallback(t_max + 1, self.max_len)
        if self._state_dev is None:
            self._state_dev = {k: jnp.asarray(v)
                               for k, v in self._state.items()}
        args = (self._params, self._caches, tok, t, self._state_dev)
        if self.cache.is_paged:
            args += (self.cache.table_device(),)
        t0 = self._obs.now_us() if self._obs.enabled else 0
        out, self._caches = step(*args)
        out = np.asarray(out)               # host sync closes the launch
        if self._obs.enabled:
            self._obs.on_launch(
                "decode",
                entry.key if entry is not None else None,
                entry.plan if entry is not None else None, t0,
                handles=[s.handle for _, s in live])
        for i, st in live:
            self._advance(i, st, int(out[i]), events)

    # --- speculative verify launch ------------------------------------------

    def _collect_drafts(self, live) -> Dict[int, List[int]]:
        """Ask each speculating slot's drafter for draft tokens.

        Only slots that are past their prompt, still enabled, and with
        generation budget left get to draft; everything else rides the
        launch as a 1-token row.  Returns only NON-empty drafts — an
        empty dict means this step is a plain decode launch."""
        drafts: Dict[int, List[int]] = {}
        for i, st in live:
            spec, drafter = self._spec_cfg[i], self._drafters[i]
            if spec is None or drafter is None or st.prompt_left:
                continue
            # a draft row past the request's remaining budget could
            # never emit — don't pay to verify it.  The cache-wall bound
            # is one stricter than decode's (max_len - 2): the whole
            # accepted run must land strictly below the capacity-finish
            # position, else a multi-token emit would hit the wall after
            # FEWER tokens than sequential decode (the wall check reads
            # the already-advanced position) — breaking bit-equality
            budget = st.request.max_new_tokens \
                - len(st.completion.tokens) - 1
            room = self.max_len - 2 - int(self._pos[i])
            k = min(spec.k, budget, room)
            if k < 1:
                continue
            history = st.completion.prompt + st.completion.tokens
            d = list(drafter.propose(history, k))[:k]
            if d:
                drafts[i] = d
        return drafts

    def _verify_launch(self, live, drafts: Dict[int, List[int]],
                       events: List[Event]) -> None:
        """One planned verify launch: every live slot rides (lockstep),
        speculating slots carry their drafts, the rest take 1-token
        rows (``dlen = 0`` — behaviorally a decode row)."""
        if self.cache.is_paged:
            # each slot writes rows [pos, pos + dlen]: allocate row pos
            # like decode (failure finishes the request), then extend
            # page-by-page for the draft rows, truncating the draft at
            # the first row the pool cannot cover (speculation must not
            # steal a page a plain decode step would have had)
            for i, st in list(live):
                p = int(self._pos[i])
                if not self.cache.ensure(i, p):
                    drafts.pop(i, None)
                    self._finish_capacity(i, st, events)
                    continue
                d = drafts.get(i)
                if not d:
                    continue
                kept = 0
                while kept < len(d) and self.cache.ensure(i, p + kept + 1):
                    kept += 1
                drafts[i] = d[:kept]
            live = self.sched.live()
            if not live:
                return
            if self.share_prefix:
                self._apply_copies()
        K = max((len(drafts.get(i, [])) for i, _ in live), default=0)
        if K == 0:                      # every draft culled: plain step
            self._plain_launch(live, events)
            return
        toks = np.zeros((self.B, K + 1), np.int32)
        dlen = np.zeros(self.B, np.int32)
        toks[:, 0] = self._next_token
        t_max = 0
        for i, _ in live:
            d = drafts.get(i, [])
            dlen[i] = len(d)
            toks[i, 1:1 + len(d)] = d
            self.cache.note_write(i, int(self._pos[i]) + len(d))
            t_max = max(t_max, int(self._pos[i]) + len(d))
        entry = self.sched.verify_entry(K, t_max, self._build_verify)
        if self._state_dev is None:
            self._state_dev = {k: jnp.asarray(v)
                               for k, v in self._state.items()}
        args = (self._params, self._caches, jnp.asarray(toks),
                jnp.asarray(self._pos), jnp.asarray(dlen),
                self._state_dev)
        if self.cache.is_paged:
            args += (self.cache.table_device(),)
        t0 = self._obs.now_us() if self._obs.enabled else 0
        out, acc, self._caches = entry.step(*args)
        out, acc = np.asarray(out), np.asarray(acc)
        if self._obs.enabled:
            self._obs.on_launch("verify", entry.key, entry.plan, t0,
                                handles=[s.handle for _, s in live])
        for i, st in live:
            self._advance_verified(i, st, drafts.get(i, []),
                                   int(acc[i]), out[i], events)

    def _advance_verified(self, i: int, st: SlotState, d: List[int],
                          a: int, row: np.ndarray,
                          events: List[Event]) -> None:
        """Post-verify bookkeeping for one slot: commit the accepted
        positions, emit ``d[:a]`` plus the correction/bonus token, roll
        ``kv_len`` back over the rejected rows, and run the
        acceptance-rate / max_rejects accounting."""
        st.completion.steps += 1
        if st.prompt_left:              # loop-mode prefill rider
            self._pos[i] += 1
            self._next_token[i] = st.prompt_left.pop(0)
            return
        self._pos[i] += a + 1
        self.cache.truncate(i, int(self._pos[i]))
        emit = d[:a] + [int(row[a])]
        emitted = 0
        for tok in emit:
            self._emit_token(i, st, tok, events)
            emitted += 1
            if st.completion.finish_reason is not None:
                break
        if not d:                       # non-speculating rider
            return
        spec = self._spec_cfg[i]
        self.stats.record_spec_step(len(d), a, emitted)
        drafter = self._drafters[i]
        if drafter is not None:
            drafter.observe(a, len(d))
        if a == 0:
            self._spec_rejects[i] += 1
            if spec is not None and spec.max_rejects is not None \
                    and self._spec_rejects[i] >= spec.max_rejects:
                # this request's traffic doesn't draft well — stop
                # paying for verify rows it keeps rejecting
                self._spec_cfg[i] = None
                self._drafters[i] = None
                self.stats.record_spec_disabled()
        else:
            self._spec_rejects[i] = 0

    def _advance(self, i: int, st: SlotState, tok_out: int,
                 events: List[Event]) -> None:
        self._pos[i] += 1
        st.completion.steps += 1
        if st.prompt_left:                      # loop-mode prefilling
            self._next_token[i] = st.prompt_left.pop(0)
            return
        self._emit_token(i, st, tok_out, events)

    def _release(self, i: int) -> None:
        """Free a finished request's slot AND its cache residency (page
        allocations return to the pool; the slot's table row goes back
        to the trash page so its lockstep writes land harmlessly)."""
        self.sched.finish(i)
        self.cache.release(i)

    def _finish(self, i: int, st: SlotState, reason: str,
                events: List[Event]) -> None:
        """The one finish protocol: stamp the reason, emit FINISHED to
        both the step's event list and the handle's queue, release the
        slot + its cache residency."""
        comp = st.completion
        comp.finish_reason = reason
        fin = Event(FINISHED, st.handle, comp.request_id,
                    finish_reason=reason)
        events.append(fin)
        self._queues[st.handle].append(fin)
        self._release(i)
        if self._obs.enabled:
            self._obs.on_finish(st.handle, reason)

    def _finish_capacity(self, i: int, st: SlotState,
                         events: List[Event]) -> None:
        """Finish ONE request on page-pool exhaustion (pre-launch: no
        token is produced this step — there is nowhere to write its KV
        row).  The rest of the batch keeps decoding."""
        if not self._warned_page_capacity:
            self._warned_page_capacity = True
            warnings.warn(
                f"request {st.request.request_id} exhausted the KV page "
                f"pool ({self.cache.spec.total_pages} pages of "
                f"{self.cache.spec.page_size}) mid-generation; finishing "
                "with finish_reason='cache_capacity' (further page "
                "exhaustions on this engine are silent)",
                RuntimeWarning, stacklevel=3)
            if self._obs.enabled:
                self._obs.on_warning(
                    "page_capacity",
                    f"request {st.request.request_id} exhausted the "
                    f"{self.cache.spec.total_pages}-page KV pool")
        self._finish(i, st, FINISH_CACHE_CAPACITY, events)

    def _finish_reason(self, i: int, st: SlotState,
                       token: int) -> Optional[str]:
        req = st.request
        if req.eos_id is not None and token == req.eos_id:
            return FINISH_EOS
        if token in req.sampling.stop:
            return FINISH_STOP
        if len(st.completion.tokens) >= req.max_new_tokens:
            return FINISH_LENGTH
        if self._pos[i] >= self.max_len - 1:
            if not self._warned_len_capacity:
                self._warned_len_capacity = True
                warnings.warn(
                    f"request {req.request_id} hit the KV cache capacity "
                    f"(max_len={self.max_len}) mid-generation; finishing "
                    "with finish_reason='cache_capacity' (further "
                    "max_len hits on this engine are silent)",
                    RuntimeWarning, stacklevel=3)
                if self._obs.enabled:
                    self._obs.on_warning(
                        "len_capacity",
                        f"request {req.request_id} hit the KV cache "
                        f"capacity (max_len={self.max_len})")
            return FINISH_CACHE_CAPACITY
        return None

    def _emit_token(self, i: int, st: SlotState, token: int,
                    events: List[Event]) -> None:
        comp = st.completion
        comp.tokens.append(token)
        q = self._queues[st.handle]
        ev = Event(TOKEN, st.handle, comp.request_id, token=token,
                   index=len(comp.tokens) - 1)
        events.append(ev)
        q.append(ev)
        if self._obs.enabled:
            self._obs.on_token(st.handle, ev.index)
        reason = self._finish_reason(i, st, token)
        if reason is not None:
            self._finish(i, st, reason, events)
        else:
            self._next_token[i] = token


class DecodeEngine:
    """Legacy batch-synchronous facade: ``generate(requests)``.

    A thin wrapper over :class:`ServingEngine` pinned to
    ``prefill_mode="loop"`` (decode-by-teacher-forcing admission) and
    the pure-argmax :class:`~repro.serving.sampling.GreedySampler` (the
    wrapper's documented contract is greedy-only, and argmax keeps the
    jitted step as cheap as the pre-redesign one — no per-token vocab
    sorts).  That makes its completions bit-identical to the
    pre-redesign engine: same plan buckets, same specialized steps,
    same launch order, same ``jnp.argmax``.  New code should drive
    :class:`ServingEngine` directly (see the README migration map).
    """

    def __init__(self, model: Model, scfg: ServeConfig, *,
                 max_len: int = 256, batch_slots: int = 4,
                 policy: Optional[str] = None,
                 tune_table: Optional[Any] = None):
        self.engine = ServingEngine(model, scfg, max_len=max_len,
                                    batch_slots=batch_slots, policy=policy,
                                    prefill_mode="loop",
                                    sampler=GreedySampler(),
                                    tune_table=tune_table)
        self.model = model
        self.cfg = model.cfg
        self.policy = self.engine.policy
        self.max_len = max_len
        self.B = batch_slots
        self.use_metadata = self.engine.use_metadata
        self.planner = self.engine.planner

    @property
    def stats(self) -> PlanCacheStats:
        return self.engine.stats

    def load(self, params: Pytree) -> None:
        self.engine.load(params)

    def planned_splits(self) -> Dict[int, int]:
        return self.engine.planned_splits()

    def _metadata(self, t_max: int) -> LaunchPlan:
        return self.engine._metadata(t_max)

    def generate(self, requests: Sequence[Request]) -> List[Completion]:
        # validate up front: a bad request must fail fast, not abort the
        # batch mid-flight after other requests already completed
        for req in requests:
            self.engine.validate(req)
        for req in requests:
            self.engine.submit(req)
        return self.engine.drain()
