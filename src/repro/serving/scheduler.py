"""Scheduler: slot admission, per-slot request state, bucketed plans.

The scheduler is the pure-Python half of the serving engine: it owns
the fixed pool of decode slots, the pending-request queue, and — via
the PR-2 :class:`~repro.plan.Planner` / :class:`~repro.plan.PlanCache`
— every launch-plan decision the engine consumes.  The engine owns the
arrays and the jitted steps; it asks the scheduler *which* plan covers
the current launch and hands back a builder for the specialized step.

Two plan families share the one cache (and its
:class:`~repro.plan.PlanCacheStats` counters):

- **decode** plans, keyed by the int cache-length bucket (exactly the
  pre-redesign engine's keys, so legacy stats assertions keep holding);
- **prefill** plans, keyed by ``("prefill", bucket)`` where ``bucket``
  is the prompt length rounded up to ``prefill_bucket`` — one planned,
  jitted fused-prefill launch per admission, reused across every prompt
  in the same bucket.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.plan import AttentionSpec, LaunchPlan, PlanCache, Planner, \
    bucket_seqlen
from repro.serving.sampling import GREEDY, SamplingParams


@dataclass
class Request:
    """One generation request (the engine's public input)."""
    request_id: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    sampling: SamplingParams = GREEDY


@dataclass
class Completion:
    """One finished (or in-flight) request's output."""
    request_id: int
    prompt: List[int]
    tokens: List[int] = field(default_factory=list)
    steps: int = 0
    finish_reason: Optional[str] = None


@dataclass
class SlotState:
    """Per-slot request lifecycle state (host side).

    The launch-facing per-slot numerics (next write position, next fed
    token) live ONLY in the engine's arrays — they must survive a
    slot's death for legacy bit-equality, so duplicating them here
    would invite desync."""
    handle: int
    request: Request
    completion: Completion
    prompt_left: List[int] = field(default_factory=list)  # loop prefill


@dataclass(frozen=True)
class PlanEntry:
    """One plan-cache entry: a frozen plan + its specialized step."""
    key: Any
    plan: LaunchPlan
    step: Any                          # jitted, specialized on ``plan``

    @property
    def metadata(self) -> LaunchPlan:  # legacy field name
        return self.plan


class Scheduler:
    """Slot admission + per-slot state + bucketed plan selection."""

    def __init__(self, cfg: ModelConfig, *, batch_slots: int, max_len: int,
                 policy: str, num_splits_override: Optional[int] = None,
                 bucket_width: int = 128,
                 prefill_bucket: Optional[int] = None,
                 plan_capacity: Optional[int] = None,
                 cache_layout: str = "dense",
                 kv_dtype: str = "bfloat16",
                 table: Optional[Any] = None,
                 mesh: Optional[Any] = None,
                 seq_shards: int = 1,
                 plans: Optional[PlanCache] = None):
        self.cfg = cfg
        self.B = batch_slots
        self.max_len = max_len
        self.bucket_width = bucket_width
        self.prefill_bucket_width = prefill_bucket or bucket_width
        self.cache_layout = cache_layout
        self.kv_dtype = kv_dtype
        self.kv_quantized = kv_dtype != "bfloat16"
        # mesh-native serving: seq_shards > 1 routes every plan through
        # Planner.mesh_plan so ``mesh_splits`` provenance lands on each
        # LaunchPlan, and decode plans are realized fused over ``mesh``'s
        # "model" axis (the sequence dimension of the paged/dense cache)
        self.mesh = mesh
        self.seq_shards = seq_shards
        self.planner = Planner(policy=policy,
                               num_splits_override=num_splits_override,
                               table=table)
        # ``plans`` lets the mesh-native engine share one PlanCache per
        # shard topology (keyed on the ShardSpec fingerprint upstream)
        self.plans: PlanCache = plans if plans is not None \
            else PlanCache(plan_capacity)
        if table is not None:
            # measured-policy lookups/fallbacks land in the SAME stats
            # object as plan-cache hits/misses (one observability surface)
            table.attach_stats(self.plans.stats)
        self.slots: List[Optional[SlotState]] = [None] * batch_slots
        self.pending: Deque[SlotState] = deque()

    # --- planning core ------------------------------------------------------

    def _plan(self, spec: AttentionSpec, bucket: int) -> LaunchPlan:
        """The one planner entry every plan family goes through: under a
        sequence-sharded topology, plans carry ``mesh_splits`` provenance
        (the chips-for-SMs occupancy decision, or the storage-forced
        shard count when H_KV doesn't divide the axis)."""
        if self.seq_shards > 1:
            return self.planner.mesh_plan(spec, axis_size=self.seq_shards,
                                          bucket=bucket)
        return self.planner.plan(spec, bucket=bucket)

    def _realize(self, plan: LaunchPlan) -> LaunchPlan:
        """Realize a DECODE plan's mesh split as the fused seq-sharded
        kernel path: pin the shard mesh on the plan so
        ``decode_attention_update`` takes the shard_map branch (per-chip
        partial softmax + LSE combine).  Verify/prefill plans keep their
        provenance but stay GSPMD-partitioned (the fused path is
        single-query-row only)."""
        if self.mesh is not None and plan.mesh_splits \
                and plan.mesh_splits > 1:
            return dataclasses.replace(plan, min_splits=1,
                                       seq_shard_mesh=self.mesh,
                                       seq_shard_axis="model")
        return plan

    # --- admission ----------------------------------------------------------

    def validate(self, req: Request) -> None:
        """Fail fast on requests that could never run (an admitted bad
        request must not abort a batch mid-flight)."""
        if not req.prompt:
            raise ValueError(f"request {req.request_id}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.request_id}: max_new_tokens must be >= 1, "
                f"got {req.max_new_tokens}")
        if len(req.prompt) >= self.max_len:
            # prefill would write past the cache and silently corrupt
            # the last row (dynamic_update_slice clamps) — refuse
            raise ValueError(
                f"request {req.request_id}: prompt length "
                f"{len(req.prompt)} >= max_len ({self.max_len})")

    def submit(self, handle: int, req: Request) -> SlotState:
        """Enqueue a request the engine has already passed through
        :meth:`validate` (the engine owns the single validation pass —
        duplicating the checks here would invite drift)."""
        st = SlotState(handle, req,
                       Completion(req.request_id, list(req.prompt)))
        self.pending.append(st)
        return st

    def admit_next(self, admissible: Optional[
            Callable[[SlotState], bool]] = None
            ) -> Optional[Tuple[int, SlotState]]:
        """Pop one pending request into the lowest free slot (None when
        no slot is free or nothing is pending).

        ``admissible`` gates the queue head on a resource the scheduler
        does not own — the engine passes the cache manager's page-budget
        check, so admission is against FREE PAGES rather than the mere
        existence of a free slot.  Admission stays FIFO: a refused head
        blocks the queue (no reordering) until a finishing request frees
        its pages.
        """
        if not self.pending:
            return None
        for i, slot in enumerate(self.slots):
            if slot is None:
                if admissible is not None \
                        and not admissible(self.pending[0]):
                    return None
                st = self.pending.popleft()
                self.slots[i] = st
                return i, st
        return None

    def finish(self, i: int) -> None:
        self.slots[i] = None

    # --- liveness -----------------------------------------------------------

    def live(self) -> List[Tuple[int, SlotState]]:
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def has_work(self) -> bool:
        return bool(self.pending) or any(s is not None for s in self.slots)

    # --- decode planning ----------------------------------------------------

    def _kv_heads(self) -> int:
        """H_KV as the decode workload sees it (MLA: one shared latent)."""
        return 1 if self.cfg.mla else self.cfg.num_kv_heads

    def decode_bucket(self, t_max: int) -> int:
        """RESIDENT-length bucket for the longest live position.

        This is what keys decode plans (and their jitted
        specializations): the per-step resident max, never the engine's
        padded ``max_len`` — a short-context request in a long-capacity
        engine plans (and, under the paged layout, attends) on what is
        actually resident.
        """
        return bucket_seqlen(min(int(t_max) + 1, self.max_len),
                             self.bucket_width)

    def decode_spec(self, bucket: int) -> AttentionSpec:
        cfg = self.cfg
        return AttentionSpec.decode(self.B, bucket, cfg.num_heads,
                                    self._kv_heads(),
                                    cfg.resolved_head_dim,
                                    kv_dtype=self.kv_dtype,
                                    layout=self.cache_layout)

    def decode_plan(self, t_max: int) -> LaunchPlan:
        """Compute (not cache) the frozen decode plan for ``t_max``."""
        bucket = self.decode_bucket(t_max)
        return self._realize(self._plan(self.decode_spec(bucket), bucket))

    def decode_entry(self, t_max: int,
                     build: Callable[[LaunchPlan], Any]) -> PlanEntry:
        """Plan-cache lookup: one specialized jitted step per bucket."""
        bucket = self.decode_bucket(t_max)

        def miss() -> PlanEntry:
            plan = self._realize(self._plan(self.decode_spec(bucket),
                                            bucket))
            return PlanEntry(bucket, plan, build(plan))

        return self.plans.get_or_build(bucket, miss)

    # --- speculative verify planning ----------------------------------------

    def verify_spec(self, k: int, bucket: int) -> AttentionSpec:
        """The verify-kind spec: a ``k + 1``-row query block (current
        token + k drafts) against the resident-length bucket."""
        cfg = self.cfg
        return AttentionSpec.verify(self.B, k + 1, bucket, cfg.num_heads,
                                    self._kv_heads(),
                                    cfg.resolved_head_dim,
                                    kv_dtype=self.kv_dtype,
                                    layout=self.cache_layout)

    def verify_entry(self, k: int, t_max: int,
                     build: Callable[[LaunchPlan], Any]) -> PlanEntry:
        """One planned, jitted verify specialization per
        ``("verify", k, bucket)`` key, resident in the same PlanCache as
        decode/prefill plans.  ``t_max`` is the max position of any row
        the launch writes (each slot's position + its draft count), so
        the bucket covers the speculative extent; the split decision
        runs the same sequence-aware policy as decode, on the k+1-row
        workload (``num_m_blocks`` scales with the query block — the
        occupancy shift speculation buys)."""
        bucket = self.decode_bucket(t_max)
        key = ("verify", k, bucket)

        def miss() -> PlanEntry:
            plan = self._plan(self.verify_spec(k, bucket), bucket)
            return PlanEntry(key, plan, build(plan))

        return self.plans.get_or_build(key, miss)

    # --- prefill planning ---------------------------------------------------

    def prefill_len(self, prompt_len: int) -> int:
        """Prompt length rounded up to its prefill bucket (capped at the
        cache length so the padded prompt always fits)."""
        return min(bucket_seqlen(prompt_len, self.prefill_bucket_width),
                   self.max_len)

    def prefill_spec(self, bucket: int) -> AttentionSpec:
        cfg = self.cfg
        return AttentionSpec.prefill(1, bucket, cfg.num_heads,
                                     self._kv_heads(),
                                     cfg.resolved_head_dim)

    def prefill_entry(self, prompt_len: int,
                      build: Callable[[LaunchPlan], Any]) -> PlanEntry:
        """One planned, jitted fused-prefill specialization per prompt-
        length bucket, resident in the same PlanCache as decode plans."""
        bucket = self.prefill_len(prompt_len)
        key = ("prefill", bucket)

        def miss() -> PlanEntry:
            plan = self._plan(self.prefill_spec(bucket), bucket)
            return PlanEntry(key, plan, build(plan))

        return self.plans.get_or_build(key, miss)

    def suffix_prefill_entry(self, suffix_len: int, total_len: int,
                             build: Callable[[LaunchPlan], Any]
                             ) -> PlanEntry:
        """Suffix-only prefill specialization (prefix sharing): queries
        span the unshared suffix (bucketed to ``mb``) while keys span
        the whole resident prompt (the view bucket ``vb``), so entries
        key on the PAIR — ``("sprefill", vb, mb)``.  The launch counter
        under these keys is what lets callers assert zero (full)
        prefill launches for shared admissions."""
        mb = min(bucket_seqlen(suffix_len, self.prefill_bucket_width),
                 self.max_len)
        vb = self.prefill_len(total_len)
        key = ("sprefill", vb, mb)

        def miss() -> PlanEntry:
            cfg = self.cfg
            spec = AttentionSpec("prefill", 1, mb, vb, cfg.num_heads,
                                 self._kv_heads(), cfg.resolved_head_dim)
            plan = self._plan(spec, vb)
            return PlanEntry(key, plan, build(plan))

        return self.plans.get_or_build(key, miss)

    # --- observability ------------------------------------------------------

    def queue_depth(self) -> int:
        """Requests still waiting for a slot (repro.obs gauge)."""
        return len(self.pending)

    def occupancy(self) -> Tuple[int, int]:
        """``(live_slots, total_slots)`` (repro.obs gauges)."""
        return sum(s is not None for s in self.slots), self.B

    def planned_splits(self) -> Dict[int, int]:
        """bucket -> frozen num_splits, for every resident DECODE plan."""
        return {k: e.plan.num_splits for k, e in self.plans.items()
                if isinstance(k, int)}

    def planned_prefill_buckets(self) -> List[int]:
        """Resident prefill-plan buckets (sorted)."""
        return sorted(k[1] for k in self.plans.keys()
                      if isinstance(k, tuple) and k[0] == "prefill")

    def planned_suffix_buckets(self) -> List[Tuple[int, int]]:
        """Resident suffix-prefill (view, suffix) bucket pairs (sorted)."""
        return sorted((k[1], k[2]) for k in self.plans.keys()
                      if isinstance(k, tuple) and k[0] == "sprefill")

    def planned_verify_keys(self) -> List[Tuple[int, int]]:
        """Resident verify-plan (k, bucket) pairs (sorted)."""
        return sorted((k[1], k[2]) for k in self.plans.keys()
                      if isinstance(k, tuple) and k[0] == "verify")
