"""ShardResolver: ShardSpec -> ShardPlan (meshes + plan-cache identity).

The resolver is where a declarative :class:`~repro.shard.ShardSpec`
meets the live device set: it validates divisibility against the cache
layout the engine will run, builds the global ``(dp, sp)`` mesh and the
per-shard ``(1, sp)`` sub-meshes over an EXPLICIT device grid
(:func:`~repro.launch.mesh.make_engine_mesh` — deterministic, never
``mesh_utils`` reordering), and fingerprints the result so one
:class:`~repro.plan.PlanCache` per (topology, shard) is shared by every
engine resolved to the same topology in a process.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax

from repro.launch.mesh import make_engine_mesh
from repro.plan import PlanCache
from repro.shard.spec import ShardSpec

# process-wide registry: one PlanCache (plans AND compiled steps) per
# (topology fingerprint, shard index, engine identity).  Determinism of
# the device grid is what makes sharing compiled steps safe: shard d of
# topology T always owns the same devices, so a cached jitted step's
# closed-over sub-mesh is THE sub-mesh of every later same-identity
# engine.
_PLAN_CACHES: Dict[Tuple, PlanCache] = {}


def shard_plan_cache(key: Tuple, capacity: Optional[int] = None
                     ) -> PlanCache:
    """The registry entry for ``key``, created on first use."""
    cache = _PLAN_CACHES.get(key)
    if cache is None:
        cache = PlanCache(capacity)
        _PLAN_CACHES[key] = cache
    return cache


def clear_shard_plan_caches() -> None:
    """Drop every registered per-topology PlanCache (tests/benchmarks:
    isolate stats across engine generations)."""
    _PLAN_CACHES.clear()


@dataclass(frozen=True)
class ShardPlan:
    """The resolved artifact: concrete meshes + the topology identity.

    ``mesh`` spans all ``dp * sp`` devices on axes ``("data", "model")``;
    ``submeshes[d]`` is shard ``d``'s ``(1, sp)`` slice of the same
    grid.  ``fingerprint`` extends the spec's with the backend identity
    (plans and compiled steps must not survive a device-set change).
    """
    spec: ShardSpec
    mesh: Any
    submeshes: Tuple[Any, ...]
    devices: Tuple[Any, ...]
    fingerprint: str = field(default="")

    def shard_devices(self, d: int) -> Tuple[Any, ...]:
        """The devices shard ``d`` owns (row ``d`` of the grid)."""
        sp = self.spec.sp
        return self.devices[d * sp:(d + 1) * sp]

    def plan_cache(self, shard: int, ident: Tuple = (),
                   capacity: Optional[int] = None) -> PlanCache:
        """Shard ``shard``'s per-topology PlanCache, shared across every
        same-identity engine in this process.  ``ident`` folds in the
        engine knobs compiled steps close over (model, policy, layout,
        sampler, ...) so differently-configured engines never share."""
        return shard_plan_cache(
            (self.fingerprint, shard) + tuple(ident), capacity)

    def describe(self) -> Dict[str, Any]:
        d = dict(self.spec.describe())
        d["fingerprint"] = self.fingerprint
        d["devices"] = [str(x) for x in self.devices]
        return d


@dataclass(frozen=True)
class ShardResolver:
    """Resolves a :class:`ShardSpec` against the live device set."""
    spec: ShardSpec

    def resolve(self, *, max_len: int, cache_layout: str = "dense",
                page_size: int = 64,
                devices: Optional[Sequence[Any]] = None) -> ShardPlan:
        """Validate + build the meshes.  Divisibility is checked here
        (fail at resolution, not at the first launch): the fused
        sequence-sharded decode splits the cache's L dim — ``max_len``
        for dense storage, the gathered view (a ``page_size`` multiple)
        for paged."""
        s = self.spec
        if s.sp > 1:
            if cache_layout == "paged":
                if page_size % s.sp:
                    raise ValueError(
                        f"page_size ({page_size}) must divide over "
                        f"sp={s.sp} for sequence-sharded paged decode")
            elif max_len % s.sp:
                raise ValueError(
                    f"max_len ({max_len}) must divide over sp={s.sp} "
                    "for sequence-sharded decode")
        devs = tuple(devices) if devices is not None \
            else tuple(jax.devices())
        mesh, submeshes = make_engine_mesh(s.dp, s.sp, devs)
        used = devs[:s.num_devices]
        d0 = used[0]
        fp = (f"{s.fingerprint}.{jax.default_backend()}."
              f"{getattr(d0, 'device_kind', '?')}.{len(used)}")
        return ShardPlan(spec=s, mesh=mesh, submeshes=submeshes,
                         devices=used, fingerprint=fp)
