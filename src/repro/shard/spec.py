"""ShardSpec: the declarative mesh-native serving topology.

The spec is pure data — how many data-parallel slot shards (``dp``),
how many chips each shard sequence-shards its KV cache over (``sp``),
how many decode slots and KV pages each shard owns, and how params land
on a shard's sub-mesh.  Nothing here touches jax: resolution (device
grids, NamedShardings, divisibility against a concrete cache layout)
is the :class:`~repro.shard.ShardResolver`'s job, exactly like
``TuneSpec`` -> ``Calibrator`` and ``CacheSpec`` -> ``CacheManager``.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, replace
from typing import Any, Dict, Optional

PARAM_POLICIES = ("replicated", "tp")


@dataclass(frozen=True)
class ShardSpec:
    """A ``dp x sp`` serving topology over ``dp * sp`` chips.

    ``dp`` slot shards each run an independent request lifecycle
    (scheduler, cache manager, page budget) over ``slots_per_shard``
    lockstep decode slots; within a shard, ``sp`` chips sequence-shard
    the KV cache's L dim — the paper's split-KV decision lifted to the
    mesh, with chips in place of SMs.
    """
    dp: int = 1                     # data-parallel slot shards
    sp: int = 1                     # sequence-shard width per shard
    slots_per_shard: int = 4        # decode slots per dp shard
    # paged layout only: each shard's page pool is budgeted separately
    # (None = the ServeConfig's engine-wide budget, per shard)
    page_budget_per_shard: Optional[int] = None
    params: str = "replicated"      # "replicated" | "tp" (model axis)

    def __post_init__(self):
        if self.dp < 1 or self.sp < 1:
            raise ValueError(
                f"shard topology axes must be >= 1, got dp={self.dp}, "
                f"sp={self.sp}")
        if self.slots_per_shard < 1:
            raise ValueError(
                f"slots_per_shard must be >= 1, got "
                f"{self.slots_per_shard}")
        if self.page_budget_per_shard is not None \
                and self.page_budget_per_shard < 1:
            raise ValueError(
                f"page_budget_per_shard must be >= 1 (or None), got "
                f"{self.page_budget_per_shard}")
        if self.params not in PARAM_POLICIES:
            raise ValueError(
                f"unknown params policy {self.params!r}; known: "
                f"{PARAM_POLICIES}")

    # --- derived ------------------------------------------------------------

    @property
    def num_devices(self) -> int:
        return self.dp * self.sp

    @property
    def total_slots(self) -> int:
        """Aggregate decode slots across all dp shards — the capacity
        claim the A/B benchmark measures (dp=4 serves 4x the slots)."""
        return self.dp * self.slots_per_shard

    @property
    def fingerprint(self) -> str:
        """Stable topology identity — keys the per-topology PlanCache
        registry and stamps the stats dump."""
        canon = json.dumps(
            {"dp": self.dp, "sp": self.sp,
             "slots_per_shard": self.slots_per_shard,
             "page_budget_per_shard": self.page_budget_per_shard,
             "params": self.params},
            sort_keys=True)
        return "shard." + hashlib.sha256(canon.encode()).hexdigest()[:12]

    def describe(self) -> Dict[str, Any]:
        return {
            "dp": self.dp, "sp": self.sp,
            "slots_per_shard": self.slots_per_shard,
            "total_slots": self.total_slots,
            "num_devices": self.num_devices,
            "page_budget_per_shard": self.page_budget_per_shard,
            "params": self.params,
            "fingerprint": self.fingerprint,
        }

    # --- parsing ------------------------------------------------------------

    @classmethod
    def parse(cls, text: str, **overrides: Any) -> "ShardSpec":
        """Parse the CLI/config form: ``"4,2"`` (dp,sp positional) or
        ``"dp=4,sp=2"`` (named, any subset).  ``overrides`` win over
        the parsed fields."""
        fields: Dict[str, Any] = {}
        parts = [p.strip() for p in str(text).split(",") if p.strip()]
        if not parts:
            raise ValueError(f"empty shard topology string {text!r}")
        if any("=" in p for p in parts):
            for p in parts:
                if "=" not in p:
                    raise ValueError(
                        f"mixed positional/named shard topology {text!r}"
                        " — use 'dp,sp' or 'dp=...,sp=...'")
                k, v = (s.strip() for s in p.split("=", 1))
                if k not in ("dp", "sp", "slots_per_shard",
                             "page_budget_per_shard"):
                    raise ValueError(
                        f"unknown shard topology field {k!r} in {text!r}")
                fields[k] = int(v)
        else:
            if len(parts) > 2:
                raise ValueError(
                    f"positional shard topology takes 'dp' or 'dp,sp', "
                    f"got {text!r}")
            fields["dp"] = int(parts[0])
            if len(parts) == 2:
                fields["sp"] = int(parts[1])
        fields.update(overrides)
        return cls(**fields)

    def with_(self, **changes: Any) -> "ShardSpec":
        return replace(self, **changes)
