"""ShardedServingEngine: the mesh-native request-lifecycle engine.

Consumes a resolved :class:`~repro.shard.ShardPlan`: one
:class:`~repro.serving.ServingEngine` per dp shard, each bound to its
``(1, sp)`` sub-mesh, its own scheduler/cache-manager/page-budget, and
the topology's shared per-shard :class:`~repro.plan.PlanCache`.  The
public surface is the single-engine one — submit / step / stream /
drain — with a routing layer in front:

- **submit** routes each request to the least-loaded shard
  (:func:`pick_shard` — deterministic: ties break on the lowest shard
  index), so admission is provably *per shard*: a request admits
  against ITS shard's free slots and page budget, never the aggregate.
- **step** pumps every shard with work one lockstep launch and remaps
  shard-local event handles back to the global ones.
- **drain** runs all shards to completion, merges completions by
  ``request_id``, and (with ``ServeConfig.stats_path``) writes ONE
  stats dump holding every shard's
  :meth:`~repro.plan.PlanCacheStats.to_json` snapshot plus the
  :func:`~repro.plan.merge_stats_snapshots` aggregate.

Because each shard's sampler PRNG folds the absolute token position
(never the slot index or engine identity), greedy/sampled streams are
bit-identical to a single-device engine serving the same requests —
the property test drives random topologies against that oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.configs.base import ServeConfig
from repro.models.registry import Model
from repro.obs import atomic_write_json, resolve_obs
from repro.plan import merge_stats_snapshots
from repro.serving.engine import ServingEngine
from repro.serving.events import Event
from repro.serving.sampling import Sampler
from repro.serving.scheduler import Completion, Request
from repro.shard.resolver import ShardPlan, ShardResolver
from repro.shard.spec import ShardSpec

Pytree = Any


def pick_shard(loads: Sequence[int]) -> int:
    """Least-loaded shard, lowest index on ties — deterministic, so a
    request stream maps to the same shards on every run (the property
    test replays per-shard traffic against a single-device oracle)."""
    return min(range(len(loads)), key=lambda d: (loads[d], d))


class ShardedServingEngine:
    """dp x sp mesh-native serving over per-shard ServingEngines."""

    def __init__(self, model: Model, scfg: ServeConfig, *,
                 spec: Optional[ShardSpec] = None,
                 plan: Optional[ShardPlan] = None,
                 max_len: int = 256,
                 policy: Optional[str] = None,
                 sampler: Optional[Sampler] = None,
                 prefill_mode: Optional[str] = None,
                 cache_layout: Optional[str] = None,
                 tune_table: Optional[Any] = None,
                 devices: Optional[Sequence[Any]] = None,
                 obs: Optional[Any] = None):
        if plan is not None:
            spec = plan.spec
        elif spec is None:
            if scfg.shard is None:
                raise ValueError(
                    "no topology: pass spec=/plan= or set "
                    "ServeConfig.shard (e.g. shard='4,2')")
            spec = ShardSpec.parse(scfg.shard)
        layout = cache_layout or scfg.cache_layout
        if plan is None:
            plan = ShardResolver(spec).resolve(
                max_len=max_len, cache_layout=layout,
                page_size=scfg.cache_page_size, devices=devices)
        self.spec = spec
        self.plan = plan
        self.model = model
        self.cfg = model.cfg
        self.scfg = scfg
        self.max_len = max_len
        self._stats_path = scfg.stats_path
        self._trace_path = scfg.trace_path
        self._metrics_path = scfg.metrics_path
        # one observer for the topology: each shard gets a labelled VIEW
        # sharing the parent's clock/tracer/metrics, so all shards'
        # spans land on ONE timeline and metric families merge
        if obs is not None:
            self._obs = obs
            self._owns_obs = False
        else:
            self._obs = resolve_obs(scfg)
            self._owns_obs = self._obs.enabled

        # per-shard ServeConfig: the shard budget replaces the engine-
        # wide one; stats_path/shard/trace_path/metrics_path are lifted
        # to THIS layer
        core_cfg = dataclasses.replace(
            scfg, stats_path=None, shard=None,
            trace_path=None, metrics_path=None,
            cache_page_budget=(spec.page_budget_per_shard
                               if spec.page_budget_per_shard is not None
                               else scfg.cache_page_budget))
        # engine identity for the shared per-topology PlanCache: every
        # knob a compiled step closes over.  Two same-identity engines
        # may swap steps freely — the closures touch only config-derived
        # state (model/layout/sampler behavior) plus the deterministic
        # sub-mesh.
        ident = (self.cfg.name, policy or scfg.split_policy, max_len,
                 spec.slots_per_shard, layout,
                 scfg.kv_quant or scfg.kv_cache_dtype,
                 scfg.prefill_bucket, scfg.seqlen_bucket,
                 scfg.num_splits_override, prefill_mode, spec.params,
                 type(sampler).__name__ if sampler is not None else None,
                 tune_table.version if tune_table is not None
                 else scfg.tune_table_path)
        self.cores: List[ServingEngine] = []
        for d in range(spec.dp):
            self.cores.append(ServingEngine(
                model, core_cfg,
                max_len=max_len, batch_slots=spec.slots_per_shard,
                policy=policy, sampler=sampler,
                prefill_mode=prefill_mode, cache_layout=cache_layout,
                tune_table=tune_table,
                mesh=plan.submeshes[d],
                plan_cache=plan.plan_cache(
                    d, ident, scfg.plan_cache_capacity),
                shard_id=d, param_policy=spec.params,
                obs=(self._obs.shard_view(d) if self._obs.enabled
                     else None)))

        # routing state: global handle <-> (shard, shard-local handle)
        self._routes: Dict[int, Tuple[int, int]] = {}
        self._back: Dict[Tuple[int, int], int] = {}
        self._routed: List[List[int]] = [[] for _ in range(spec.dp)]
        self._next_handle = 0

    # --- capacity / identity -------------------------------------------------

    @property
    def B(self) -> int:
        """Aggregate decode slots (dp x slots_per_shard)."""
        return self.spec.total_slots

    @property
    def prefill_mode(self) -> str:
        return self.cores[0].prefill_mode

    @property
    def tune_table(self) -> Optional[Any]:
        return self.cores[0].tune_table

    # single-engine compat (launcher prints, quick inspection): shard 0
    # stands in for "the" scheduler/stats — per-shard truth is
    # shard_stats() / describe()
    @property
    def sched(self) -> Any:
        return self.cores[0].sched

    @property
    def stats(self) -> Any:
        return self.cores[0].stats

    def cache_stats(self) -> Dict[str, Any]:
        return self.cores[0].cache_stats()

    def planned_prefill_buckets(self) -> List[int]:
        buckets = set()
        for core in self.cores:
            buckets.update(core.planned_prefill_buckets())
        return sorted(buckets)

    def routed(self, d: int) -> List[int]:
        """The request_ids routed to shard ``d``, in submit order (the
        property test replays exactly this stream on the oracle)."""
        return list(self._routed[d])

    # --- state ---------------------------------------------------------------

    def load(self, params: Pytree) -> None:
        """Land one copy of ``params`` per shard (each core device_puts
        onto its own sub-mesh per the spec's params policy)."""
        for core in self.cores:
            core.load(params)

    # --- request lifecycle ---------------------------------------------------

    def _load_of(self, d: int) -> int:
        core = self.cores[d]
        return len(core.sched.pending) + len(core.sched.live())

    def validate(self, req: Request) -> None:
        self.cores[0].validate(req)

    def submit(self, req: Request) -> int:
        """Route to the least-loaded shard and enqueue there.  The
        returned handle is global; admission happens on a later
        :meth:`step`, against THAT shard's slots and page budget."""
        d = pick_shard([self._load_of(i) for i in range(self.spec.dp)])
        ch = self.cores[d].submit(req)
        g = self._next_handle
        self._next_handle += 1
        self._routes[g] = (d, ch)
        self._back[(d, ch)] = g
        self._routed[d].append(req.request_id)
        return g

    def has_work(self) -> bool:
        return any(core.has_work() for core in self.cores)

    def _remap(self, d: int, evs: List[Event]) -> List[Event]:
        return [dataclasses.replace(ev, handle=self._back[(d, ev.handle)])
                for ev in evs]

    def step(self) -> List[Event]:
        """One scheduling step on every shard with work; events carry
        GLOBAL handles."""
        events: List[Event] = []
        for d, core in enumerate(self.cores):
            if core.has_work():
                events.extend(self._remap(d, core.step()))
        return events

    def stream(self, handle: int) -> Iterator[Event]:
        """Iterate one global handle's events (pumps only its shard)."""
        if handle not in self._routes:
            raise ValueError(f"handle {handle} is unknown or drained")
        d, ch = self._routes[handle]
        for ev in self.cores[d].stream(ch):
            yield dataclasses.replace(ev, handle=handle)

    def drain(self) -> List[Completion]:
        """Run every shard to completion; completions merge sorted by
        ``request_id``.  With ``ServeConfig.stats_path`` set, the merged
        per-shard + aggregate stats dump is written here (the per-core
        configs carry ``stats_path=None`` on purpose)."""
        done: List[Completion] = []
        for core in self.cores:
            done.extend(core.drain())
        done.sort(key=lambda c: c.request_id)
        if self._stats_path:
            self.dump_stats(self._stats_path)
        if self._owns_obs:
            self.dump_obs()
        return done

    # --- observability -------------------------------------------------------

    def shard_stats(self) -> List[Dict[str, Any]]:
        """Per-shard PlanCacheStats snapshots, annotated with shard
        identity (index, devices, policy, table)."""
        out = []
        for d, core in enumerate(self.cores):
            snap = core.stats.to_json()
            snap["shard"] = d
            snap["devices"] = [str(x) for x in
                               self.plan.shard_devices(d)]
            snap["policy"] = core.policy
            if core.tune_table is not None:
                snap["table_version"] = core.tune_table.version
            out.append(snap)
        return out

    def aggregate_stats(self) -> Dict[str, Any]:
        """The cross-shard counter sum (merge_stats_snapshots)."""
        return merge_stats_snapshots(
            [core.stats.to_json() for core in self.cores])

    def _stats_snapshot(self) -> Dict[str, Any]:
        """The topology's stats dump: per-shard PlanCacheStats sections
        plus the :func:`merge_stats_snapshots` aggregate."""
        return {
            "topology": self.spec.describe(),
            "fingerprint": self.plan.fingerprint,
            "shards": self.shard_stats(),
            "aggregate": self.aggregate_stats(),
        }

    def dump_stats(self, path: str) -> None:
        """ONE stats file for the whole topology, written atomically
        (temp file + ``os.replace``): per-shard sections plus the
        aggregate (the single-engine dump's shape, summed)."""
        atomic_write_json(path, self._stats_snapshot())

    def dump_obs(self) -> None:
        """Write the topology's trace / metrics artifacts (no-op when
        neither path is set or the observer was injected)."""
        if self._obs.enabled and (self._trace_path or self._metrics_path):
            self._obs.dump(self._trace_path, self._metrics_path,
                           plan_stats=self._stats_snapshot())

    def describe(self) -> List[Dict[str, Any]]:
        """Per-shard admission/residency summary (the serve launcher
        prints one row per shard after drain)."""
        rows = []
        for d, core in enumerate(self.cores):
            row: Dict[str, Any] = {
                "shard": d,
                "devices": [str(x) for x in self.plan.shard_devices(d)],
                "slots": core.B,
                "live": len(core.sched.live()),
                "pending": len(core.sched.pending),
                "routed": len(self._routed[d]),
                "launches": core.stats.total_launches,
            }
            cs = core.cache_stats()
            if core.cache.is_paged:
                row["total_pages"] = cs["total_pages"]
                row["free_pages"] = cs["free_pages"]
            rows.append(row)
        return rows

    def planned_splits(self) -> Dict[int, int]:
        """bucket -> frozen num_splits over ALL shards' resident decode
        plans (same-topology shards share the decision per bucket)."""
        out: Dict[int, int] = {}
        for core in self.cores:
            out.update(core.planned_splits())
        return out

    def check_conservation(self) -> None:
        """Page conservation on every shard's cache manager (assertion
        messages carry the ``shard{d}`` label)."""
        for core in self.cores:
            if core.cache.is_paged:
                core.cache.check_conservation()
