"""``repro.shard`` — the mesh-native serving subsystem (Spec ->
Resolver -> Plan -> Engine).

The seventh first-class subsystem, and the one that takes the paper's
split heuristic to its pod-scale analogue: where ``repro.plan`` splits
a decode launch's KV over a chip's SMs, ``repro.shard`` splits the
SERVING TOPOLOGY over a mesh of chips — data-parallel slot shards for
throughput, sequence-sharded decode (chips-for-SMs) for long-context
latency — with the same spec -> resolver -> artifact design as
``repro.plan`` / ``repro.cache`` / ``repro.tune`` / ``repro.spec`` /
``repro.quant``:

- :class:`ShardSpec`      — declarative ``dp x sp`` topology: slot
  shards, per-shard slot count and page budget, params policy.
- :class:`ShardResolver`  — validates divisibility against the cache
  layout, builds the deterministic device grid
  (:func:`~repro.launch.mesh.make_engine_mesh`), fingerprints the
  backend.
- :class:`ShardPlan`      — the resolved artifact: global mesh,
  per-shard sub-meshes, and the per-(topology, shard) PlanCache
  registry (plans AND compiled steps shared across same-identity
  engines).
- :class:`ShardedServingEngine` — dp per-shard
  :class:`~repro.serving.ServingEngine` cores behind one routed
  submit / step / stream / drain surface; admission is per shard,
  decode plans carry ``mesh_splits`` provenance
  (``Planner.mesh_plan``), and sp > 1 shards realize them as the fused
  shard_map sequence-sharded kernel.

Serve with ``ServeConfig(shard="4,2")`` / ``serve --mesh 4,2``; A/B
with ``benchmarks/shard_ab.py``.
"""
from repro.shard.engine import (  # noqa: F401
    ShardedServingEngine,
    pick_shard,
)
from repro.shard.resolver import (  # noqa: F401
    ShardPlan,
    ShardResolver,
    clear_shard_plan_caches,
    shard_plan_cache,
)
from repro.shard.spec import ShardSpec  # noqa: F401
