"""SpecConfig: the WHAT of speculative decoding for one request.

Mirrors the declarative-spec half of ``repro.plan`` / ``repro.cache`` /
``repro.tune``: a frozen, validating dataclass the serving stack can
hash, log, and thread through ``SamplingParams`` without pulling in any
engine state.  The resolver half is the :class:`~repro.spec.Drafter`
registry (``get_drafter``); the artifact half is
:class:`~repro.spec.VerifyOutcome`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# Draft lengths are bounded so a bad knob cannot make the engine build a
# verify specialization with an absurd query block (the verify launch is
# (k + 1) query rows; plans are cached per ("verify", k, bucket) key).
MAX_DRAFT_LEN = 64


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Per-request speculative-decoding knob.

    - ``method``: drafter name in the :func:`~repro.spec.get_drafter`
      registry (``"ngram"`` / ``"prompt_lookup"`` built in; a
      draft-model backend registers the same way).
    - ``k``: draft length — tokens proposed per verify step.  The verify
      launch scores ``k + 1`` query rows (the committed current token
      plus the k drafts) and emits between 1 and ``k + 1`` tokens.
    - ``max_rejects``: after this many *consecutive* verify steps with
      zero accepted drafts, the engine stops drafting for the request
      and falls back to plain decode (``None`` = never give up).
      Counted in ``PlanCacheStats.spec_disabled``.
    """
    method: str = "ngram"
    k: int = 4
    max_rejects: Optional[int] = None

    def __post_init__(self) -> None:
        if not self.method or not isinstance(self.method, str):
            raise ValueError("SpecConfig.method must be a drafter name")
        if not 1 <= int(self.k) <= MAX_DRAFT_LEN:
            raise ValueError(
                f"SpecConfig.k must be in [1, {MAX_DRAFT_LEN}], got {self.k}")
        if self.max_rejects is not None and int(self.max_rejects) < 1:
            raise ValueError(
                f"SpecConfig.max_rejects must be >= 1 or None, "
                f"got {self.max_rejects}")

    def describe(self) -> str:
        mr = "∞" if self.max_rejects is None else str(self.max_rejects)
        return f"spec[{self.method} k={self.k} max_rejects={mr}]"
