"""``repro.spec`` — the speculative-decoding subsystem (SpecConfig ->
Drafter -> VerifyOutcome).

The fifth first-class subsystem, opening the workload class the ROADMAP
names after TPOT: verify steps attend with per-slot query blocks of
``k + 1 > 1`` tokens, raising decode arithmetic intensity and giving
the paper's sequence-aware split policy a new planning regime.  Same
spec -> resolver -> artifact design as ``repro.plan`` / ``repro.cache``
/ ``repro.tune``:

- :class:`SpecConfig`    — declarative per-request knob (drafter
  ``method``, draft length ``k``, ``max_rejects`` give-up threshold),
  carried on ``SamplingParams.speculation`` and validated at submit.
- :class:`Drafter`       — the resolver: host-side token proposers over
  each slot's prompt+emitted history.  Built-ins are self-speculative
  (:class:`NGramDrafter`, :class:`PromptLookupDrafter`); the registry
  (:func:`register_drafter`) is shaped so a draft-model backend slots
  in under a new name with per-request state.
- :class:`VerifyOutcome` — the artifact: per-slot accept/reject result
  of one verify launch, aggregated into ``PlanCacheStats``
  (``spec_acceptance_rate``, ``spec_tokens_per_step``).

The verify launch itself is planned like everything else: a ``"verify"``
:class:`~repro.plan.AttentionSpec` kind, frozen under
``("verify", k, bucket)`` keys in the same :class:`~repro.plan.PlanCache`
— k-row query blocks, causal-within-block masking, zero policy
evaluations in dispatch — with batched accept/reject *inside* the
jitted step (longest-accepted-prefix for greedy, standard rejection
sampling on the per-request seeded PRNG for sampled requests) and a
multi-token KV write-back that commits only accepted rows.
"""
from repro.spec.config import MAX_DRAFT_LEN, SpecConfig  # noqa: F401
from repro.spec.drafter import (  # noqa: F401
    Drafter,
    NGramDrafter,
    PromptLookupDrafter,
    available_drafters,
    get_drafter,
    register_drafter,
)
from repro.spec.outcome import VerifyOutcome  # noqa: F401
