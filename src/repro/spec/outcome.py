"""VerifyOutcome: the artifact half of ``repro.spec``.

One frozen record per slot per verify launch — what was proposed, what
survived batched accept/reject, and what the engine actually emitted.
The engine aggregates these into ``PlanCacheStats`` (acceptance rate,
effective tokens/step); tests and benchmarks consume them directly.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class VerifyOutcome:
    """Result of one verify step for one slot.

    - ``slot``: batch slot index.
    - ``proposed``: draft tokens scored this step (0 for a slot that
      rode the launch without drafts).
    - ``accepted``: drafts that survived accept/reject (longest accepted
      prefix for greedy; rejection-sampling coin for sampled), already
      clamped to ``proposed``.
    - ``emitted``: the tokens the step contributed to the completion —
      the accepted drafts plus the correction/bonus token sampled at the
      first non-accepted row.  ``len(emitted) == accepted + 1`` unless
      the request finished mid-commit (eos/stop/length).
    """
    slot: int
    proposed: int
    accepted: int
    emitted: Tuple[int, ...]

    def __post_init__(self) -> None:
        if not 0 <= self.accepted <= self.proposed:
            raise ValueError(
                f"accepted ({self.accepted}) must be in "
                f"[0, proposed={self.proposed}]")

    @property
    def tokens_gained(self) -> int:
        """Tokens beyond what a plain decode step would have emitted."""
        return max(0, len(self.emitted) - 1)
