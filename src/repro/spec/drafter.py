"""Drafters: the resolver half of ``repro.spec``.

A :class:`Drafter` turns a slot's token history (prompt + everything
emitted so far) into up to ``k`` *draft* tokens — guesses for the next
tokens the model would emit — which the engine then scores in one
planned verify launch and accepts/rejects in a batch.

The built-ins are **self-speculative**: they propose continuations
copied out of the request's own history (n-gram match / prompt lookup),
so they cost zero model FLOPs and zero extra weights.  The interface is
deliberately wider than they need — ``propose`` receives the full
history and may return *fewer* than ``k`` tokens (including none) — so
a draft-model backend can implement the same contract: run a small LM
over ``history``, return its greedy continuation, register under a new
name.  Nothing in the engine assumes drafts came from a lookup.

Registry idiom mirrors ``repro.serving.sampling.register_sampler``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Type


class Drafter:
    """Base drafter: propose up to ``k`` draft tokens from a history.

    One drafter instance is created per admitted request (so stateful
    backends — a draft model carrying its own KV cache — can keep
    per-request state across calls).  ``propose`` must be cheap: it runs
    on the host inside the engine's step loop.
    """

    #: registry name (set by ``register_drafter``)
    name: str = "base"

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        """Return 0..k draft tokens continuing ``history``.

        ``history`` is the request's prompt followed by every token
        emitted so far — exactly the token stream the model has been fed.
        Returning ``[]`` skips speculation for this step (the slot takes
        a plain 1-token row in the verify launch).
        """
        raise NotImplementedError

    def observe(self, accepted: int, proposed: int) -> None:
        """Feedback hook after each verify step (accepted of proposed).

        Built-ins ignore it; adaptive drafters (e.g. a draft model
        tuning its own k) can use it.  Must not raise.
        """


class NGramDrafter(Drafter):
    """Self-speculative n-gram continuation over the full history.

    Matches the trailing ``n-1``-gram of the history against earlier
    occurrences (most recent first) and proposes the tokens that
    followed the match.  Greedy decode loves to settle into repetitive
    continuations — exactly the regime where copying history verifies.
    """

    name = "ngram"

    def __init__(self, n: int = 3) -> None:
        if n < 2:
            raise ValueError(f"NGramDrafter needs n >= 2, got {n}")
        self.n = int(n)

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        h = list(history)
        m = self.n - 1
        if len(h) <= m:
            return []
        key = tuple(h[-m:])
        # most recent earlier occurrence of the trailing (n-1)-gram
        for start in range(len(h) - m - 1, -1, -1):
            if tuple(h[start:start + m]) == key:
                cont = h[start + m:start + m + k]
                return cont
        return []


class PromptLookupDrafter(Drafter):
    """Prompt-lookup decoding: longest-suffix match, longest n first.

    Tries trailing n-grams from ``max_ngram`` down to ``min_ngram``
    against the history and copies the continuation of the most recent
    match — the "prompt lookup" heuristic (good for summarize/extract
    traffic where the output quotes its prompt), generalized over the
    emitted tokens too.
    """

    name = "prompt_lookup"

    def __init__(self, min_ngram: int = 1, max_ngram: int = 4) -> None:
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.min_ngram = int(min_ngram)
        self.max_ngram = int(max_ngram)

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        h = list(history)
        for m in range(min(self.max_ngram, len(h) - 1),
                       self.min_ngram - 1, -1):
            key = tuple(h[-m:])
            for start in range(len(h) - m - 1, -1, -1):
                if tuple(h[start:start + m]) == key:
                    cont = h[start + m:start + m + k]
                    if cont:
                        return cont
        return []


_DRAFTERS: Dict[str, Type[Drafter]] = {}


def register_drafter(name: str, cls: Type[Drafter]) -> None:
    """Register a drafter class under ``name`` (draft-model backends
    plug in here; ``SpecConfig.method`` selects by this name)."""
    cls.name = name
    _DRAFTERS[name] = cls


def get_drafter(name: str) -> Type[Drafter]:
    if name not in _DRAFTERS:
        raise KeyError(
            f"unknown drafter {name!r}; have {sorted(_DRAFTERS)}")
    return _DRAFTERS[name]


def available_drafters() -> List[str]:
    return sorted(_DRAFTERS)


register_drafter("ngram", NGramDrafter)
register_drafter("prompt_lookup", PromptLookupDrafter)
