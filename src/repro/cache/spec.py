"""CacheSpec: the declarative input to the cache subsystem.

Mirrors the ``repro.plan`` design (PR 2): a spec answers "WHAT cache are
we running" — family, capacity, dtype, layout — and nothing about HOW
the arrays are arranged; a :class:`~repro.cache.CacheLayout` (resolved
by the :class:`~repro.cache.CacheManager`) compiles the how.

Two layouts:

- ``dense`` — today's ``(layers, B, max_len, ...)`` arrays, bit-for-bit
  what ``Model.init_cache`` always produced.
- ``paged`` — fixed-size pages in a shared pool plus per-slot page
  tables: per-request capacity, ragged per-slot residency, and decode
  views sized by the RESIDENT-length bucket instead of the padded slot
  capacity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

LAYOUTS = ("dense", "paged")

# Page 0 of every pool is the trash page: unallocated page-table entries
# point at it, so gathers of a slot's unallocated tail read (masked)
# garbage and scatters of that tail land somewhere harmless.
TRASH_PAGE = 0


@dataclass(frozen=True)
class CacheSpec:
    """One engine's KV-cache storage, declaratively.

    ``page_budget`` is the number of DATA pages in the pool (the trash
    page is extra); ``None`` sizes it dense-equivalently — every slot
    can hold ``max_len`` rows, so nothing a dense engine could serve is
    refused.  Smaller budgets oversubscribe slots against each other:
    admission then gates on free pages and a mid-flight allocation
    failure surfaces as a per-request ``cache_capacity`` finish.

    ``share_prefix`` (paged only) turns on per-page refcounts plus a
    token-keyed prefix trie in the :class:`~repro.cache.CacheManager`:
    admission maps a request's shared prompt prefix onto already-
    resident pages (zero prefill compute for the shared part) and pages
    copy-on-write when a write would dirty a page another owner still
    reads.  ``prefix_capacity`` bounds how many pages the trie may keep
    anchored (None = unbounded); anchored-only pages are evicted
    leaf-first LRU when the pool runs dry or the bound is hit.
    """
    family: str
    batch: int
    max_len: int
    kv_dtype: str = "bfloat16"
    layout: str = "dense"
    page_size: int = 64
    page_budget: Optional[int] = None
    share_prefix: bool = False
    prefix_capacity: Optional[int] = None

    def __post_init__(self):
        if self.layout not in LAYOUTS:
            raise ValueError(
                f"unknown cache layout {self.layout!r}; known: {LAYOUTS}")
        if self.batch < 1 or self.max_len < 1:
            raise ValueError(f"bad cache extent: batch={self.batch}, "
                             f"max_len={self.max_len}")
        if self.layout == "paged":
            if self.page_size < 1:
                raise ValueError(f"page_size must be >= 1, "
                                 f"got {self.page_size}")
            if self.page_budget is not None and self.page_budget < 1:
                raise ValueError(f"page_budget must be >= 1, "
                                 f"got {self.page_budget}")
            if self.prefix_capacity is not None \
                    and self.prefix_capacity < 1:
                raise ValueError(f"prefix_capacity must be >= 1, "
                                 f"got {self.prefix_capacity}")
        elif self.share_prefix:
            raise ValueError(
                "share_prefix needs per-slot page tables to map shared "
                "prefixes onto; use layout='paged'")

    # --- derived extents ----------------------------------------------------

    @property
    def slot_pages(self) -> int:
        """Page-table width: pages a single slot can ever hold."""
        return -(-self.max_len // self.page_size)

    @property
    def total_pages(self) -> int:
        """Data pages in the pool (excluding the trash page)."""
        if self.page_budget is not None:
            return self.page_budget
        return self.batch * self.slot_pages

    @property
    def pool_pages(self) -> int:
        """Pool allocation size: data pages + the trash page."""
        return self.total_pages + 1

    def pages_for(self, length: int) -> int:
        """Pages needed to hold ``length`` resident rows."""
        return -(-max(0, int(length)) // self.page_size)

    def view_pages(self, view_len: int) -> int:
        """Pages a gather covering ``view_len`` rows spans (capped at the
        slot-table width — a view can never exceed a slot's capacity)."""
        return min(-(-int(view_len) // self.page_size), self.slot_pages)
