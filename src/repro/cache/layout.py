"""CacheLayout: pluggable device-side cache arrangements.

A layout owns the mapping between the model-facing cache VIEW (the
pytree every ``decode_step`` / ``prefill_slot`` consumes — dense
``(layers, B, L, ...)`` leaves) and the device STORAGE (whatever the
layout actually allocates).  Four entry points, all pure and traceable:

- ``init_storage()``                      — allocate the storage pytree;
- ``gather_view(storage, table, n)``      — full-batch dense view of the
  first ``n`` pages per slot (paged) / the storage itself (dense);
- ``scatter_view(storage, view, ...)``    — write an updated view back;
- ``slot_view`` / ``write_slot``          — the batch-1 variants the
  fused-prefill admission path uses.

:class:`DenseLayout` is bit-identical to the pre-redesign arrays (its
``init_storage`` is exactly what ``Model.init_cache`` always returned);
:class:`PagedKVCache` stores pageable leaves as fixed-size pages in a
shared pool, gathered per launch through
:func:`repro.kernels.ops.gather_pages` — the layout-aware gather path.

Leaf pageability: a cache leaf pages iff its spec says so
(``ParamSpec.paged``), or — when unmarked — iff it carries a "seq" axis
spanning the full slot capacity.  Position-complete leaves (encdec
cross K/V: read to their full length every step) and recurrent states
(no seq axis) stay dense inside the paged storage and pass through the
gather untouched.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.cache.spec import CacheSpec, TRASH_PAGE
from repro.kernels import ops
from repro.models.common import ParamSpec, is_spec

Pytree = Any

# Re-export: the per-tensor paged view consumed by ops.decode_attention.
PagedKV = ops.PagedKV


def _map_specs(fn, specs: Pytree, *trees: Pytree) -> Pytree:
    return jax.tree_util.tree_map(fn, specs, *trees, is_leaf=is_spec)


class CacheLayout:
    """Base: resolved from a :class:`CacheSpec` by the CacheManager."""

    kind: str = "abstract"

    def __init__(self, model, spec: CacheSpec):
        self.model = model
        self.spec = spec
        self.specs = model.cache_specs(spec.batch, spec.max_len,
                                       spec.kv_dtype)

    # --- sizing (observability / benchmarks) --------------------------------

    def _leaf_bytes(self, s: ParamSpec) -> int:
        n = 1
        for d in s.shape:
            n *= d
        return n * jnp.dtype(s.jdtype).itemsize

    def dense_bytes(self) -> int:
        """Bytes of the dense-equivalent storage (the baseline)."""
        leaves = jax.tree_util.tree_leaves(self.specs, is_leaf=is_spec)
        return sum(self._leaf_bytes(s) for s in leaves)

    def storage_bytes(self) -> int:
        raise NotImplementedError

    def row_bytes(self) -> int:
        """Pageable-cache bytes per resident row per slot (all layers)."""
        total = 0
        for s in jax.tree_util.tree_leaves(self.specs, is_leaf=is_spec):
            if _pageable(s, self.spec.max_len):
                total += self._leaf_bytes(s) // (s.shape[1] * s.shape[2])
        return total

    def attended_bytes(self, view_len: int) -> int:
        """K/V bytes one decode launch reads for a ``view_len``-row view
        (the cache term of the decode roofline)."""
        raise NotImplementedError


class DenseLayout(CacheLayout):
    """Today's arrays, kept bit-identical: storage IS the view."""

    kind = "dense"

    def init_storage(self) -> Pytree:
        from repro.models.common import init_params
        return init_params(self.specs, jax.random.PRNGKey(0))

    def gather_view(self, storage: Pytree, table=None,
                    num_pages: Optional[int] = None) -> Pytree:
        return storage

    def scatter_view(self, storage: Pytree, view: Pytree, table=None,
                     num_pages: Optional[int] = None) -> Pytree:
        return view

    def storage_bytes(self) -> int:
        return self.dense_bytes()

    def attended_bytes(self, view_len: int) -> int:
        # dense decode streams the PADDED slot capacity per launch
        del view_len
        return self.row_bytes() * self.spec.max_len * self.spec.batch


def _pageable(s: ParamSpec, max_len: int) -> bool:
    """Whether one cache leaf pages over its sequence axis.

    Layer-stacked cache leaves are ``(layers, batch, seq, ...)``; a leaf
    pages iff its (possibly inferred) ``paged`` flag allows it AND its
    seq axis spans the full slot capacity — page arithmetic (position =
    page * page_size + offset) is only meaningful there.  Ring caches
    (seq == window < max_len) and fixed-length memories therefore stay
    dense even if unmarked.
    """
    if s.paged is False:
        return False
    # paged=True and paged=None both defer to the shape check: page
    # arithmetic is meaningless off the (batch, full-capacity seq) form
    return (len(s.axes) >= 3 and s.axes[1] == "batch"
            and s.axes[2] == "seq" and s.shape[2] == max_len)


class PagedKVCache(CacheLayout):
    """Fixed-size pages + per-slot page tables over a shared pool.

    Pageable leaves ``(layers, B, max_len, *rest)`` are stored as
    ``(layers, pool_pages, page_size, *rest)``; one page table ``(B,
    slot_pages) int32`` is shared by every leaf (all layers of all
    leaves write the same positions).  Page 0 is the trash page (see
    :data:`repro.cache.spec.TRASH_PAGE`).  Non-pageable leaves keep
    their dense shape inside the storage pytree and pass through
    gather/scatter untouched.
    """

    kind = "paged"

    def __init__(self, model, spec: CacheSpec):
        super().__init__(model, spec)
        self._paged_mask = _map_specs(
            lambda s: _pageable(s, spec.max_len), self.specs)
        if not any(jax.tree_util.tree_leaves(self._paged_mask)):
            raise ValueError(
                f"{spec.family!r} caches hold no pageable (full-capacity "
                "seq-axis) leaves; use layout='dense'")

    # --- storage ------------------------------------------------------------

    def _paged_shape(self, s: ParamSpec):
        return ((s.shape[0], self.spec.pool_pages, self.spec.page_size)
                + s.shape[3:])

    def init_storage(self) -> Pytree:
        def one(s: ParamSpec, paged: bool):
            shape = self._paged_shape(s) if paged else s.shape
            return jnp.zeros(shape, s.jdtype)
        return _map_specs(one, self.specs, self._paged_mask)

    # --- full-batch decode view --------------------------------------------

    def gather_view(self, storage: Pytree, table: jax.Array,
                    num_pages: int) -> Pytree:
        def one(s, paged, leaf):
            if not paged:
                return leaf
            return ops.gather_pages(leaf, table, num_pages=num_pages,
                                    axis=1)
        return _map_specs(one, self.specs, self._paged_mask, storage)

    def scatter_view(self, storage: Pytree, view: Pytree,
                     table: jax.Array, num_pages: int) -> Pytree:
        def one(s, paged, leaf, vleaf):
            if not paged:
                return vleaf
            return ops.scatter_pages(leaf, vleaf, table,
                                     num_pages=num_pages, axis=1)
        return _map_specs(one, self.specs, self._paged_mask, storage,
                          view)

    def write_token(self, storage: Pytree, view: Pytree,
                    table: jax.Array, positions: jax.Array,
                    num_pages: int) -> Pytree:
        """Write back ONLY the page holding each slot's row ``positions[b]``
        (the decode step mutates exactly one row per slot, so scattering
        the whole view would re-write ``view_len`` rows of HBM per step
        for nothing).  Non-pageable leaves take the full view leaf, same
        as :meth:`scatter_view`.  Dead slots' table rows point at the
        trash page, so their (stale) writes land there.
        """
        ps = self.spec.page_size
        pidx = positions.astype(jnp.int32) // ps              # (B,)
        dst = jnp.take_along_axis(table, pidx[:, None], axis=1)  # (B, 1)

        def one(s, paged, leaf, vleaf):
            if not paged:
                return vleaf
            B = vleaf.shape[1]
            vp = vleaf.reshape(vleaf.shape[:2] + (num_pages, ps)
                               + vleaf.shape[3:])
            idx = pidx.reshape((1, B, 1, 1) + (1,) * (vp.ndim - 4))
            sel = jnp.take_along_axis(vp, idx, axis=2)  # (l, B, 1, ps, ..)
            return leaf.at[:, dst].set(sel.astype(leaf.dtype))
        return _map_specs(one, self.specs, self._paged_mask, storage,
                          view)

    def write_rows(self, storage: Pytree, view: Pytree,
                   table: jax.Array, start: jax.Array,
                   count: jax.Array, max_rows: int,
                   num_pages: int) -> Pytree:
        """Write back only the pages overlapping each slot's rows
        ``[start[b], start[b] + count[b])`` — the speculative verify
        step's accept-masked commit.  ``max_rows`` (static) bounds the
        per-slot row count, so a run straddles at most
        ``ceil(max_rows / page_size) + 1`` pages; pages in the span but
        wholly beyond the accepted extent are redirected to the trash
        page, which is how rejected draft rows die INSIDE the jitted
        step (the host then rolls ``kv_len`` back — no storage
        mutation needed).  Rows below ``start`` on the first page
        round-trip their gathered values unchanged.
        """
        ps = self.spec.page_size
        span = -(-int(max_rows) // ps) + 1
        first = start.astype(jnp.int32) // ps                   # (B,)
        pidx = first[:, None] + jnp.arange(span, dtype=jnp.int32)
        end = (start + count).astype(jnp.int32)                 # (B,)
        commit = (pidx * ps < end[:, None]) & (pidx < num_pages)
        pidx_c = jnp.minimum(pidx, num_pages - 1)
        dst = jnp.take_along_axis(table, pidx_c, axis=1)        # (B, span)
        dst = jnp.where(commit, dst, TRASH_PAGE)

        def one(s, paged, leaf, vleaf):
            if not paged:
                return vleaf
            B = vleaf.shape[1]
            vp = vleaf.reshape(vleaf.shape[:2] + (num_pages, ps)
                               + vleaf.shape[3:])
            idx = pidx_c.reshape((1, B, span, 1)
                                 + (1,) * (vp.ndim - 4))
            sel = jnp.take_along_axis(vp, idx, axis=2)
            return leaf.at[:, dst].set(sel.astype(leaf.dtype))
        return _map_specs(one, self.specs, self._paged_mask, storage,
                          view)

    # --- batch-1 slot view (fused-prefill admission) ------------------------

    def slot_view(self, storage: Pytree, table: jax.Array,
                  slot: jax.Array, num_pages: int) -> Pytree:
        row = jax.lax.dynamic_slice(table, (slot, 0), (1, num_pages))

        def one(s, paged, leaf):
            if paged:
                return ops.gather_pages(leaf, row, num_pages=num_pages,
                                        axis=1)
            return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=1)
        return _map_specs(one, self.specs, self._paged_mask, storage)

    def write_slot(self, storage: Pytree, view: Pytree, table: jax.Array,
                   slot: jax.Array, num_pages: int) -> Pytree:
        row = jax.lax.dynamic_slice(table, (slot, 0), (1, num_pages))

        def one(s, paged, leaf, vleaf):
            if paged:
                return ops.scatter_pages(leaf, vleaf, row,
                                         num_pages=num_pages, axis=1)
            start = (0, slot) + (0,) * (leaf.ndim - 2)
            return jax.lax.dynamic_update_slice(
                leaf, vleaf.astype(leaf.dtype), start)
        return _map_specs(one, self.specs, self._paged_mask, storage,
                          view)

    # --- page copies (prefix sharing) ---------------------------------------

    def copy_page(self, storage: Pytree, src: jax.Array,
                  dst: jax.Array) -> Pytree:
        """Copy one pool page (every layer of every pageable leaf) from
        ``src`` to ``dst``.  Backs copy-on-write and copy-on-adopt: the
        manager queues (src, dst) pairs and the engine applies them
        through a jitted step BEFORE any gather can read ``dst``."""
        def one(s, paged, leaf):
            if not paged:
                return leaf
            row = jax.lax.dynamic_slice_in_dim(leaf, src, 1, axis=1)
            return jax.lax.dynamic_update_slice_in_dim(leaf, row, dst,
                                                       axis=1)
        return _map_specs(one, self.specs, self._paged_mask, storage)

    # --- admission reset ----------------------------------------------------

    def zero_slot(self, storage: Pytree, slot: jax.Array) -> Pytree:
        """Zero the NON-paged leaves' slot column (recurrent state /
        position-complete memories must not leak across requests).
        Paged leaves need no reset: freshly allocated pages hold stale
        rows only at positions >= the new request's ``kv_len``, which
        every consumer masks."""
        def one(s, paged, leaf):
            if paged:
                return leaf
            row = jnp.zeros(leaf.shape[:1] + (1,) + leaf.shape[2:],
                            leaf.dtype)
            start = (0, slot) + (0,) * (leaf.ndim - 2)
            return jax.lax.dynamic_update_slice(leaf, row, start)
        return _map_specs(one, self.specs, self._paged_mask, storage)

    # --- sizing -------------------------------------------------------------

    def storage_bytes(self) -> int:
        def one(s, paged):
            if not paged:
                return self._leaf_bytes(s)
            n = 1
            for d in self._paged_shape(s):
                n *= d
            return n * jnp.dtype(s.jdtype).itemsize
        sizes = _map_specs(one, self.specs, self._paged_mask)
        return sum(jax.tree_util.tree_leaves(sizes))

    def attended_bytes(self, view_len: int) -> int:
        # paged decode streams only the RESIDENT-bucket view per launch
        return self.row_bytes() * int(view_len) * self.spec.batch
