"""repro.cache: the KV-cache subsystem (CacheSpec -> CacheLayout ->
PagedKVCache), mirroring the repro.plan design.

- :class:`CacheSpec`   — WHAT cache: family, capacity, dtype, layout.
- :class:`CacheLayout` — HOW it's arranged on device:
  :class:`DenseLayout` (the pre-redesign arrays, bit-identical) or
  :class:`PagedKVCache` (fixed-size pages + per-slot page tables).
- :class:`CacheManager` — residency bookkeeping: per-slot ``kv_len``
  (the planner's resident-length summary), free-list page allocation,
  page-table device mirroring, and — under ``share_prefix`` — per-page
  refcounts, copy-on-write, and the :class:`PrefixTrie` that maps new
  prompts onto already-resident prefix pages.

Entry points the stack threads instead of owning raw arrays:
``gather_view`` / ``scatter_view`` (decode), ``slot_view`` /
``write_slot`` (fused-prefill admission), ``zero_slot`` (admission
reset), plus the :class:`~repro.kernels.ops.PagedKV` per-tensor view
``kernels.ops.decode_attention`` accepts directly.
"""
from repro.cache.layout import (  # noqa: F401
    CacheLayout,
    DenseLayout,
    PagedKV,
    PagedKVCache,
)
from repro.cache.manager import CacheManager  # noqa: F401
from repro.cache.prefix import PrefixMatch, PrefixTrie  # noqa: F401
from repro.cache.spec import (  # noqa: F401
    LAYOUTS,
    TRASH_PAGE,
    CacheSpec,
)
