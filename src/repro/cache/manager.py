"""CacheManager: resolves a CacheSpec into a layout + owns residency.

The manager is the pure-Python half of the cache subsystem (the analogue
of the PR-3 ``Scheduler``): it tracks per-slot resident lengths
(``kv_len`` — the source of truth the Planner's resident-length buckets
come from), and, for the paged layout, the free-list, per-slot page
tables, per-page refcounts and the prefix trie.  The serving engine owns
the device arrays (donation flow) and asks the manager *where* things
live; the layout supplies the traceable gather/scatter.

Page-table discipline:

- page 0 is the trash page; a freshly-initialized or released slot's
  whole table row points there;
- allocation is per-slot prefix-contiguous: slot ``i`` holding ``n``
  resident rows owns table entries ``[0, pages_for(n))``;
- allocation is all-or-nothing (a partial grab is rolled back), so a
  ``False`` from :meth:`reserve` / :meth:`ensure` leaves no state to
  clean up — the engine turns it into the per-request
  ``cache_capacity`` finish.

Page lifetime (``share_prefix``):

Every data page carries a refcount: +1 per slot-table reference and +1
when the prefix trie anchors it.  :meth:`release` DECREMENTS instead of
freeing — a page returns to the free list only at refcount zero, so a
finished request's prefix pages survive as long as the trie (or an
adopter) holds them.  Writes go through a copy-on-write guard
(:meth:`ensure` / the growth path): dirtying a page with
``refcount > 1`` first moves the writer onto a fresh private page and
queues a device-side page copy the engine applies
(:meth:`drain_copies` -> ``PagedKVCache.copy_page``) before the next
gather.  Admission maps a prompt's shared prefix onto existing pages
(:meth:`admit_prompt`) and indexes the finished prefill back into the
trie (:meth:`register_prefix`); trie-only pages (``refcount == 1``) are
reclaimed leaf-first LRU when the free list runs dry.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.cache.layout import CacheLayout, DenseLayout, PagedKVCache
from repro.cache.prefix import PrefixTrie
from repro.cache.spec import TRASH_PAGE, CacheSpec

_LAYOUTS = {"dense": DenseLayout, "paged": PagedKVCache}


class CacheManager:
    """Residency bookkeeping + layout resolution for one engine."""

    def __init__(self, model, spec: CacheSpec, *, label: str = ""):
        self.spec = spec
        # who this manager serves (e.g. "shard2" under the mesh-native
        # engine, whose free lists are per-shard): stamped into
        # describe() and the conservation assertions so a multi-shard
        # failure names the pool that broke
        self.label = label
        self.layout: CacheLayout = _LAYOUTS[spec.layout](model, spec)
        self.B = spec.batch
        self.kv_len = np.zeros(self.B, np.int32)
        self._table = np.full((self.B, max(1, spec.slot_pages)),
                              TRASH_PAGE, np.int32)
        self._allocated = np.zeros(self.B, np.int32)   # prefix page count
        self._free: List[int] = list(range(spec.total_pages, 0, -1)) \
            if spec.layout == "paged" else []
        self._table_dev = None                         # dirty => None
        # per-page reference counts (index 0 = the trash page, pinned
        # at zero: it is never allocated, never freed, never shared)
        self.refcount = np.zeros(spec.pool_pages if spec.layout == "paged"
                                 else 1, np.int32)
        self.trie: Optional[PrefixTrie] = (
            PrefixTrie(spec.page_size, spec.prefix_capacity)
            if spec.layout == "paged" and spec.share_prefix else None)
        # (src, dst) device copies queued by COW / copy-on-adopt; the
        # engine drains and applies them BEFORE the next gather touches
        # dst (until the copy lands, dst holds garbage)
        self._pending_copies: List[Tuple[int, int]] = []
        # observability (benchmarks/prefix_ab reads these)
        self.prefix_hits = 0            # admissions that reused >= 1 row
        self.prefix_shared_rows = 0     # prompt rows served from the trie
        self.prefix_copies = 0          # copy-on-adopt + COW page copies
        self.pages_allocated_total = 0  # free-list pops, ever

    # --- storage ------------------------------------------------------------

    @property
    def is_paged(self) -> bool:
        return self.spec.layout == "paged"

    def init_storage(self):
        return self.layout.init_storage()

    def table_device(self):
        """Device mirror of the page table, re-uploaded only when an
        allocation / release dirtied it (not per decode step)."""
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self._table)
        return self._table_dev

    # --- residency ----------------------------------------------------------

    def note_write(self, slot: int, pos: int) -> None:
        """Record that row ``pos`` of ``slot`` is now resident."""
        self.kv_len[slot] = max(self.kv_len[slot], pos + 1)

    def truncate(self, slot: int, length: int) -> None:
        """Roll a slot's resident length BACK to ``length`` (speculative
        rejection).  Pages are NOT freed: the speculative rows were
        allocated against the slot's eventual extent, the very next
        verify launch rewrites them, and every attention path already
        masks rows above ``kv_len`` — so conservation holds with the
        pages still owned, and releasing/re-granting them per step would
        thrash the free list (and, under prefix sharing, re-trigger COW
        on pages the slot just privatized)."""
        self.kv_len[slot] = min(self.kv_len[slot], max(int(length), 0))

    def resident_max(self) -> int:
        """Largest per-slot resident length (the planner's summary)."""
        return int(self.kv_len.max()) if self.B else 0

    def release(self, slot: int) -> None:
        """Drop a finished slot's references: resident length to zero,
        per-page refcounts decremented (a page frees only at zero — the
        trie or an adopter may still hold it), table row to the trash
        page (a dead slot still rides the lockstep launch — its writes
        must land in trash).

        Idempotent: releasing an already-released slot is a no-op.  A
        double-finish (e.g. a streamed handle also swept by ``drain()``)
        must not double-decrement — under refcounting that would free
        pages other owners still read, silently aliasing two live slots.
        """
        self.kv_len[slot] = 0
        n = int(self._allocated[slot])
        if not n:                       # already released: nothing held
            return
        for p in self._table[slot, :n][::-1]:
            self._unref(int(p))
        self._table[slot, :n] = TRASH_PAGE
        self._allocated[slot] = 0
        self._table_dev = None

    def _unref(self, page: int) -> None:
        if page == TRASH_PAGE:
            return
        self.refcount[page] -= 1
        assert self.refcount[page] >= 0, f"page {page} over-released"
        if self.refcount[page] == 0:
            self._free.append(page)

    # --- page accounting ----------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_for(self, length: int) -> int:
        return self.spec.pages_for(length)

    def max_request_pages(self) -> int:
        """Largest allocation a single request may ever be GRANTED: the
        slot-table width, capped at the pool itself — a slot can never
        hold more pages than exist, so admission math against the
        uncapped table width would admit pool-filling prompts that
        deadlock the FIFO head on their first decode-token page."""
        return min(self.spec.slot_pages, self.spec.total_pages)

    def _evictable_pages(self) -> int:
        """Trie-only pages (``refcount == 1``): reclaimable leaf-first."""
        if self.trie is None:
            return 0
        return sum(1 for p in self.trie.pages() if self.refcount[p] == 1)

    def _evict_one(self) -> bool:
        """Reclaim one trie-only page onto the free list."""
        if self.trie is None:
            return False
        p = self.trie.pop_evictable(lambda pg: self.refcount[pg] == 1)
        if p is None:
            return False
        self._unref(p)                  # trie's reference was the last
        return True

    def _pop_page(self) -> Optional[int]:
        if not self._free and not self._evict_one():
            return None
        p = self._free.pop()
        self.refcount[p] = 1
        self.pages_allocated_total += 1
        return p

    def can_reserve(self, length: int) -> bool:
        """Whether a fresh slot could hold ``length`` rows right now
        (counting trie-only pages, which reclaim on demand)."""
        if not self.is_paged:
            return True
        return self.pages_for(length) <= \
            len(self._free) + self._evictable_pages()

    def reserve(self, slot: int, length: int) -> bool:
        """Grow ``slot``'s allocation to cover ``length`` rows
        (all-or-nothing)."""
        if not self.is_paged:
            return True
        return self._grow(slot, self.pages_for(length))

    def ensure(self, slot: int, pos: int) -> bool:
        """Make row ``pos`` of ``slot`` writable (allocating its page if
        needed, copy-on-writing it if shared).  ``False`` = pool
        exhausted: the engine finishes the request with
        ``finish_reason='cache_capacity'``."""
        if not self.is_paged:
            return pos < self.spec.max_len
        j = pos // self.spec.page_size
        if not self._grow(slot, j + 1):
            return False
        return self._make_writable(slot, j, j + 1)

    def _grow(self, slot: int, need: int) -> bool:
        have = int(self._allocated[slot])
        if need <= have:
            return True
        if need - have > len(self._free) + self._evictable_pages():
            return False
        for j in range(have, need):
            p = self._pop_page()
            assert p is not None, "availability check raced the pool"
            self._table[slot, j] = p
        self._allocated[slot] = need
        self._table_dev = None
        return True

    def _make_writable(self, slot: int, j0: int, j1: int) -> bool:
        """Copy-on-write every shared page among ``slot``'s table
        entries ``[j0, j1)``: a write must never dirty a page another
        slot (or the trie) still reads."""
        for j in range(j0, min(j1, int(self._allocated[slot]))):
            src = int(self._table[slot, j])
            if src == TRASH_PAGE or self.refcount[src] <= 1:
                continue
            dst = self._pop_page()
            if dst is None:
                return False
            self.refcount[src] -= 1     # still > 0: others hold it
            self._table[slot, j] = dst
            self._pending_copies.append((src, dst))
            self.prefix_copies += 1
            self._table_dev = None
        return True

    def drain_copies(self) -> List[Tuple[int, int]]:
        """Take the queued (src, dst) device page copies.  The engine
        MUST apply them (``PagedKVCache.copy_page``) before the next
        gather that could read a dst page — a COW'd page holds garbage
        until its copy lands."""
        out, self._pending_copies = self._pending_copies, []
        return out

    # --- prefix sharing -----------------------------------------------------

    def shared_rows(self, prompt: Sequence[int]) -> int:
        """Rows of ``prompt`` an admission right now would reuse."""
        if self.trie is None:
            return 0
        m = self.trie.match(prompt, touch=False)
        return m.full_pages * self.spec.page_size + m.boundary_rows

    def can_admit(self, prompt: Sequence[int]) -> bool:
        """Page-budget admission gate, counting only the NEW pages a
        prompt needs: matched full pages are adopted (refcount++, no
        pool cost) — but adopting a trie-only page also pins it, so
        pages that are both "matched" and "evictable" can't be counted
        twice."""
        if not self.is_paged:
            return True
        if self.trie is None:
            return self.can_reserve(len(prompt))
        m = self.trie.match(prompt, touch=False)
        need = self.spec.pages_for(len(prompt)) - m.full_pages
        pinned = sum(1 for p in m.pages if self.refcount[p] == 1)
        return need <= len(self._free) + self._evictable_pages() - pinned

    def admit_prompt(self, slot: int, prompt: Sequence[int]
                     ) -> Optional[int]:
        """Map ``prompt``'s shared prefix onto existing pages and
        reserve fresh pages for the rest (all-or-nothing; a failure
        rolls the slot back and returns None — callers gate on
        :meth:`can_admit` first).

        Returns the number of ALREADY-VALID leading rows: the engine's
        suffix prefill starts there.  Full-page matches are adopted in
        place (refcount++); a boundary match additionally allocates one
        private page and queues a device copy from the donor
        ("copy-on-adopt"), leaving only the final prompt row — whose
        logits are never cached — to recompute.
        """
        n = len(prompt)
        if not self.is_paged or self.trie is None:
            return 0 if self.reserve(slot, n) else None
        assert int(self._allocated[slot]) == 0, \
            "admit_prompt needs a released slot"
        ps = self.spec.page_size
        m = self.trie.match(prompt)
        copies: List[Tuple[int, int]] = []
        for j, p in enumerate(m.pages):         # adopt full shared pages
            self._table[slot, j] = p
            self.refcount[p] += 1
        self._allocated[slot] = m.full_pages
        if m.pages:
            self._table_dev = None
        shared = m.full_pages * ps
        if m.boundary_page is not None:
            # privatize the donor's boundary page: rows
            # [shared, shared + boundary_rows) become valid on arrival
            # of the device copy (drained by the engine pre-prefill)
            if self._grow(slot, m.full_pages + 1):
                copies.append((m.boundary_page,
                               int(self._table[slot, m.full_pages])))
                shared += m.boundary_rows
            # on grow failure fall through: the final _grow below also
            # fails and rolls everything back
        if not self._grow(slot, self.spec.pages_for(n)) or \
                not self._make_writable(slot, shared // ps,
                                        (n - 1) // ps + 1):
            self.release(slot)                  # rollback (refcounts too)
            return None
        if shared:
            self.prefix_hits += 1
            self.prefix_shared_rows += shared
            self.prefix_copies += len(copies)
        self._pending_copies.extend(copies)
        return shared

    def register_prefix(self, slot: int, prompt: Sequence[int]) -> int:
        """Index ``slot``'s freshly prefilled prompt into the trie
        (FULL pages only — a partial page's tail rows are garbage).
        Newly anchored pages gain a trie reference; at
        ``prefix_capacity`` the LRU trie-only pages are evicted to make
        room, and extension stops if none can be.  Returns the number of
        pages newly anchored."""
        if self.trie is None:
            return 0
        full = len(prompt) // self.spec.page_size
        if not full:
            return 0
        pages = [int(p) for p in self._table[slot, :full]]

        def can_add() -> bool:
            cap = self.trie.capacity
            if cap is None or self.trie.anchored < cap:
                return True
            return self._evict_one()

        new = self.trie.insert(prompt, pages, can_add=can_add)
        for p in new:
            self.refcount[p] += 1
        return len(new)

    def reset_prefix(self) -> int:
        """Drop every trie anchor (pages free once unreferenced
        elsewhere).  Returns the number of anchors dropped."""
        if self.trie is None:
            return 0
        dropped = 0
        while self._evict_one():
            dropped += 1
        # anything left is adopter-pinned; detach anchors anyway so the
        # trie is empty and the pages free when their adopters finish
        remaining = self.trie.pop_evictable(lambda pg: True)
        while remaining is not None:
            self._unref(remaining)
            dropped += 1
            remaining = self.trie.pop_evictable(lambda pg: True)
        return dropped

    # --- invariants ---------------------------------------------------------

    def check_conservation(self) -> None:
        """Assert the page-conservation invariants (tests / benchmarks):

        - refcount[p] == slot-table references within allocated
          prefixes + (1 if the trie anchors p);
        - referenced + free partitions the data pool exactly (every page
          is live xor free — ``sum(refcounts of live pages)`` counts
          each shared page once per owner, so the distinct-live count is
          what conservation is stated over);
        - a page reachable from two slots has refcount >= 2;
        - the trash page is never refcounted, never free-listed, never
          inside an allocated prefix.
        """
        who = f"[{self.label}] " if self.label else ""
        rc = np.zeros_like(self.refcount)
        owners: Dict[int, int] = {}
        for i in range(self.B):
            for j in range(int(self._allocated[i])):
                p = int(self._table[i, j])
                assert p != TRASH_PAGE, \
                    f"{who}slot {i} allocated prefix holds the trash page"
                rc[p] += 1
                owners[p] = owners.get(p, 0) + 1
        if self.trie is not None:
            for p in self.trie.pages():
                rc[p] += 1
        assert (rc == self.refcount).all(), \
            f"{who}refcount drift: expected {rc.tolist()}, " \
            f"have {self.refcount.tolist()}"
        for p, k in owners.items():
            if k >= 2:
                assert self.refcount[p] >= 2, \
                    f"{who}page {p} in {k} slots with refcount " \
                    f"{int(self.refcount[p])}"
        live = {int(p) for p in np.nonzero(self.refcount)[0]}
        free = set(self._free)
        assert len(free) == len(self._free), \
            f"{who}free list holds duplicates"
        assert not (live & free), \
            f"{who}pages both live and free: {live & free}"
        assert TRASH_PAGE not in free and TRASH_PAGE not in live
        assert len(live) + len(free) == self.spec.total_pages, \
            f"{who}pool leak: {len(live)} live + {len(free)} free != " \
            f"{self.spec.total_pages}"

    # --- observability ------------------------------------------------------

    def describe(self) -> Dict[str, object]:
        d: Dict[str, object] = {
            "layout": self.spec.layout,
            "kv_dtype": self.spec.kv_dtype,
        }
        if self.label:
            d["label"] = self.label
        d.update({
            "storage_bytes": self.layout.storage_bytes(),
            "dense_bytes": self.layout.dense_bytes(),
            "resident_max": self.resident_max(),
        })
        if self.is_paged:
            d.update(page_size=self.spec.page_size,
                     total_pages=self.spec.total_pages,
                     free_pages=len(self._free),
                     allocated=[int(a) for a in self._allocated])
            if self.trie is not None:
                d.update(share_prefix=True,
                         prefix_anchored_pages=self.trie.anchored,
                         prefix_hits=self.prefix_hits,
                         prefix_shared_rows=self.prefix_shared_rows,
                         prefix_copies=self.prefix_copies,
                         pages_allocated_total=self.pages_allocated_total)
        return d
