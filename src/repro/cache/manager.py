"""CacheManager: resolves a CacheSpec into a layout + owns residency.

The manager is the pure-Python half of the cache subsystem (the analogue
of the PR-3 ``Scheduler``): it tracks per-slot resident lengths
(``kv_len`` — the source of truth the Planner's resident-length buckets
come from), and, for the paged layout, the free-list and per-slot page
tables.  The serving engine owns the device arrays (donation flow) and
asks the manager *where* things live; the layout supplies the traceable
gather/scatter.

Page-table discipline:

- page 0 is the trash page; a freshly-initialized or released slot's
  whole table row points there;
- allocation is per-slot prefix-contiguous: slot ``i`` holding ``n``
  resident rows owns table entries ``[0, pages_for(n))``;
- allocation is all-or-nothing (a partial grab is rolled back), so a
  ``False`` from :meth:`reserve` / :meth:`ensure` leaves no state to
  clean up — the engine turns it into the per-request
  ``cache_capacity`` finish.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.cache.layout import CacheLayout, DenseLayout, PagedKVCache
from repro.cache.spec import TRASH_PAGE, CacheSpec

_LAYOUTS = {"dense": DenseLayout, "paged": PagedKVCache}


class CacheManager:
    """Residency bookkeeping + layout resolution for one engine."""

    def __init__(self, model, spec: CacheSpec):
        self.spec = spec
        self.layout: CacheLayout = _LAYOUTS[spec.layout](model, spec)
        self.B = spec.batch
        self.kv_len = np.zeros(self.B, np.int32)
        self._table = np.full((self.B, max(1, spec.slot_pages)),
                              TRASH_PAGE, np.int32)
        self._allocated = np.zeros(self.B, np.int32)   # prefix page count
        self._free: List[int] = list(range(spec.total_pages, 0, -1)) \
            if spec.layout == "paged" else []
        self._table_dev = None                         # dirty => None

    # --- storage ------------------------------------------------------------

    @property
    def is_paged(self) -> bool:
        return self.spec.layout == "paged"

    def init_storage(self):
        return self.layout.init_storage()

    def table_device(self):
        """Device mirror of the page table, re-uploaded only when an
        allocation / release dirtied it (not per decode step)."""
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self._table)
        return self._table_dev

    # --- residency ----------------------------------------------------------

    def note_write(self, slot: int, pos: int) -> None:
        """Record that row ``pos`` of ``slot`` is now resident."""
        self.kv_len[slot] = max(self.kv_len[slot], pos + 1)

    def resident_max(self) -> int:
        """Largest per-slot resident length (the planner's summary)."""
        return int(self.kv_len.max()) if self.B else 0

    def release(self, slot: int) -> None:
        """Free a finished slot: resident length to zero, pages back to
        the free list, table row to the trash page (a dead slot still
        rides the lockstep launch — its writes must land in trash)."""
        self.kv_len[slot] = 0
        n = int(self._allocated[slot])
        if n:
            self._free.extend(int(p) for p in self._table[slot, :n][::-1])
            self._table[slot, :n] = TRASH_PAGE
            self._allocated[slot] = 0
            self._table_dev = None

    # --- page accounting ----------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_for(self, length: int) -> int:
        return self.spec.pages_for(length)

    def max_request_pages(self) -> int:
        """Largest allocation a single request may ever need."""
        return self.spec.slot_pages

    def can_reserve(self, length: int) -> bool:
        """Whether a fresh slot could hold ``length`` rows right now."""
        if not self.is_paged:
            return True
        return self.pages_for(length) <= len(self._free)

    def reserve(self, slot: int, length: int) -> bool:
        """Grow ``slot``'s allocation to cover ``length`` rows
        (all-or-nothing)."""
        if not self.is_paged:
            return True
        return self._grow(slot, self.pages_for(length))

    def ensure(self, slot: int, pos: int) -> bool:
        """Make row ``pos`` of ``slot`` writable (allocating its page if
        needed).  ``False`` = pool exhausted: the engine finishes the
        request with ``finish_reason='cache_capacity'``."""
        if not self.is_paged:
            return pos < self.spec.max_len
        return self._grow(slot, pos // self.spec.page_size + 1)

    def _grow(self, slot: int, need: int) -> bool:
        have = int(self._allocated[slot])
        if need <= have:
            return True
        if need - have > len(self._free):
            return False
        for j in range(have, need):
            self._table[slot, j] = self._free.pop()
        self._allocated[slot] = need
        self._table_dev = None
        return True

    # --- observability ------------------------------------------------------

    def describe(self) -> Dict[str, object]:
        d: Dict[str, object] = {
            "layout": self.spec.layout,
            "kv_dtype": self.spec.kv_dtype,
            "storage_bytes": self.layout.storage_bytes(),
            "dense_bytes": self.layout.dense_bytes(),
            "resident_max": self.resident_max(),
        }
        if self.is_paged:
            d.update(page_size=self.spec.page_size,
                     total_pages=self.spec.total_pages,
                     free_pages=len(self._free),
                     allocated=[int(a) for a in self._allocated])
        return d
