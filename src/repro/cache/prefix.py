"""PrefixTrie: token-keyed index of shareable KV pages.

The host-side half of prefix sharing (vLLM PagedAttention / SGLang
RadixAttention lineage, page-granular like vLLM rather than
arbitrary-split radix): one trie node per FULL page of a previously
prefilled prompt, keyed by that page's ``page_size`` token ids.  A node
chain from the root therefore names a token prefix AND the exact pages
holding its K/V rows — admission walks the new prompt down the chain and
adopts every matched page instead of recomputing it.

Refcounts live in the :class:`~repro.cache.CacheManager` (the trie never
touches them): every anchored node holds one reference on its page, so a
page can outlive the request that prefilled it.  Two match grades:

- **full-page** — the prompt's next ``page_size`` tokens equal a child's
  key: the child's page is adopted in place (refcount++, no copy);
- **boundary** — the prompt ends mid-page but a child's key STARTS with
  the remaining tokens: the child's page holds a superset of the rows
  the prompt needs, so the manager copies it into a fresh private page
  ("copy-on-adopt" — the donor stays anchored for future full matches).

Eviction is leaf-first LRU over nodes whose page the manager reports as
trie-only (``refcount == 1``): an in-use chain's ancestors are all
pinned by their adopters' refcounts, so evictable nodes always form
whole subtrees and leaf-first removal reaches every one of them.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterator, List, Optional, Sequence, \
    Tuple


class _Node:
    """One full page of a cached prefix: key = its page of token ids."""

    __slots__ = ("key", "page", "parent", "children", "last_use")

    def __init__(self, key: Tuple[int, ...], page: int,
                 parent: Optional["_Node"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_use = 0


class PrefixMatch:
    """What a prompt can reuse: adopted pages + an optional copy donor."""

    __slots__ = ("pages", "boundary_page", "boundary_rows")

    def __init__(self, pages: List[int], boundary_page: Optional[int],
                 boundary_rows: int):
        self.pages = pages              # full-page adoptions, in order
        self.boundary_page = boundary_page  # copy-on-adopt donor (or None)
        self.boundary_rows = boundary_rows  # rows the donor covers

    @property
    def full_pages(self) -> int:
        return len(self.pages)


class PrefixTrie:
    """Page-granular prefix index over previously prefilled prompts."""

    def __init__(self, page_size: int, capacity: Optional[int] = None):
        assert page_size >= 1
        self.page_size = page_size
        self.capacity = capacity        # max anchored pages (None = inf)
        self.root = _Node((), -1, None)
        self.anchored = 0               # live (non-root) node count
        self._clock = 0                 # logical LRU time

    # --- lookup -------------------------------------------------------------

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.last_use = self._clock

    def match(self, tokens: Sequence[int], *,
              touch: bool = True) -> PrefixMatch:
        """Longest reusable prefix of ``tokens``.

        Full-page matching is capped at ``(len(tokens) - 1) // page_size``
        pages: the LAST prompt token's logits are never cached, so at
        least one row must always be recomputed by the suffix prefill.
        The boundary donor (when present) covers every remaining row but
        that last one — ``boundary_rows == len(tokens) - full_rows - 1``
        is implied and stored explicitly for the caller's arithmetic.
        """
        ps = self.page_size
        n = len(tokens)
        pages: List[int] = []
        node = self.root
        cap = max(0, (n - 1) // ps)     # full pages adoptable
        while len(pages) < cap:
            j = len(pages)
            key = tuple(tokens[j * ps:(j + 1) * ps])
            child = node.children.get(key)
            if child is None:
                break
            if touch:
                self._touch(child)
            pages.append(child.page)
            node = child
        rest = tuple(tokens[len(pages) * ps:])
        # a donor must cover rows [0, len(rest) - 1) of the remainder in
        # ONE page, so len(rest) <= ps is implied: a longer rest's first
        # ps tokens would have been a full-page child (checked above)
        if 2 <= len(rest) <= ps:        # >= 1 copied row + the recomputed one
            for key, child in node.children.items():
                if key[:len(rest)] == rest:
                    if touch:
                        self._touch(child)
                    return PrefixMatch(pages, child.page, len(rest) - 1)
        return PrefixMatch(pages, None, 0)

    # --- insertion ----------------------------------------------------------

    def insert(self, tokens: Sequence[int], pages: Sequence[int], *,
               can_add: Optional[Callable[[], bool]] = None) -> List[int]:
        """Index ``tokens``' FULL pages, returning the pages newly
        anchored (the caller owns their refcounts).  Existing nodes are
        deduped — a re-prefilled identical prefix anchors nothing new
        and the prompt's own copy of the page stays private.  ``can_add``
        is consulted before each new node (the manager's capacity /
        eviction hook); a False stops extension at that depth.
        """
        ps = self.page_size
        full = len(tokens) // ps
        assert len(pages) >= full, "insert needs one page per full chunk"
        node = self.root
        new: List[int] = []
        for j in range(full):
            key = tuple(tokens[j * ps:(j + 1) * ps])
            child = node.children.get(key)
            if child is None:
                if can_add is not None and not can_add():
                    break
                child = _Node(key, int(pages[j]), node)
                node.children[key] = child
                self.anchored += 1
                new.append(child.page)
            self._touch(child)
            node = child
        return new

    # --- eviction -----------------------------------------------------------

    def _iter_nodes(self) -> Iterator[_Node]:
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())

    def pages(self) -> Iterator[int]:
        """Every anchored page (conservation checks read this)."""
        for node in self._iter_nodes():
            yield node.page

    def pop_evictable(self, evictable: Callable[[int], bool]
                      ) -> Optional[int]:
        """Detach the LRU LEAF whose page the predicate allows (the
        manager passes ``refcount == 1``, i.e. trie-only) and return its
        page; None when nothing qualifies.  Interior nodes become leaves
        as their subtrees drain, so repeated calls walk whole chains."""
        victim: Optional[_Node] = None
        for node in self._iter_nodes():
            if node.children or not evictable(node.page):
                continue
            if victim is None or node.last_use < victim.last_use:
                victim = node
        if victim is None:
            return None
        del victim.parent.children[victim.key]
        self.anchored -= 1
        return victim.page

    def __len__(self) -> int:
        return self.anchored
