"""Checkpointing: sharded, atomic, async, elastic-restorable."""
from repro.checkpoint.checkpoint import (  # noqa: F401
    AsyncCheckpointer,
    latest_step,
    restore,
    save,
)
