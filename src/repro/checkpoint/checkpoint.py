"""Sharded pytree checkpoints: atomic, keep-last-k, async, elastic.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json       # treedef, shapes, dtypes, step, mesh shape
        shard_00000.npz     # flat leaves (this host's addressable shards)

Writes go to ``step_N.tmp/`` then ``os.rename`` — a crashed writer never
corrupts the latest checkpoint (restore scans for the newest COMPLETE
directory).  ``AsyncCheckpointer`` runs the serialization on a worker
thread after blocking on device->host copies, overlapping I/O with the
next training steps (the fault-tolerance story in DESIGN.md).

**Elastic restore**: checkpoints are mesh-agnostic — leaves are saved
dense (gathered per host) and re-sharded on load via ``jax.device_put``
against the NEW mesh's shardings, so a job can restart on a different
pod count / mesh shape than it saved from.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any


def _flatten_with_paths(tree: Pytree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save(path: str | Path, step: int, tree: Pytree,
         keep: int = 3) -> Path:
    """Blocking checkpoint write with atomic rename + retention."""
    base = Path(path)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat, _ = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "leaves": []}
    for key, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype not in ("float64", "float32", "float16", "int64", "int32",
                         "int16", "int8", "uint8", "uint16", "uint32",
                         "uint64", "bool"):
            # numpy's savez can't round-trip ml_dtypes (bfloat16 etc.):
            # store the raw bits and the true dtype in the manifest
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                           else np.uint8)
        arrays[key.replace("/", "__")] = arr
        manifest["leaves"].append(
            {"key": key, "shape": list(arr.shape), "dtype": dtype})
    np.savez(tmp / "shard_00000.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(base, keep)
    return final


def _retain(base: Path, keep: int) -> None:
    steps = sorted(p for p in base.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(path: str | Path) -> Optional[int]:
    base = Path(path)
    if not base.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in base.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and not p.name.endswith(".tmp")
             and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(path: str | Path, like: Pytree, *, step: Optional[int] = None,
            shardings: Optional[Pytree] = None) -> Tuple[int, Pytree]:
    """Restore into the structure of ``like``; reshard onto ``shardings``.

    ``shardings`` may target a DIFFERENT mesh than the checkpoint was
    written from (elastic restart): leaves are stored dense and placed
    with ``jax.device_put`` per-leaf.
    """
    base = Path(path)
    if step is None:
        step = latest_step(base)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {base}")
    d = base / f"step_{step:08d}"
    data = np.load(d / "shard_00000.npz")
    manifest = json.loads((d / "manifest.json").read_text())
    saved_dtype = {e["key"]: e["dtype"] for e in manifest["leaves"]}

    flat, treedef = _flatten_with_paths(like)
    leaves = []
    flat_sh = (treedef.flatten_up_to(shardings)
               if shardings is not None else [None] * len(flat))
    for (key, proto), sh in zip(flat, flat_sh):
        arr = data[key.replace("/", "__")]
        true_dtype = jax.numpy.dtype(saved_dtype[key])
        if arr.dtype != true_dtype:      # bit-stored ml_dtype: view back
            arr = arr.view(true_dtype)
        want = jax.numpy.dtype(jax.numpy.asarray(proto).dtype
                               if not hasattr(proto, "dtype")
                               else proto.dtype)
        if arr.dtype != want:
            arr = arr.astype(want)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return step, jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """One-in-flight async writer; ``wait()`` before process exit."""

    def __init__(self, path: str | Path, keep: int = 3):
        self.path = Path(path)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Pytree) -> None:
        self.wait()
        # block on device->host copies NOW (cheap), serialize on the thread
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save(self.path, step, host_tree, keep=self.keep)
            except BaseException as e:                  # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
