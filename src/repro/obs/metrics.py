"""MetricsRegistry: labeled counters / gauges / fixed-bucket histograms.

Pure host-side, dependency-free instruments with two export surfaces:

- :meth:`MetricsRegistry.snapshot` — a JSON-safe dict, one entry per
  metric family: ``{"kind", "help", "series": {label_key: value},
  "aggregate": merged}``.  The ``aggregate`` entry merges every label
  series (counters/gauges sum; histograms merge counts, sums and
  retained samples), so a sharded engine's per-``shard=d`` series and
  their cross-shard merge ship in one snapshot.
- :meth:`MetricsRegistry.prometheus` — Prometheus text exposition
  (``# HELP`` / ``# TYPE`` + one line per series; histograms render the
  standard cumulative ``_bucket{le=...}`` / ``_sum`` / ``_count``
  triplet), every name prefixed ``repro_``.

Histograms keep fixed buckets (Prometheus-style upper bounds) PLUS the
raw samples (bounded at ``SAMPLE_CAP``), so snapshot percentiles are
exact — the serving A/B's TTFT/TPOT ``mean``/``p50``/``p90`` columns
read them verbatim instead of re-timing around ``step()``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Iterable, List, Optional, Tuple

# default latency buckets (milliseconds): sub-ms to 10s
DEFAULT_BUCKETS = (1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                   500.0, 1000.0, 2500.0, 5000.0, 10000.0)
# raw samples retained per histogram series for exact percentiles; a
# run long enough to overflow this reports percentiles over the first
# SAMPLE_CAP observations (count/sum/buckets stay exact)
SAMPLE_CAP = 65536

NAMESPACE = "repro"


def _label_key(labels: Dict[str, str]) -> str:
    return ",".join(f"{k}={labels[k]}" for k in sorted(labels))


def _percentile(samples: List[float], q: float) -> float:
    """numpy-style linear-interpolation percentile, ``q`` in [0, 1]."""
    if not samples:
        return 0.0
    s = sorted(samples)
    k = (len(s) - 1) * q
    f, c = math.floor(k), math.ceil(k)
    if f == c:
        return float(s[int(k)])
    return float(s[f] + (s[c] - s[f]) * (k - f))


class _Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1) -> None:
        self.value += n


class _Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class _Histogram:
    __slots__ = ("buckets", "counts", "count", "sum", "samples")

    def __init__(self, buckets: Tuple[float, ...]) -> None:
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)   # +1: the +Inf bucket
        self.count = 0
        self.sum = 0.0
        self.samples: List[float] = []

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        i = 0
        for i, le in enumerate(self.buckets):
            if x <= le:
                break
        else:
            i = len(self.buckets)
        self.counts[i] += 1
        if len(self.samples) < SAMPLE_CAP:
            self.samples.append(x)

    def snapshot(self) -> Dict[str, Any]:
        cum, acc = {}, 0
        for le, n in zip(self.buckets, self.counts):
            acc += n
            cum[f"{le:g}"] = acc
        cum["+Inf"] = self.count
        return {
            "count": self.count,
            "sum": round(self.sum, 6),
            "mean": round(self.sum / self.count, 6) if self.count else 0.0,
            "min": round(min(self.samples), 6) if self.samples else 0.0,
            "max": round(max(self.samples), 6) if self.samples else 0.0,
            "p50": round(_percentile(self.samples, 0.50), 6),
            "p90": round(_percentile(self.samples, 0.90), 6),
            "p99": round(_percentile(self.samples, 0.99), 6),
            "buckets": cum,
        }


class Family:
    """One named metric family: children per label combination."""

    def __init__(self, kind: str, name: str, help: str,
                 buckets: Optional[Tuple[float, ...]] = None) -> None:
        self.kind = kind
        self.name = name
        self.help = help
        self.buckets = buckets
        self._children: Dict[str, Any] = {}
        self._child_labels: Dict[str, Dict[str, str]] = {}

    def labels(self, **labels: Any) -> Any:
        lv = {k: str(v) for k, v in labels.items()}
        key = _label_key(lv)
        child = self._children.get(key)
        if child is None:
            if self.kind == "counter":
                child = _Counter()
            elif self.kind == "gauge":
                child = _Gauge()
            else:
                child = _Histogram(self.buckets or DEFAULT_BUCKETS)
            self._children[key] = child
            self._child_labels[key] = lv
        return child

    # no-label convenience (single-engine fast path)
    def inc(self, n: float = 1, **labels: Any) -> None:
        self.labels(**labels).inc(n)

    def set(self, v: float, **labels: Any) -> None:
        self.labels(**labels).set(v)

    def observe(self, x: float, **labels: Any) -> None:
        self.labels(**labels).observe(x)

    # --- export -------------------------------------------------------------

    def _aggregate(self) -> Any:
        if self.kind in ("counter", "gauge"):
            return round(sum(c.value for c in self._children.values()), 6)
        merged = _Histogram(self.buckets or DEFAULT_BUCKETS)
        for c in self._children.values():
            merged.count += c.count
            merged.sum += c.sum
            for i, n in enumerate(c.counts):
                merged.counts[i] += n
            room = SAMPLE_CAP - len(merged.samples)
            merged.samples.extend(c.samples[:room])
        return merged.snapshot()

    def snapshot(self) -> Dict[str, Any]:
        series = {}
        for key, c in self._children.items():
            series[key] = (c.snapshot() if self.kind == "histogram"
                           else round(c.value, 6))
        return {"kind": self.kind, "help": self.help, "series": series,
                "aggregate": self._aggregate()}

    def prometheus(self) -> List[str]:
        full = f"{NAMESPACE}_{self.name}"
        lines = [f"# HELP {full} {self.help}",
                 f"# TYPE {full} {self.kind}"]

        def fmt(labels: Dict[str, str], extra: str = "") -> str:
            parts = [f'{k}="{v}"' for k, v in sorted(labels.items())]
            if extra:
                parts.append(extra)
            return "{" + ",".join(parts) + "}" if parts else ""

        for key, c in self._children.items():
            lv = self._child_labels[key]
            if self.kind in ("counter", "gauge"):
                lines.append(f"{full}{fmt(lv)} {c.value:g}")
            else:
                acc = 0
                for le, n in zip(c.buckets, c.counts):
                    acc += n
                    extra = 'le="%g"' % le
                    lines.append(f"{full}_bucket{fmt(lv, extra)} {acc}")
                inf = 'le="+Inf"'
                lines.append(f"{full}_bucket{fmt(lv, inf)} {c.count}")
                lines.append(f"{full}_sum{fmt(lv)} {c.sum:g}")
                lines.append(f"{full}_count{fmt(lv)} {c.count}")
        return lines


class MetricsRegistry:
    """Named metric families, memoized by name (a second registration
    with the same name returns the existing family — shard views of one
    Observer share families and differ only in their bound labels)."""

    def __init__(self) -> None:
        self._families: "Dict[str, Family]" = {}

    def _get(self, kind: str, name: str, help: str,
             buckets: Optional[Tuple[float, ...]] = None) -> Family:
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}, "
                    f"not {kind}")
            return fam
        fam = Family(kind, name, help, buckets)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "") -> Family:
        return self._get("counter", name, help)

    def gauge(self, name: str, help: str = "") -> Family:
        return self._get("gauge", name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Family:
        return self._get("histogram", name, help, tuple(buckets))

    def snapshot(self) -> Dict[str, Any]:
        return {name: fam.snapshot()
                for name, fam in sorted(self._families.items())}

    def prometheus(self) -> str:
        lines: List[str] = []
        for _, fam in sorted(self._families.items()):
            lines.extend(fam.prometheus())
        return "\n".join(lines) + "\n" if lines else ""
