"""Atomic artifact writes (temp file + ``os.replace``).

Every observability artifact — the PlanCacheStats dump, the Chrome
trace, the metrics snapshot — goes through these helpers: the bytes
land in a temp file in the TARGET directory first and are renamed into
place, so a crash mid-``drain`` can never leave truncated JSON that a
downstream benchmark reader chokes on.  ``os.replace`` is atomic on
POSIX within one filesystem, which same-directory placement guarantees.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any


def atomic_write_text(path: Any, text: str) -> Path:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=str(p.parent), prefix=p.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, p)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return p


def atomic_write_json(path: Any, obj: Any, *, indent: int = 1,
                      sort_keys: bool = True) -> Path:
    return atomic_write_text(
        path, json.dumps(obj, indent=indent, sort_keys=sort_keys) + "\n")
