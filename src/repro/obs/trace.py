"""Tracer -> TraceArtifact: Chrome trace-event JSON, Perfetto-loadable.

The :class:`Tracer` is a pure host-side event sink — timestamps are
computed by the :class:`~repro.obs.observer.Observer` (which owns the
injectable monotonic clock) and passed in as already-monotonic
microsecond ints.  Events use the Chrome trace-event JSON format
(https://ui.perfetto.dev loads the artifact directly):

- ``ph="X"`` complete spans (``ts`` + ``dur`` in microseconds);
- ``ph="i"`` thread-scoped instant events (first token, warnings);
- ``ph="M"`` process/thread-name metadata, emitted once per track.

Track layout: ``pid`` is the shard index (process_name ``shard{d}``
under a sharded topology, the engine name otherwise); ``tid 0`` is the
"launches" track carrying per-launch spans stamped with LaunchPlan
provenance; ``tid = handle + 1`` is one track per request carrying its
lifecycle spans (queue_wait / admit / per-step decode/verify rows under
the enclosing "request" span).

:func:`validate_trace` is the schema gate the obs smoke and the trace
tests assert through: key/type checks per event plus per-track nesting
consistency — on any one (pid, tid) track, X spans must form a proper
forest (contained or disjoint, never partially overlapping) with
non-negative durations.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.obs.io import atomic_write_json

_PH = ("X", "i", "M")
_META_NAMES = ("process_name", "thread_name")


class Tracer:
    """Append-only Chrome trace-event sink (host side, no clock)."""

    def __init__(self) -> None:
        self._events: List[Dict[str, Any]] = []
        self._procs: Dict[int, Dict[str, Any]] = {}
        self._threads: Dict[tuple, str] = {}

    def __len__(self) -> int:
        return len(self._events)

    # --- metadata (once per track) ------------------------------------------

    def ensure_process(self, pid: int, name: str,
                       force: bool = False) -> None:
        """Register a pid's process name once; ``force`` renames an
        already-registered pid in place (a shard view claiming the pid
        its parent registered under the generic engine name)."""
        ev = self._procs.get(pid)
        if ev is not None:
            if force and ev["args"]["name"] != name:
                ev["args"]["name"] = name
            return
        ev = {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
              "ts": 0, "args": {"name": name}}
        self._procs[pid] = ev
        self._events.append(ev)

    def ensure_thread(self, pid: int, tid: int, name: str) -> None:
        if (pid, tid) in self._threads:
            return
        self._threads[(pid, tid)] = name
        self._events.append({"name": "thread_name", "ph": "M",
                             "pid": pid, "tid": tid, "ts": 0,
                             "args": {"name": name}})

    # --- events -------------------------------------------------------------

    def complete(self, pid: int, tid: int, name: str, cat: str,
                 ts: int, dur: int,
                 args: Optional[Dict[str, Any]] = None) -> None:
        ev: Dict[str, Any] = {"name": name, "cat": cat, "ph": "X",
                              "pid": pid, "tid": tid,
                              "ts": int(ts), "dur": max(0, int(dur))}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def instant(self, pid: int, tid: int, name: str, cat: str, ts: int,
                args: Optional[Dict[str, Any]] = None) -> None:
        ev: Dict[str, Any] = {"name": name, "cat": cat, "ph": "i",
                              "pid": pid, "tid": tid, "ts": int(ts),
                              "s": "t"}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def artifact(self) -> "TraceArtifact":
        """Snapshot the events recorded so far (list is copied — the
        tracer keeps recording; a later artifact supersedes)."""
        return TraceArtifact(events=list(self._events))


@dataclass
class TraceArtifact:
    """The exported trace: ``{"traceEvents": [...]}`` + helpers."""

    events: List[Dict[str, Any]] = field(default_factory=list)

    def to_json(self) -> Dict[str, Any]:
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def save(self, path: Any) -> None:
        atomic_write_json(path, self.to_json())

    @classmethod
    def load(cls, path: Any) -> "TraceArtifact":
        import json
        from pathlib import Path
        obj = json.loads(Path(path).read_text())
        return cls(events=obj["traceEvents"])

    def validate(self) -> None:
        validate_trace(self.to_json())

    # --- query helpers (tests / smoke assertions) ---------------------------

    def spans(self, name: Optional[str] = None,
              cat: Optional[str] = None) -> List[Dict[str, Any]]:
        return [e for e in self.events if e["ph"] == "X"
                and (name is None or e["name"] == name)
                and (cat is None or e.get("cat") == cat)]

    def instants(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        return [e for e in self.events if e["ph"] == "i"
                and (name is None or e["name"] == name)]


def validate_trace(obj: Any) -> None:
    """Raise ``ValueError`` unless ``obj`` is schema-valid Chrome trace
    JSON with nesting-consistent, non-negative-duration spans."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be a dict with a traceEvents list")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    tracks: Dict[tuple, List[Dict[str, Any]]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        for k, t in (("name", str), ("ph", str), ("pid", int),
                     ("tid", int), ("ts", int)):
            if not isinstance(ev.get(k), t) or isinstance(ev.get(k), bool):
                raise ValueError(f"event {i}: missing/invalid {k!r}")
        if ev["ph"] not in _PH:
            raise ValueError(f"event {i}: unknown ph {ev['ph']!r}")
        if ev["ts"] < 0:
            raise ValueError(f"event {i}: negative ts")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), int) or ev["dur"] < 0:
                raise ValueError(f"event {i}: X span needs dur >= 0")
            tracks.setdefault((ev["pid"], ev["tid"]), []).append(ev)
        elif ev["ph"] == "M":
            if ev["name"] not in _META_NAMES:
                raise ValueError(
                    f"event {i}: metadata name {ev['name']!r} not in "
                    f"{_META_NAMES}")
            if not isinstance(ev.get("args", {}).get("name"), str):
                raise ValueError(f"event {i}: metadata needs args.name")
    # nesting consistency per track: sorted by (ts, -dur), every span is
    # either contained in the open ancestor or starts at/after its end
    for (pid, tid), spans in tracks.items():
        spans.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: List[Dict[str, Any]] = []
        for ev in spans:
            t0, t1 = ev["ts"], ev["ts"] + ev["dur"]
            while stack and t0 >= stack[-1]["ts"] + stack[-1]["dur"]:
                stack.pop()
            if stack and t1 > stack[-1]["ts"] + stack[-1]["dur"]:
                top = stack[-1]
                raise ValueError(
                    f"track (pid={pid}, tid={tid}): span "
                    f"{ev['name']!r} [{t0}, {t1}) partially overlaps "
                    f"{top['name']!r} [{top['ts']}, "
                    f"{top['ts'] + top['dur']})")
            stack.append(ev)
